"""L2 model correctness: custom-vjp gradients vs pure-jnp autodiff, train
step semantics, and per-environment shape checks."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rng(seed):
    return np.random.default_rng(seed)


def _rand_params(spec, r, scale=0.3):
    params = []
    dims = spec.dims
    for i in range(model.N_LAYERS):
        params.append(jnp.asarray(
            r.normal(size=(dims[i], dims[i + 1]), scale=scale), jnp.float32))
        params.append(jnp.asarray(r.normal(size=(dims[i + 1],), scale=0.1),
                                  jnp.float32))
    return params


def _rand_batch(spec, r):
    b = spec.batch
    return dict(
        obs=jnp.asarray(r.normal(size=(b, spec.obs_dim)), jnp.float32),
        actions=jnp.asarray(r.integers(0, spec.n_actions, size=(b,)),
                            jnp.int32),
        rewards=jnp.asarray(r.normal(size=(b,)), jnp.float32),
        next_obs=jnp.asarray(r.normal(size=(b, spec.obs_dim)), jnp.float32),
        dones=jnp.asarray(r.integers(0, 2, size=(b,)), jnp.float32),
        is_weights=jnp.asarray(r.uniform(0.1, 1.0, size=(b,)), jnp.float32),
    )


def _ref_loss(spec, params, tparams, batch):
    """Pure-jnp replica of model.loss_fn (no Pallas anywhere)."""
    ws, bs = params[0::2], params[1::2]
    tws, tbs = tparams[0::2], tparams[1::2]
    q = ref.mlp_forward_ref(batch["obs"], ws, bs)
    q_sa = jnp.take_along_axis(q, batch["actions"][:, None], axis=1)[:, 0]
    tq = ref.mlp_forward_ref(batch["next_obs"], tws, tbs)
    if spec.double_dqn:
        nq = ref.mlp_forward_ref(batch["next_obs"], ws, bs)
        na = jnp.argmax(nq, axis=1)
        tmax = jnp.take_along_axis(tq, na[:, None], axis=1)[:, 0]
    else:
        tmax = jnp.max(tq, axis=1)
    tmax = jax.lax.stop_gradient(tmax)
    td = ref.td_error_ref(q_sa, tmax, batch["rewards"], batch["dones"],
                          spec.gamma)
    return ref.weighted_huber_ref(td, batch["is_weights"]), td


@pytest.mark.parametrize("env", ["cartpole", "acrobot", "lunarlander"])
def test_custom_vjp_grads_match_pure_jnp(env):
    """The Pallas-backed backward pass must equal jnp autodiff."""
    spec = model.ENV_SPECS[env]
    r = _rng(hash(env) % 2**31)
    params = _rand_params(spec, r)
    tparams = _rand_params(spec, r)
    batch = _rand_batch(spec, r)

    def pallas_loss(params):
        q = model.mlp_forward(params, batch["obs"])
        q_sa = jnp.take_along_axis(q, batch["actions"][:, None], axis=1)[:, 0]
        tq = model.mlp_forward(tparams, batch["next_obs"])
        nq = model.mlp_forward(params, batch["next_obs"])
        na = jnp.argmax(nq, axis=1)
        tmax = jax.lax.stop_gradient(
            jnp.take_along_axis(tq, na[:, None], axis=1)[:, 0])
        _, elems = model.td_huber_vjp(q_sa, tmax, batch["rewards"],
                                      batch["dones"], batch["is_weights"],
                                      spec.gamma, 1.0)
        return jnp.mean(elems)

    def jnp_loss(params):
        return _ref_loss(spec, params, tparams, batch)[0]

    g_pallas = jax.grad(pallas_loss)(params)
    g_ref = jax.grad(jnp_loss)(params)
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("env", list(model.ENV_SPECS))
def test_train_step_output_layout(env):
    """21 outputs in the documented flat order, finite values."""
    spec = model.ENV_SPECS[env]
    if env == "pongproxy":
        pytest.skip("covered by the AOT smoke test; slow under interpret")
    r = _rng(7)
    params = _rand_params(spec, r)
    tparams = [p.copy() for p in params]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    batch = _rand_batch(spec, r)
    ts = model.make_train_step(spec)
    out = jax.jit(ts)(*params, *tparams, *m, *v, jnp.float32(0.0),
                      batch["obs"], batch["actions"], batch["rewards"],
                      batch["next_obs"], batch["dones"], batch["is_weights"])
    assert len(out) == 6 + 6 + 6 + 1 + 1 + 1
    for i, p in enumerate(params):
        assert out[i].shape == p.shape
        assert bool(jnp.all(jnp.isfinite(out[i])))
    assert out[18].shape == ()          # t'
    assert float(out[18]) == 1.0
    assert out[19].shape == (spec.batch,)  # td
    assert out[20].shape == ()          # loss
    assert float(out[20]) >= 0.0


def test_train_step_adam_descends():
    """Repeated steps on a fixed batch must reduce the loss (Adam works)."""
    spec = model.ENV_SPECS["cartpole"]
    r = _rng(3)
    params = _rand_params(spec, r)
    tparams = [p.copy() for p in params]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    t = jnp.float32(0.0)
    batch = _rand_batch(spec, r)
    ts = jax.jit(model.make_train_step(spec))
    losses = []
    for _ in range(30):
        out = ts(*params, *tparams, *m, *v, t, batch["obs"],
                 batch["actions"], batch["rewards"], batch["next_obs"],
                 batch["dones"], batch["is_weights"])
        params, m, v, t = list(out[0:6]), list(out[6:12]), list(out[12:18]), out[18]
        losses.append(float(out[20]))
    assert losses[-1] < losses[0] * 0.5, losses[::6]


def test_td_output_equals_new_priorities_semantics():
    """td output of the train step must match the reference TD error
    computed from the *pre-update* parameters (that is what PER feeds back
    as new priorities)."""
    spec = model.ENV_SPECS["acrobot"]
    r = _rng(11)
    params = _rand_params(spec, r)
    tparams = _rand_params(spec, r)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    batch = _rand_batch(spec, r)
    ts = jax.jit(model.make_train_step(spec))
    out = ts(*params, *tparams, *m, *v, jnp.float32(0.0), batch["obs"],
             batch["actions"], batch["rewards"], batch["next_obs"],
             batch["dones"], batch["is_weights"])
    _, td_want = _ref_loss(spec, params, tparams, batch)
    np.testing.assert_allclose(out[19], td_want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("env", ["cartpole", "lunarlander"])
def test_act_argmax_consistent(env):
    spec = model.ENV_SPECS[env]
    r = _rng(5)
    params = _rand_params(spec, r)
    act = jax.jit(model.make_act(spec))
    obs = jnp.asarray(r.normal(size=(1, spec.obs_dim)), jnp.float32)
    a, q = act(*params, obs)
    assert a.dtype == jnp.int32
    assert int(a[0]) == int(jnp.argmax(q[0]))
    q_ref = ref.mlp_forward_ref(obs, params[0::2], params[1::2])
    np.testing.assert_allclose(q, q_ref, rtol=1e-4, atol=1e-4)


def test_init_params_shapes_and_scale():
    spec = model.ENV_SPECS["lunarlander"]
    params = model.init_params(spec, seed=0)
    dims = spec.dims
    assert len(params) == 6
    for i in range(3):
        assert params[2 * i].shape == (dims[i], dims[i + 1])
        assert params[2 * i + 1].shape == (dims[i + 1],)
        std = float(jnp.std(params[2 * i]))
        he = (2.0 / dims[i]) ** 0.5
        assert 0.5 * he < std < 1.5 * he
