"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis-style sweeps over shapes/dtypes/seeds (the registry is offline,
so the sweep grids are explicit parametrizations driven by seeded RNG —
same coverage intent as `hypothesis.given`; see DESIGN.md §4).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.kernels import qnet, td, tcam_match, ref


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# dense / MLP forward
# ---------------------------------------------------------------------------

DENSE_SHAPES = [
    (1, 4, 2), (64, 4, 128), (64, 128, 128), (64, 128, 2), (7, 13, 5),
    (33, 100, 3), (64, 6400, 512), (128, 8, 4), (2, 2, 2), (65, 129, 127),
]


@pytest.mark.parametrize("m,k,n", DENSE_SHAPES)
@pytest.mark.parametrize("relu", [False, True])
def test_dense_matches_ref(m, k, n, relu):
    r = _rng(m * 1000 + k * 10 + n + int(relu))
    x = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(r.normal(size=(n,)), jnp.float32)
    got = qnet.dense(x, w, b, relu=relu)
    want = ref.dense_relu_ref(x, w, b) if relu else ref.dense_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * k ** 0.5)


@pytest.mark.parametrize("seed", range(5))
def test_dense_block_size_invariance(seed):
    """Result must not depend on the tiling chosen."""
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(48, 96)), jnp.float32)
    w = jnp.asarray(r.normal(size=(96, 80)), jnp.float32)
    b = jnp.asarray(r.normal(size=(80,)), jnp.float32)
    base = qnet.dense(x, w, b, relu=True, bm=128, bn=128, bk=128)
    for bm, bn, bk in [(16, 16, 16), (8, 32, 96), (48, 80, 8)]:
        alt = qnet.dense(x, w, b, relu=True, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(base, alt, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dims", [
    [4, 128, 128, 2], [6, 128, 128, 3], [8, 128, 128, 4], [2, 16, 16, 3],
])
@pytest.mark.parametrize("batch", [1, 64])
def test_mlp_forward_matches_ref(dims, batch):
    r = _rng(sum(dims) + batch)
    x = jnp.asarray(r.normal(size=(batch, dims[0])), jnp.float32)
    ws = [jnp.asarray(r.normal(size=(dims[i], dims[i + 1]), scale=0.3),
                      jnp.float32) for i in range(3)]
    bs = [jnp.asarray(r.normal(size=(dims[i + 1],)), jnp.float32)
          for i in range(3)]
    got = qnet.mlp_forward(x, ws, bs)
    want = ref.mlp_forward_ref(x, ws, bs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_dense_zero_input():
    z = jnp.zeros((8, 8), jnp.float32)
    b = jnp.arange(8, dtype=jnp.float32)
    out = qnet.dense(z, z, b, relu=False)
    np.testing.assert_allclose(out, jnp.broadcast_to(b, (8, 8)))


# ---------------------------------------------------------------------------
# td_huber
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 8, 64, 256])
@pytest.mark.parametrize("gamma", [0.9, 0.99])
@pytest.mark.parametrize("seed", [0, 1])
def test_td_huber_matches_ref(batch, gamma, seed):
    r = _rng(seed * 31 + batch)
    q = jnp.asarray(r.normal(size=(batch,)), jnp.float32)
    tm = jnp.asarray(r.normal(size=(batch,)), jnp.float32)
    rew = jnp.asarray(r.normal(size=(batch,)), jnp.float32)
    done = jnp.asarray(r.integers(0, 2, size=(batch,)), jnp.float32)
    w = jnp.asarray(r.uniform(0.01, 1.0, size=(batch,)), jnp.float32)
    tdv, elems = td.td_huber(q, tm, rew, done, w, gamma=gamma)
    td_want = ref.td_error_ref(q, tm, rew, done, gamma)
    np.testing.assert_allclose(tdv, td_want, rtol=1e-5, atol=1e-6)
    loss_want = ref.weighted_huber_ref(td_want, w)
    np.testing.assert_allclose(jnp.mean(elems), loss_want, rtol=1e-5,
                               atol=1e-6)


def test_td_huber_done_masks_bootstrap():
    """done=1 must kill the bootstrap term entirely."""
    b = 16
    q = jnp.zeros((b,))
    tm = jnp.full((b,), 1e6, jnp.float32)  # would explode if not masked
    rew = jnp.ones((b,))
    done = jnp.ones((b,))
    w = jnp.ones((b,))
    tdv, _ = td.td_huber(q, tm, rew, done, w, gamma=0.99)
    np.testing.assert_allclose(tdv, jnp.ones((b,)), atol=1e-6)


def test_huber_quadratic_linear_regions():
    q = jnp.asarray([0.0, 0.0], jnp.float32)
    tm = jnp.zeros((2,), jnp.float32)
    rew = jnp.asarray([0.5, 3.0], jnp.float32)  # td = 0.5 (quad), 3.0 (lin)
    done = jnp.ones((2,), jnp.float32)
    w = jnp.ones((2,), jnp.float32)
    _, elems = td.td_huber(q, tm, rew, done, w, gamma=0.99, delta=1.0)
    np.testing.assert_allclose(elems, [0.5 * 0.25, 3.0 - 0.5], atol=1e-6)


# ---------------------------------------------------------------------------
# tcam_match
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,rpa", [(64, 64), (128, 64), (8192, 64), (256, 32)])
@pytest.mark.parametrize("seed", [0, 3])
def test_tcam_search_matches_ref(n, rpa, seed):
    r = _rng(seed + n)
    rows = jnp.asarray(
        r.integers(0, 2**32, size=(n,), dtype=np.uint64).astype(np.uint32))
    care = jnp.full((n,), 0xFFFFFFFF, jnp.uint32)
    q = jnp.uint32(rows[r.integers(0, n)])
    for prefix_bits in [32, 24, 16, 8, 0]:
        qc = jnp.uint32((0xFFFFFFFF << (32 - prefix_bits)) & 0xFFFFFFFF) \
            if prefix_bits else jnp.uint32(0)
        mt, mi = tcam_match.tcam_search(rows, care, q, qc, rows_per_array=rpa)
        np.testing.assert_array_equal(
            mt.astype(bool), ref.tcam_match_ref(rows, care, q, qc))
        np.testing.assert_array_equal(
            mi, ref.mismatch_count_ref(rows, care, q, qc))


def test_tcam_all_dont_care_matches_everything():
    rows = jnp.arange(64, dtype=jnp.uint32)
    care = jnp.full((64,), 0xFFFFFFFF, jnp.uint32)
    mt, mi = tcam_match.tcam_search(rows, care, jnp.uint32(0), jnp.uint32(0))
    assert int(mt.sum()) == 64
    assert int(mi.max()) == 0


def test_tcam_prefix_query_selects_aligned_range():
    """Prefix query with p don't-care low bits matches exactly the
    2^p-aligned block containing the query (paper Fig 6c)."""
    rows = jnp.arange(256, dtype=jnp.uint32)
    care = jnp.full((256,), 0xFFFFFFFF, jnp.uint32)
    q = jnp.uint32(0b10100000)  # 160
    qc = jnp.uint32(0xFFFFFFF0)  # low 4 bits don't-care
    mt, _ = tcam_match.tcam_search(rows, care, q, qc)
    matched = np.nonzero(np.asarray(mt))[0]
    np.testing.assert_array_equal(matched, np.arange(160, 176))


def test_tcam_stored_dont_care_cells():
    """Stored 'x' cells must match any query bit (TCAM ternary semantics)."""
    rows = jnp.asarray([0b1010, 0b1010], jnp.uint32)
    care = jnp.asarray([0xFFFFFFFF, 0xFFFFFFF0], jnp.uint32)  # row1 low4 = x
    q = jnp.uint32(0b1111)
    qc = jnp.uint32(0xFFFFFFFF)
    mt, mi = tcam_match.tcam_search(rows, care, q, qc)
    assert list(np.asarray(mt)) == [0, 1]
    assert int(mi[0]) > 0 and int(mi[1]) == 0
