"""AOT round-trip sanity: lowering produces parseable HLO text with the
expected entry signature, and the manifest describes it accurately."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_roundtrip_numerics():
    """Lower a tiny jitted fn and re-execute the HLO text through
    xla_client — the same path the Rust runtime uses."""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text and "f32[2,2]" in text


@pytest.mark.parametrize("env", ["cartpole", "acrobot"])
def test_lowered_train_entry_shapes(env, tmp_path):
    spec = model.ENV_SPECS[env]
    manifest = {"envs": {}}
    aot.lower_env(spec, str(tmp_path), manifest)
    text = open(tmp_path / f"{env}_train.hlo.txt").read()
    assert "ENTRY" in text
    b = spec.batch
    # batch inputs appear in the entry computation signature
    assert f"f32[{b},{spec.obs_dim}]" in text
    assert f"s32[{b}]" in text
    ent = manifest["envs"][env]
    assert len(ent["train_inputs"]) == 31
    assert ent["train_inputs"][25]["shape"] == [b, spec.obs_dim]
    assert ent["train_inputs"][26]["dtype"] == "int32"
    assert ent["dims"] == spec.dims


def test_manifest_written(tmp_path):
    manifest = {"version": 1, "envs": {}}
    aot.lower_env(model.ENV_SPECS["mountaincar"], str(tmp_path), manifest)
    aot.lower_tcam(str(tmp_path), manifest)
    path = tmp_path / "manifest.json"
    path.write_text(json.dumps(manifest))
    loaded = json.loads(path.read_text())
    assert loaded["tcam"]["n_rows"] == aot.TCAM_ROWS
    assert loaded["tcam"]["rows_per_array"] == 64
    assert (tmp_path / loaded["tcam"]["artifact"]).exists()


def test_repo_artifacts_exist_and_match_manifest():
    """`make artifacts` output is consistent (skips if not yet built)."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    for name, ent in manifest["envs"].items():
        for key in ("train_artifact", "act_artifact"):
            p = os.path.join(art, ent[key])
            assert os.path.exists(p), p
            head = open(p).read(4096)
            assert "ENTRY" in head or "HloModule" in head
        spec = model.ENV_SPECS[name]
        assert ent["dims"] == spec.dims
        assert ent["batch"] == spec.batch
