"""AOT driver: lower every L2 graph to HLO *text* artifacts for the Rust
runtime, plus a manifest.json describing shapes/orders.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the `xla` 0.1.6 crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--envs a,b]

Python runs ONCE at build time (make artifacts); the Rust binary is
self-contained afterwards.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

TCAM_ROWS = 8192  # 128 arrays x 64 rows, the paper's ER-8192 example


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_env(spec: model.EnvSpec, out_dir: str, manifest: dict) -> None:
    train = model.make_train_step(spec)
    act = model.make_act(spec)
    train_shapes = model.train_example_shapes(spec)
    act_shapes = model.act_example_shapes(spec, batch=1)

    train_path = os.path.join(out_dir, f"{spec.name}_train.hlo.txt")
    act_path = os.path.join(out_dir, f"{spec.name}_act.hlo.txt")

    lowered = jax.jit(train).lower(*train_shapes)
    open(train_path, "w").write(to_hlo_text(lowered))
    lowered = jax.jit(act).lower(*act_shapes)
    open(act_path, "w").write(to_hlo_text(lowered))

    manifest["envs"][spec.name] = {
        "obs_dim": spec.obs_dim,
        "n_actions": spec.n_actions,
        "hidden": spec.hidden,
        "batch": spec.batch,
        "gamma": spec.gamma,
        "lr": spec.lr,
        "double_dqn": spec.double_dqn,
        "dims": spec.dims,
        "train_artifact": os.path.basename(train_path),
        "act_artifact": os.path.basename(act_path),
        "train_inputs": [_shape_entry(s) for s in train_shapes],
        "act_inputs": [_shape_entry(s) for s in act_shapes],
    }
    print(f"  lowered {spec.name}: {train_path}, {act_path}")


def lower_tcam(out_dir: str, manifest: dict) -> None:
    search = model.make_tcam_search(TCAM_ROWS)
    shapes = model.tcam_example_shapes(TCAM_ROWS)
    path = os.path.join(out_dir, f"tcam_search_{TCAM_ROWS}.hlo.txt")
    lowered = jax.jit(search).lower(*shapes)
    open(path, "w").write(to_hlo_text(lowered))
    manifest["tcam"] = {
        "n_rows": TCAM_ROWS,
        "rows_per_array": 64,
        "artifact": os.path.basename(path),
        "inputs": [_shape_entry(s) for s in shapes],
    }
    print(f"  lowered tcam_search: {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: path of the primary artifact; implies "
                         "--out-dir $(dirname path)")
    ap.add_argument("--envs", default="cartpole,acrobot,lunarlander,"
                                      "mountaincar,pongproxy")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "envs": {}}
    for name in args.envs.split(","):
        name = name.strip()
        if not name:
            continue
        lower_env(model.ENV_SPECS[name], out_dir, manifest)
    lower_tcam(out_dir, manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # compat sentinel for the Makefile's single-file dependency
    sentinel = os.path.join(out_dir, "model.hlo.txt")
    if not os.path.exists(sentinel):
        with open(os.path.join(out_dir, "cartpole_train.hlo.txt")) as src:
            open(sentinel, "w").write(src.read())
    print(f"wrote manifest -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
