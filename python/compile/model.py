"""L2: the DQN compute graph (forward + backward + Adam), built on the L1
Pallas kernels, AOT-lowered per environment by aot.py.

Design (DESIGN.md §2, §7):
  * one PJRT call == one full training step: Q forward on (s, s'),
    (double-)DQN TD target, importance-weighted Huber loss, full backward,
    Adam update — all inside a single lowered HLO module. Rust feeds flat
    literal lists and gets flat literal lists back; Python is never on the
    request path.
  * Pallas kernels are not auto-differentiable, so `dense` and `td_huber`
    carry custom_vjp rules whose backward passes are themselves calls into
    the same Pallas matmul kernel (dx = g @ W^T, dW = x^T g).

Parameter layout (flat, fixed order — mirrored by rust/src/runtime):
  train inputs : w0 b0 w1 b1 w2 b2 | tw0 tb0 tw1 tb1 tw2 tb2
                 | m0..m5 | v0..v5 | t
                 | obs actions rewards next_obs dones is_weights
  train outputs: w0'..b2' | m0'..m5' | v0'..v5' | t' | td | loss
  act inputs   : w0 b0 w1 b1 w2 b2 | obs
  act outputs  : actions(int32) | qvals
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import qnet, td as td_kernel
from .kernels import ref

N_LAYERS = 3  # fixed 3-layer MLP per the paper (Mnih et al. architecture)


@dataclass(frozen=True)
class EnvSpec:
    """Static network/workload description for one environment."""
    name: str
    obs_dim: int
    n_actions: int
    hidden: int = 128
    batch: int = 64
    gamma: float = 0.99
    lr: float = 1e-3
    double_dqn: bool = True

    @property
    def dims(self):
        return [self.obs_dim, self.hidden, self.hidden, self.n_actions]


# The paper's evaluation environments (Fig 8 / Table 1) + the Fig 4
# Pong-proxy (DESIGN.md §4 substitution: large MLP instead of ALE CNN).
ENV_SPECS = {
    "cartpole": EnvSpec("cartpole", obs_dim=4, n_actions=2),
    "acrobot": EnvSpec("acrobot", obs_dim=6, n_actions=3),
    "lunarlander": EnvSpec("lunarlander", obs_dim=8, n_actions=4),
    "mountaincar": EnvSpec("mountaincar", obs_dim=2, n_actions=3),
    "pongproxy": EnvSpec("pongproxy", obs_dim=6400, n_actions=6, hidden=512,
                         batch=32),
}


# ---------------------------------------------------------------------------
# Differentiable Pallas building blocks
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_vjp(x, w, b, relu):
    return qnet.dense(x, w, b, relu=relu)


def _dense_fwd(x, w, b, relu):
    y = qnet.dense(x, w, b, relu=relu)
    return y, (x, w, y)


def _dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)
    zb_in = jnp.zeros((x.shape[1],), g.dtype)   # dx accumulates over N
    zb_w = jnp.zeros((w.shape[1],), g.dtype)    # dw accumulates over M
    dx = qnet.dense(g, w.T, zb_in, relu=False)
    dw = qnet.dense(x.T, g, zb_w, relu=False)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense_vjp.defvjp(_dense_fwd, _dense_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def td_huber_vjp(q_sa, target_max_q, reward, done, is_weights, gamma, delta):
    return td_kernel.td_huber(q_sa, target_max_q, reward, done, is_weights,
                              gamma=gamma, delta=delta)


def _td_fwd(q_sa, target_max_q, reward, done, is_weights, gamma, delta):
    td, elems = td_kernel.td_huber(q_sa, target_max_q, reward, done,
                                   is_weights, gamma=gamma, delta=delta)
    return (td, elems), (td, is_weights)


def _td_bwd(gamma, delta, res, cotangents):
    td, is_weights = res
    _, g_elems = cotangents  # td output feeds priorities only (no grad path)
    # d elem / d q_sa = w * huber'(td) * d td/d q_sa = -w * clip(td, ±delta)
    g_q = g_elems * is_weights * (-jnp.clip(td, -delta, delta))
    zeros = jnp.zeros_like(td)
    return g_q, zeros, zeros, zeros, zeros


td_huber_vjp.defvjp(_td_fwd, _td_bwd)


def mlp_forward(params, x):
    """params = [w0, b0, w1, b1, w2, b2]; ReLU on hidden, linear head."""
    h = x
    for i in range(N_LAYERS):
        w, b = params[2 * i], params[2 * i + 1]
        h = dense_vjp(h, w, b, i != N_LAYERS - 1)
    return h


# ---------------------------------------------------------------------------
# Training / acting graphs
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def make_train_step(spec: EnvSpec):
    """Return train_step(flat_inputs...) -> flat_outputs tuple."""

    def loss_fn(params, target_params, obs, actions, rewards, next_obs,
                dones, is_weights):
        q = mlp_forward(params, obs)                       # (B, A)
        q_sa = jnp.take_along_axis(q, actions[:, None], axis=1)[:, 0]
        tq = mlp_forward(target_params, next_obs)          # (B, A)
        if spec.double_dqn:
            # Double DQN: argmax from the online net, value from the target.
            nq = mlp_forward(params, next_obs)
            next_a = jnp.argmax(nq, axis=1)
            tmax = jnp.take_along_axis(tq, next_a[:, None], axis=1)[:, 0]
        else:
            tmax = jnp.max(tq, axis=1)
        tmax = jax.lax.stop_gradient(tmax)
        td, elems = td_huber_vjp(q_sa, tmax, rewards, dones, is_weights,
                                 spec.gamma, 1.0)
        return jnp.mean(elems), td

    def train_step(*flat):
        p = list(flat)
        params = p[0:6]
        target_params = p[6:12]
        m_state = p[12:18]
        v_state = p[18:24]
        t = p[24]
        obs, actions, rewards, next_obs, dones, is_weights = p[25:31]

        (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, target_params, obs, actions, rewards, next_obs, dones,
            is_weights)

        t_new = t + 1.0
        # bias-corrected Adam, lr fixed at trace time
        b1t = ADAM_B1 ** t_new
        b2t = ADAM_B2 ** t_new
        new_params, new_m, new_v = [], [], []
        for pi, gi, mi, vi in zip(params, grads, m_state, v_state):
            mi2 = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
            vi2 = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
            mhat = mi2 / (1.0 - b1t)
            vhat = vi2 / (1.0 - b2t)
            new_params.append(pi - spec.lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
            new_m.append(mi2)
            new_v.append(vi2)
        return tuple(new_params + new_m + new_v + [t_new, td, loss])

    return train_step


def make_act(spec: EnvSpec):
    """Return act(w0..b2, obs) -> (argmax actions int32, qvals)."""

    def act(*flat):
        params = list(flat[0:6])
        obs = flat[6]
        q = mlp_forward(params, obs)
        return jnp.argmax(q, axis=1).astype(jnp.int32), q

    return act


def make_tcam_search(n_rows: int, rows_per_array: int = 64):
    """AM search graph (hw-codesign cross-validation artifact)."""
    from .kernels import tcam_match

    def search(rows, care, query, qcare):
        return tcam_match.tcam_search(rows, care, query, qcare,
                                      rows_per_array=rows_per_array)

    return search


# ---------------------------------------------------------------------------
# Example-args builders (shapes for AOT lowering + the Rust manifest)
# ---------------------------------------------------------------------------

def init_params(spec: EnvSpec, seed: int = 0):
    """He-init MLP parameters (also used by Rust via the params artifact)."""
    key = jax.random.PRNGKey(seed)
    params = []
    dims = spec.dims
    for i in range(N_LAYERS):
        key, k1 = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        params.append(jax.random.normal(k1, (dims[i], dims[i + 1]),
                                        jnp.float32) * scale)
        params.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return params


def train_example_shapes(spec: EnvSpec):
    dims = spec.dims
    f32 = jnp.float32
    shapes = []
    pshapes = []
    for i in range(N_LAYERS):
        pshapes.append(((dims[i], dims[i + 1]), f32))
        pshapes.append(((dims[i + 1],), f32))
    shapes += pshapes          # online params
    shapes += pshapes          # target params
    shapes += pshapes          # adam m
    shapes += pshapes          # adam v
    shapes.append(((), f32))   # t
    b = spec.batch
    shapes.append(((b, spec.obs_dim), f32))    # obs
    shapes.append(((b,), jnp.int32))           # actions
    shapes.append(((b,), f32))                 # rewards
    shapes.append(((b, spec.obs_dim), f32))    # next_obs
    shapes.append(((b,), f32))                 # dones
    shapes.append(((b,), f32))                 # is_weights
    return [jax.ShapeDtypeStruct(s, d) for s, d in shapes]


def act_example_shapes(spec: EnvSpec, batch: int = 1):
    dims = spec.dims
    f32 = jnp.float32
    shapes = []
    for i in range(N_LAYERS):
        shapes.append(((dims[i], dims[i + 1]), f32))
        shapes.append(((dims[i + 1],), f32))
    shapes.append(((batch, spec.obs_dim), f32))
    return [jax.ShapeDtypeStruct(s, d) for s, d in shapes]


def tcam_example_shapes(n_rows: int):
    u32 = jnp.uint32
    return [
        jax.ShapeDtypeStruct((n_rows,), u32),
        jax.ShapeDtypeStruct((n_rows,), u32),
        jax.ShapeDtypeStruct((1,), u32),
        jax.ShapeDtypeStruct((1,), u32),
    ]
