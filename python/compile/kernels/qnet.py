"""L1 Pallas kernels: fused dense layers for the DQN Q-network.

The hot compute of the DQN agent (paper Fig 4 "train"/"action" phases) is
the MLP forward/backward. Here the forward building block is a fused
``dense -> bias -> (ReLU)`` Pallas kernel with an explicit K-loop
accumulator, tiled so each block fits VMEM.

Hardware adaptation (DESIGN.md §3): the paper's compute fabric for the
network is a GPU; on TPU we tile for VMEM and feed the MXU with
(bm, bk) x (bk, bn) blocks. Block sizes default to MXU-friendly 128x128
(shrunk to the padded problem size when smaller).

All kernels are lowered with interpret=True — CPU PJRT cannot run Mosaic
custom-calls; on real TPU the same BlockSpecs drive the HBM->VMEM schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _vmem_scratch(shape, dtype):
    """Portable scratch allocation (VMEM on TPU, plain buffer in interpret)."""
    return pl.MemoryRef(jax.core.ShapedArray(shape, dtype), pl.MemorySpace.ANY)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, relu: bool):
    """Grid = (M/bm, N/bn, K/bk); accumulate over the k axis in VMEM scratch.

    The k axis is the innermost grid dimension, so for a fixed (i, j) output
    block the accumulator persists across the K-loop (standard Pallas matmul
    schedule; on TPU the grid is executed sequentially with revisiting).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] + b_ref[...]
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn", "bk", "interpret"))
def dense(x, w, b, *, relu: bool = False, bm: int = 128, bn: int = 128,
          bk: int = 128, interpret: bool = True):
    """Fused ``relu?(x @ w + b)`` via a tiled Pallas matmul.

    Shapes: x (M, K), w (K, N), b (N,). Inputs are zero-padded up to block
    multiples (zero padding is exact for matmul + bias) and the output is
    sliced back to (M, N).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)

    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)

    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_dense_kernel, n_k=n_k, relu=relu),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def mlp_forward(x, weights, biases, *, interpret: bool = True):
    """Q-network forward: chain of fused dense kernels, ReLU on hidden layers."""
    h = x
    last = len(weights) - 1
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = dense(h, w, b, relu=(i != last), interpret=interpret)
    return h
