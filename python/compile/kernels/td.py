"""L1 Pallas kernel: fused TD-error + importance-weighted Huber elements.

One elementwise pass over the batch computes, per transition,
  target   = r + gamma * (1 - done) * max_a' Q_target(s', a')
  td       = target - Q(s, a)
  elem     = w_is * huber(td)
and emits both the td vector (fed back to the replay memory as the new
priority, paper §2.1) and the weighted Huber elements (mean-reduced by the
caller into the scalar loss). Fusing these avoids materializing the target
vector in HBM between ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _td_kernel(q_sa_ref, tmax_ref, r_ref, done_ref, w_ref, td_ref, elem_ref,
               *, gamma: float, delta: float):
    q_sa = q_sa_ref[...]
    target = r_ref[...] + gamma * (1.0 - done_ref[...]) * tmax_ref[...]
    td = target - q_sa
    a = jnp.abs(td)
    huber = jnp.where(a <= delta, 0.5 * td * td, delta * (a - 0.5 * delta))
    td_ref[...] = td
    elem_ref[...] = w_ref[...] * huber


@functools.partial(jax.jit, static_argnames=("gamma", "delta", "interpret"))
def td_huber(q_sa, target_max_q, reward, done, is_weights, *,
             gamma: float = 0.99, delta: float = 1.0, interpret: bool = True):
    """Fused TD error + weighted Huber elements.

    All inputs are (batch,) f32. Returns (td, elems), both (batch,).
    The batch is processed as a single VMEM block: DQN batches (64) are far
    below VPU tile limits, so no grid is needed.
    """
    (b,) = q_sa.shape
    spec = pl.BlockSpec((b,), lambda: (0,))
    td, elems = pl.pallas_call(
        functools.partial(_td_kernel, gamma=gamma, delta=delta),
        grid=(),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(q_sa, target_max_q, reward, done, is_weights)
    return td, elems
