"""L1 Pallas kernel: bit-parallel ternary CAM match (the AM search).

Hardware adaptation (DESIGN.md §3): a TCAM row evaluates
``matchline_i = NOR_j mismatch(C_ij, q_j)`` across all rows in O(1). On a
vector unit the same evaluation is one XNOR+mask word op per row:

    mismatch_word = (row ^ query) & care(row) & care(query)
    match_i       = mismatch_word == 0            (exact-match sensing)
    #mismatch_i   = popcount(mismatch_word)       (best-match sensing)

Each 64x64 TCAM array of the paper stores 64 INT-32 priorities (one per
row); a grid step of this kernel processes one array's worth of rows, so
the Pallas grid dimension plays the role of the paper's parallel TCAM
array bank (Fig 6a).

Priorities are packed u32 words; don't-care bits come from the prefix-based
query strategy (Fig 6b2). uint32 ops only — exact bit semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _match_kernel(rows_ref, rcare_ref, q_ref, qcare_ref, match_ref, mis_ref):
    rows = rows_ref[...]
    rcare = rcare_ref[...]
    q = q_ref[0]
    qc = qcare_ref[0]
    both = rcare & qc
    diff = (rows ^ q) & both
    match_ref[...] = (diff == 0).astype(jnp.uint32)
    mis_ref[...] = ref.popcount_u32(diff).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("rows_per_array", "interpret"))
def tcam_search(rows, care_masks, query, query_care, *,
                rows_per_array: int = 64, interpret: bool = True):
    """Search every TCAM array in the bank for `query` (with don't-cares).

    Args:
      rows: (n,) uint32 stored priority words (n padded to rows_per_array).
      care_masks: (n,) uint32 stored-cell care bits ('x' cells are 0).
      query: () or (1,) uint32 query word.
      query_care: same shape, query care bits (prefix mask).
    Returns:
      (match, mismatches): (n,) uint32 {0,1} matchlines and (n,) uint32
      per-row mismatch-cell counts.
    """
    n = rows.shape[0]
    rpa = min(rows_per_array, n)
    assert n % rpa == 0, (n, rpa)
    q = jnp.asarray(query, jnp.uint32).reshape(1)
    qc = jnp.asarray(query_care, jnp.uint32).reshape(1)
    row_spec = pl.BlockSpec((rpa,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    match, mis = pl.pallas_call(
        _match_kernel,
        grid=(n // rpa,),
        in_specs=[row_spec, row_spec, scalar_spec, scalar_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=interpret,
    )(rows.astype(jnp.uint32), care_masks.astype(jnp.uint32), q, qc)
    return match, mis
