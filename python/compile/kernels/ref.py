"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an oracle here; pytest asserts
allclose between kernel and oracle across shape/dtype sweeps. The oracles
are also used directly by model.py when a layer is too small to benefit
from a custom kernel (the kernels and oracles are interchangeable by
construction).
"""
from __future__ import annotations

import jax.numpy as jnp


def mlp_forward_ref(x, weights, biases):
    """Plain MLP forward: ReLU on all hidden layers, linear head.

    Args:
      x: (batch, in_dim) activations.
      weights: list of (d_i, d_{i+1}) matrices.
      biases: list of (d_{i+1},) vectors.
    Returns:
      (batch, out_dim) Q-values.
    """
    h = x
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = h @ w + b
        if i != len(weights) - 1:
            h = jnp.maximum(h, 0.0)
    return h


def dense_relu_ref(x, w, b):
    """Single fused dense+ReLU layer (hidden-layer building block)."""
    return jnp.maximum(x @ w + b, 0.0)


def dense_ref(x, w, b):
    """Single dense layer, no activation (output head)."""
    return x @ w + b


def td_error_ref(q_sa, target_max_q, reward, done, gamma):
    """One-step TD error: r + gamma * (1-done) * max_a' Q_target(s',a') - Q(s,a)."""
    target = reward + gamma * (1.0 - done) * target_max_q
    return target - q_sa


def weighted_huber_ref(td, is_weights, delta=1.0):
    """Importance-weighted Huber loss (PER's loss), mean-reduced.

    huber(x) = 0.5 x^2            for |x| <= delta
             = delta(|x| - .5d)   otherwise
    """
    a = jnp.abs(td)
    huber = jnp.where(a <= delta, 0.5 * td * td, delta * (a - 0.5 * delta))
    return jnp.mean(is_weights * huber)


def tcam_match_ref(rows, care_masks, query, query_care):
    """Ternary exact-match: row i matches iff all cared bit positions agree.

    Bit-packed u32 semantics (each TCAM row stores one INT-32 priority as a
    packed u32 word):
      rows:       (n,) uint32 stored words
      care_masks: (n,) uint32, 1 = stored bit is specified, 0 = stored 'x'
      query:      ()   uint32 query word
      query_care: ()   uint32, 1 = query bit specified, 0 = query 'x'
    A cell mismatches iff both sides care and the bits differ. The row
    matchline is the OR of cell mismatches (paper Fig 3), i.e. match when
    the OR is 0.
    Returns (n,) bool match vector (the matchlines).
    """
    both_care = care_masks & query_care
    diff = (rows ^ query) & both_care
    return diff == 0


def popcount_u32(x):
    """Vectorized 32-bit popcount (SWAR)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24) & jnp.uint32(0xFF)


def mismatch_count_ref(rows, care_masks, query, query_care):
    """Per-row number of mismatching cells (best-match sensing input)."""
    both_care = care_masks & query_care
    diff = (rows ^ query) & both_care
    return popcount_u32(diff)
