#!/usr/bin/env bash
# Tier-1 gate: one command = the whole merge bar.
#   build (release) + test + fault-injection suite + formatting check.
# Run from anywhere; operates on the repository root.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q (engine pool: 1 thread, the deterministic default) =="
cargo test -q

echo "== tier-1: engine-parallelism suites at the machine's core count =="
# AMPER_ENGINE_THREADS=0 sizes every default-constructed engine pool to
# available_parallelism; the kernels are bit-identical at any worker
# count, so the same suites must pass unchanged
AMPER_ENGINE_THREADS=0 cargo test -q -p amper --test batch_equivalence
AMPER_ENGINE_THREADS=0 cargo test -q -p amper --lib runtime::

echo "== tier-1: fault-injection suite incl. net scenarios (--features testing) =="
cargo test -q -p amper --features testing --test fault_injection

echo "== tier-1: wire roundtrips + remote loopback bit-identity =="
# both run inside `cargo test -q` above; the explicit invocations keep
# the remote-tier contract visible as its own gate line
cargo test -q -p amper --test properties prop_wire
cargo test -q -p amper --test batch_equivalence remote_single_learner

echo "== tier-1: interplay study smoke (every registered technique x env) =="
# exercises the registry end to end through the CLI: all techniques on
# all built-in envs at a CI-sized horizon, artifact written and parsed
cargo run --release -q -- study interplay --smoke --out /tmp/STUDY_interplay.json

echo "== tier-1: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt not installed — skipping format check)"
fi

echo "== tier-1: cargo clippy --all-targets -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    # --all-targets lints the whole workspace — lib, bin, tests, benches
    # and examples — so CI and local runs gate the same code; the
    # `testing` feature pulls the fault-injection surface into the lint
    cargo clippy -q --all-targets -p amper --features testing -- -D warnings
else
    echo "(clippy not installed — skipping lint)"
fi

echo "tier-1 OK"
