#!/usr/bin/env python3
"""Gate the zero-copy gathered-reply path on replay_micro results.

Usage: bench_check.py [--write-baseline] CURRENT.json [BASELINE.json]

Three checks, all machine-speed independent:

1. Intra-run: the pooled + pipelined gathered path must not be slower
   than the allocating synchronous path measured in the *same* run
   (tolerance below). This is the hard gate — the zero-copy protocol
   exists to beat the PR-4 reply path, so losing to it is a regression
   no matter how fast the runner is.

2. Intra-run: batched actor inference must beat the scalar act loop at
   vec sizes >= 32 (the snapshot-driven actor's one-forward-per-tick
   claim). Skipped with a notice when the act cases are absent (older
   bench artifacts).

3. Intra-run: the wire tax of the loopback replay tier (NetServer +
   RemoteReplayClient on 127.0.0.1) must stay under a fixed multiple of
   the same-run in-process gathered path. The bound is generous — the
   wire legitimately costs framing + syscalls + a socket round trip —
   but a transport regression (lost TCP_NODELAY means ~40ms stalls,
   per-row encoding creep) lands orders of magnitude above it. Skipped
   with a notice when the net cases are absent (older artifacts).

4. Intra-run: the worker-pool train step must beat the single-threaded
   engine at batch 128 — the best of threads {2, 4} against threads 1
   from the same run (partial parallelism on throttled 2-vCPU smoke
   runners is tolerated via a small jitter margin; a clear loss means
   the pool dispatch overhead swamped the kernels). Skipped with a
   notice when the train cases are absent (older artifacts).

5. Intra-run: the chunked batch passes must not lose to their scalar
   twins measured in the same run — the integer-key CSP build vs the
   float-comparator sort, and the chunked sum-tree batch refresh vs 64
   per-leaf root-ward walks. Both pairs are bit-identical by
   construction (batch_equivalence pins that), so slower means the
   restructuring stopped paying for itself. Skipped with a notice when
   the cases are absent (older artifacts).

6. Intra-run: the registry techniques' batch-first memory overrides
   (dpsr, dual, pper) must not lose to their scalar-loop twins at
   batch 128 — same bit-identical-by-construction argument as check 5
   (batch_equivalence pins state identity, so only speed is gated
   here). Skipped with a notice when the cases are absent (older
   artifacts predating the technique registry).

7. Against the in-repo baseline (optional file): the *ratio*
   pooled/alloc is compared between the current run and the baseline
   run. Normalizing by the same-run alloc case cancels the runner's
   absolute speed, so a committed baseline from any machine remains a
   valid reference. Fails if the current ratio regresses by more than
   REL_TOLERANCE (25%). If the baseline file is missing (not yet seeded
   from a CI artifact), this check is skipped with a notice.

With --write-baseline, a run that passes every check refreshes
bench/baseline_replay_micro.json in place (the seeding procedure from
bench/README.md: download a green CI artifact, then run this with the
flag instead of hand-copying).

The improvement headline (acceptance: >=20% at batch 128 x 4 shards) is
printed either way.
"""

import json
import pathlib
import shutil
import sys

KEY_ALLOC = "svc/gathered/sync-alloc/shards4/batch128"
KEY_POOLED = "svc/gathered/pipelined-pooled/shards4/batch128"
ACT_VECS = (32, 128)
# the pooled path may not lose to the allocating path. The margin is
# sized for CI smoke runs (15 samples x 2 iters on shared 2-vCPU
# runners): scheduler jitter across the 4 shard workers can swing a
# single case several percent, so only a clear loss fails the gate —
# a real regression of the zero-copy protocol shows up far above this.
INTRA_TOLERANCE = 1.15
# allowed regression of pooled/alloc vs the committed baseline ratio
REL_TOLERANCE = 1.25
# bound on loopback/inproc for the gathered workload at each swept
# batch size: same-run normalization cancels machine speed, and real
# transport bugs (Nagle stalls, per-row frames) sit far above 30x
NET_VECS = (32, 128)
NET_TOLERANCE = 30.0
# the best multi-threaded train step may trail threads=1 by at most this
# factor at batch 128 (smoke-runner jitter); at or above it the pool is
# a regression, below 1.0 it is the expected win
TRAIN_TOLERANCE = 1.05
# chunked-vs-scalar batch passes (integer-key CSP build, sum-tree batch
# refresh): same-run ratio must stay under this
CHUNK_TOLERANCE = 1.10
# registry techniques with amortized batch-first overrides: at the
# largest swept batch the batched path may not lose to the scalar loops
MEM_TECHS = ("dpsr", "dual", "pper")
MEM_BATCH = 128
MEM_TOLERANCE = 1.10
# the committed baseline this run refreshes under --write-baseline
BASELINE_PATH = (
    pathlib.Path(__file__).resolve().parent.parent
    / "bench"
    / "baseline_replay_micro.json"
)


def load_cases(path):
    with open(path) as f:
        doc = json.load(f)
    return {c["name"]: c["mean_ns"] for c in doc["cases"]}


def main(argv):
    args = list(argv[1:])
    write_baseline = "--write-baseline" in args
    if write_baseline:
        args.remove("--write-baseline")
    if not args:
        print(__doc__)
        return 2
    current = load_cases(args[0])
    for key in (KEY_ALLOC, KEY_POOLED):
        if key not in current:
            print(f"FAIL: case '{key}' missing from {args[0]}")
            return 1
    alloc = current[KEY_ALLOC]
    pooled = current[KEY_POOLED]
    ratio = pooled / alloc
    improvement = 100.0 * (1.0 - ratio)
    print(
        f"gathered batch128 x 4 shards: sync-alloc {alloc:.0f} ns -> "
        f"pipelined-pooled {pooled:.0f} ns ({improvement:+.1f}% latency "
        f"improvement, ratio {ratio:.3f})"
    )

    failed = False
    if ratio > INTRA_TOLERANCE:
        print(
            f"FAIL: pooled+pipelined path is slower than the allocating "
            f"sync path (ratio {ratio:.3f} > {INTRA_TOLERANCE})"
        )
        failed = True
    if improvement < 20.0:
        # the acceptance target; report loudly but let the baseline
        # ratio check below decide hard failure on noisy smoke runs
        print(
            f"WARN: improvement {improvement:.1f}% is below the 20% "
            f"acceptance target"
        )

    # batched actor inference: one forward per vec-env tick must beat
    # the scalar act loop once the row count amortizes the weight reads
    for vec in ACT_VECS:
        scalar_key = f"act/scalar/vec{vec}"
        batched_key = f"act/batched/vec{vec}"
        if scalar_key not in current or batched_key not in current:
            print(f"NOTE: act cases for vec{vec} absent; skipping act gate")
            continue
        scalar = current[scalar_key]
        batched = current[batched_key]
        speedup = scalar / batched
        print(
            f"act vec{vec}: scalar-loop {scalar:.0f} ns -> batched "
            f"{batched:.0f} ns ({speedup:.2f}x)"
        )
        if batched > scalar:
            print(
                f"FAIL: batched act is slower than the scalar loop at "
                f"vec{vec} ({batched:.0f} ns > {scalar:.0f} ns)"
            )
            failed = True

    # the loopback replay tier: the wire tax is bounded, not forbidden
    for batch in NET_VECS:
        inproc_key = f"net/inproc/batch{batch}"
        loopback_key = f"net/loopback/batch{batch}"
        if inproc_key not in current or loopback_key not in current:
            print(f"NOTE: net cases for batch{batch} absent; skipping net gate")
            continue
        inproc = current[inproc_key]
        loopback = current[loopback_key]
        tax = loopback / inproc
        print(
            f"net batch{batch}: in-process {inproc:.0f} ns -> loopback "
            f"{loopback:.0f} ns ({tax:.2f}x wire tax)"
        )
        if tax > NET_TOLERANCE:
            print(
                f"FAIL: loopback wire tax {tax:.2f}x exceeds the "
                f"{NET_TOLERANCE:.0f}x bound at batch{batch} — transport "
                f"regression (frame coalescing or TCP_NODELAY lost?)"
            )
            failed = True

    # worker-pool train step: the pool must pay for itself at batch 128
    single_key = "train/threads1/batch128"
    multi_keys = [f"train/threads{t}/batch128" for t in (2, 4)]
    if single_key not in current or all(k not in current for k in multi_keys):
        print("NOTE: train/threads cases absent; skipping train gate")
    else:
        single = current[single_key]
        best_key, best = min(
            ((k, current[k]) for k in multi_keys if k in current),
            key=lambda kv: kv[1],
        )
        ratio_t = best / single
        print(
            f"train batch128: threads1 {single:.0f} ns -> best "
            f"{best_key.split('/')[1]} {best:.0f} ns ({single / best:.2f}x)"
        )
        if ratio_t >= TRAIN_TOLERANCE:
            print(
                f"FAIL: multi-threaded train step loses to threads=1 at "
                f"batch 128 (ratio {ratio_t:.3f} >= {TRAIN_TOLERANCE}) — "
                f"pool dispatch overhead exceeds the kernel win"
            )
            failed = True
        elif ratio_t >= 1.0:
            print(
                f"WARN: threaded train step not faster than threads=1 "
                f"(ratio {ratio_t:.3f}); within jitter margin, not failing"
            )

    # chunked batch passes vs their scalar twins (same run, same inputs)
    for scalar_key, chunked_key, label in (
        ("csp/build/sorted-f32/100k", "csp/build/sorted-key/100k", "csp build"),
        ("sum_tree/update64/scalar", "sum_tree/update64/chunked", "sum-tree update64"),
    ):
        if scalar_key not in current or chunked_key not in current:
            print(f"NOTE: {label} cases absent; skipping chunked gate")
            continue
        scalar = current[scalar_key]
        chunked = current[chunked_key]
        ratio_c = chunked / scalar
        print(
            f"{label}: scalar {scalar:.0f} ns -> chunked {chunked:.0f} ns "
            f"({scalar / chunked:.2f}x)"
        )
        if ratio_c > CHUNK_TOLERANCE:
            print(
                f"FAIL: chunked {label} is slower than the scalar twin "
                f"(ratio {ratio_c:.3f} > {CHUNK_TOLERANCE})"
            )
            failed = True

    # registry techniques: batched memory ops vs their scalar twins
    for tech in MEM_TECHS:
        scalar_key = f"mem/{tech}/scalar/batch{MEM_BATCH}: push+sample64+update"
        batched_key = f"mem/{tech}/batched/batch{MEM_BATCH}: push+sample64+update"
        if scalar_key not in current or batched_key not in current:
            print(f"NOTE: mem/{tech} cases absent; skipping mem gate")
            continue
        scalar = current[scalar_key]
        batched = current[batched_key]
        ratio_m = batched / scalar
        print(
            f"mem/{tech} batch{MEM_BATCH}: scalar {scalar:.0f} ns -> "
            f"batched {batched:.0f} ns ({scalar / batched:.2f}x)"
        )
        if ratio_m > MEM_TOLERANCE:
            print(
                f"FAIL: batched '{tech}' memory ops lose to the scalar "
                f"loops (ratio {ratio_m:.3f} > {MEM_TOLERANCE})"
            )
            failed = True

    if len(args) > 1:
        try:
            baseline = load_cases(args[1])
        except FileNotFoundError:
            print(
                f"NOTE: baseline {args[1]} not found — seed it by running "
                f"this script with --write-baseline on a green "
                f"BENCH_replay_micro.json CI artifact; skipping the "
                f"baseline regression check"
            )
            baseline = None
        if baseline is not None:
            if KEY_ALLOC in baseline and KEY_POOLED in baseline:
                base_ratio = baseline[KEY_POOLED] / baseline[KEY_ALLOC]
                print(f"baseline ratio {base_ratio:.3f}")
                if ratio > base_ratio * REL_TOLERANCE:
                    print(
                        f"FAIL: zero-copy path regressed >25% vs baseline "
                        f"({ratio:.3f} > {base_ratio:.3f} * {REL_TOLERANCE})"
                    )
                    failed = True
            else:
                print("NOTE: baseline lacks the gathered cases; skipping")

    if failed:
        return 1
    if write_baseline:
        BASELINE_PATH.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(args[0], BASELINE_PATH)
        print(f"baseline refreshed -> {BASELINE_PATH}")
    print("bench check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
