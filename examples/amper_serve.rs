//! Coordinator demo: the replay *service* under concurrent load — four
//! actor threads ingest CartPole transitions while a pipelined learner
//! thread drains gathered batches and feeds back priorities, exactly the
//! dataflow the AMPER accelerator serves in hardware (paper Fig 1).
//!
//! The learner keeps two requests in flight ([`GatherPipeline`]) and
//! recycles every consumed reply buffer, so steady-state batches cross
//! the service with zero fresh allocations (watch the pool-hit column).
//!
//! Run: `cargo run --release --example amper_serve [seconds]`

use std::sync::atomic::Ordering;

use amper::coordinator::{GatherPipeline, ReplayService, VectorEnvDriver};
use amper::replay::{self, ReplayKind};
use amper::util::Timer;

fn main() {
    let secs: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("seconds"))
        .unwrap_or(3);

    for kind in [ReplayKind::Per, ReplayKind::AmperFr] {
        let svc = ReplayService::spawn(replay::make(kind, 100_000), 4096, 0);
        // actors flush one 32-row PushBatch per 32 env steps (batch-first
        // ingest; pass 1 to reproduce the scalar one-command-per-step path)
        let driver = VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 7, 32);
        // double-buffered learner: request N+1 is in flight while the
        // TD feedback for batch N is computed
        let mut learner = GatherPipeline::new(svc.handle(), 64, 2);

        let t = Timer::start();
        let mut batches = 0u64;
        let mut batch_lat_ns = Vec::new();
        while t.elapsed().as_secs() < secs {
            let bt = Timer::start();
            let b = learner.next_batch().expect("gather failed");
            if b.is_empty() {
                learner.recycle(b);
                std::thread::yield_now();
                continue;
            }
            let td = vec![0.5; b.rows()];
            let _ = learner.feedback(&b, &td);
            learner.recycle(b);
            batch_lat_ns.push(bt.ns());
            batches += 1;
        }
        let steps = driver.stop();
        let h = svc.handle();
        let pushes = h.stats().pushes.load(Ordering::Relaxed);
        let pool_rate = h.reply_pool().stats().hit_rate_percent();
        let mem = svc.stop();
        let lat = amper::util::stats::Summary::of(&batch_lat_ns).unwrap();
        println!(
            "{:<9} | ingest {:>8} steps ({:>9.0}/s) | served {:>7} batches \
             ({:>7.0}/s) | batch p50 {} p99 {} | pool {pool_rate:.1}% hit | mem {}",
            kind.name(),
            steps,
            steps as f64 / secs as f64,
            batches,
            batches as f64 / secs as f64,
            amper::bench_harness::fmt_ns(lat.p50),
            amper::bench_harness::fmt_ns(lat.p99),
            mem.len(),
        );
        // the service's own per-stage histograms (what `amper serve`
        // reports and dumps as stats_json)
        let stage = |name: &str, hist: &amper::metrics::LatencyHistogram| {
            if hist.count() > 0 {
                println!(
                    "  stage {name:<13} p50 {} p99 {}",
                    amper::bench_harness::fmt_ns(hist.quantile_ns(0.5)),
                    amper::bench_harness::fmt_ns(hist.quantile_ns(0.99)),
                );
            }
        };
        let s = h.stats();
        stage("flush-accept", &s.stages.flush);
        stage("worker-gather", &s.stages.gather);
        stage("reply-merge", &s.stages.merge);
        assert_eq!(pushes, steps);
    }
}
