//! Coordinator demo: the replay *service* under concurrent load — four
//! batched actor envs ingest CartPole transitions while a pipelined
//! learner thread drains gathered batches, trains on them zero-copy, and
//! feeds back priorities, exactly the dataflow the AMPER accelerator
//! serves in hardware (paper Fig 1).
//!
//! The actors never touch the engine: they run ε-greedy over epoch-
//! versioned [`PolicySnapshot`]s that the learner publishes into a
//! [`SnapshotSlot`] every few train steps (the Ape-X actor/learner
//! hand-off), with one batched forward per vec-env tick. The learner
//! keeps two requests in flight ([`GatherPipeline`]) and recycles every
//! consumed reply buffer, so steady-state batches cross the service with
//! zero fresh allocations (watch the pool-hit column).
//!
//! Run: `cargo run --release --example amper_serve [seconds]`

use std::sync::atomic::Ordering;

use amper::coordinator::{
    FlushPolicy, GatherPipeline, PolicySnapshot, ReplayService, SnapshotSlot,
    VectorEnvDriver,
};
use amper::replay::{self, ReplayKind};
use amper::runtime::{Engine, EnvArtifacts, TrainScratch, TrainState};
use amper::util::Timer;

fn main() {
    let secs: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("seconds"))
        .unwrap_or(3);

    let engine = Engine::from_spec(EnvArtifacts::builtin("cartpole").unwrap());
    let batch = engine.spec().batch;
    let obs_dim = engine.spec().obs_dim;

    for kind in [ReplayKind::Per, ReplayKind::AmperFr] {
        let mut state = TrainState::init(engine.spec(), 0).unwrap();
        let svc = ReplayService::spawn(replay::make(kind, 100_000), 4096, 0);
        // the learner's epoch-0 snapshot seeds the slot; actor staleness
        // lands in the service stats alongside the pool counters
        let slot = SnapshotSlot::with_stats(
            PolicySnapshot::new(state.snapshot_params(), engine.spec().dims.clone(), 0)
                .unwrap(),
            svc.handle().stats().snapshot.clone(),
        );
        // actors flush one 32-row PushBatch per 32 env steps (batch-first
        // ingest) and act through the snapshot slot, never the engine
        let driver = VectorEnvDriver::spawn_snapshot(
            "cartpole",
            4,
            slot.clone(),
            svc.handle(),
            7,
            0.05,
            FlushPolicy::fixed(32),
        );
        // double-buffered learner: request N+1 is in flight while the
        // TD feedback for batch N is computed
        let mut learner = GatherPipeline::new(svc.handle(), batch, 2);
        let mut scratch = TrainScratch::default();

        let t = Timer::start();
        let mut batches = 0u64;
        let mut trained = 0u64;
        let mut batch_lat_ns = Vec::new();
        while t.elapsed().as_secs() < secs {
            let bt = Timer::start();
            let b = learner.next_batch().expect("gather failed");
            if b.is_empty() {
                learner.recycle(b);
                std::thread::yield_now();
                continue;
            }
            let n = b.rows();
            let td = if n == batch && b.obs.len() == n * obs_dim {
                let out = engine
                    .train_step_scratch(&mut state, (&b).into(), &mut scratch)
                    .expect("train step");
                trained += 1;
                if trained % 8 == 0 {
                    slot.publish(state.snapshot_params());
                }
                out.td
            } else {
                vec![0.5; n]
            };
            let _ = learner.feedback(&b, &td);
            learner.recycle(b);
            batch_lat_ns.push(bt.ns());
            batches += 1;
        }
        let steps = driver.stop();
        let h = svc.handle();
        let pushes = h.stats().pushes.load(Ordering::Relaxed);
        let pool_rate = h.reply_pool().stats().hit_rate_percent();
        let mem = svc.stop();
        let lat = amper::util::stats::Summary::of(&batch_lat_ns).unwrap();
        println!(
            "{:<9} | ingest {:>8} steps ({:>9.0}/s) | served {:>7} batches \
             ({:>7.0}/s, {trained} trained) | batch p50 {} p99 {} | pool \
             {pool_rate:.1}% hit | mem {}",
            kind.name(),
            steps,
            steps as f64 / secs as f64,
            batches,
            batches as f64 / secs as f64,
            amper::bench_harness::fmt_ns(lat.p50),
            amper::bench_harness::fmt_ns(lat.p99),
            mem.len(),
        );
        let snap = slot.stats();
        println!(
            "  snapshots: {} published (epoch {}), actor p99 staleness {} epochs \
             over {} reads",
            snap.publishes.load(Ordering::Relaxed),
            slot.epoch(),
            snap.behind.quantile_ns(0.99),
            snap.behind.count(),
        );
        // the service's own per-stage histograms (what `amper serve`
        // reports and dumps as stats_json)
        let stage = |name: &str, hist: &amper::metrics::LatencyHistogram| {
            if hist.count() > 0 {
                println!(
                    "  stage {name:<13} p50 {} p99 {}",
                    amper::bench_harness::fmt_ns(hist.quantile_ns(0.5)),
                    amper::bench_harness::fmt_ns(hist.quantile_ns(0.99)),
                );
            }
        };
        let s = h.stats();
        stage("flush-accept", &s.stages.flush);
        stage("worker-gather", &s.stages.gather);
        stage("reply-merge", &s.stages.merge);
        assert_eq!(pushes, steps);
    }
}
