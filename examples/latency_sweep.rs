//! Fig 9 driver: accelerator latency sweeps as a runnable example.
//! Prints Fig 9a (vs the paper's GPU reference and this host's measured
//! sum-tree PER), Fig 9b (group sweep) and Fig 9c (CSP-ratio sweep).
//!
//! Run: `cargo run --release --example latency_sweep`

use amper::bench_harness::fmt_ns;
use amper::hardware::gpu_model;
use amper::studies::fig9;

fn main() {
    println!("== Fig 9a: per-batch sampling latency (m=20, CSP ratio 0.15, batch 64) ==");
    let rows = fig9::fig9a(64, 1);
    for r in &rows {
        println!(
            "er={:<6} {:<18} {:>12}{}",
            r.er_size,
            r.variant,
            fmt_ns(r.latency_ns),
            if r.csp_len > 0 {
                format!("   (CSP {})", r.csp_len)
            } else {
                String::new()
            }
        );
    }
    for &size in &gpu_model::FIG9A_SIZES {
        let get = |v: &str| {
            rows.iter()
                .find(|r| r.er_size == size && r.variant == v)
                .unwrap()
                .latency_ns
        };
        println!(
            "er={size}: speedup vs GPU-PER  AMPER-k {:.0}x | AMPER-fr {:.0}x   \
             (paper bands: k 55-170x, fr 118-270x)",
            get("per-gpu(paper)") / get("amper-k"),
            get("per-gpu(paper)") / get("amper-fr"),
        );
    }

    println!("\n== Fig 9b: latency vs group number m (ER 10000, ratio 0.15) ==");
    for r in fig9::fig9b(64, 2) {
        println!(
            "m={:<3} {:<10} {:>12}  (CSP {})",
            r.m,
            r.variant,
            fmt_ns(r.latency_ns),
            r.csp_len
        );
    }

    println!("\n== Fig 9c: latency vs CSP ratio (ER 10000, m=20) ==");
    for r in fig9::fig9c(64, 3) {
        println!(
            "ratio={:<5} {:<10} {:>12}  (CSP {})",
            r.csp_ratio,
            r.variant,
            fmt_ns(r.latency_ns),
            r.csp_len
        );
    }
}
