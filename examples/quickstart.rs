//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. load the DQN engine (manifest-driven when `artifacts/` exists,
//!    built-in env specs otherwise),
//! 2. run one native train step from Rust,
//! 3. sample a batch with each replay technique,
//! 4. run one sampling operation on the simulated AMPER accelerator and
//!    print its Table-2-derived latency.
//!
//! Run: `cargo run --release --example quickstart`

use amper::hardware::accelerator::{AccelConfig, AmperAccelerator};
use amper::replay::amper::Variant;
use amper::replay::{self, Experience, ReplayKind};
use amper::runtime::{Engine, TrainBatch, TrainState};
use amper::util::error::Result;
use amper::util::Rng;

fn main() -> Result<()> {
    let mut rng = Rng::new(0);

    // --- 1. the compiled DQN --------------------------------------------
    let engine = Engine::load(std::path::Path::new("artifacts"), "cartpole")?;
    let spec = engine.spec().clone();
    println!(
        "loaded cartpole engine: MLP {:?}, batch {}",
        spec.dims, spec.batch
    );

    let mut state = TrainState::init(&spec, 42)?;
    let mut batch = TrainBatch::zeros(spec.batch, spec.obs_dim);
    for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
        *x = rng.normal_f32(0.0, 1.0);
    }
    for a in batch.actions.iter_mut() {
        *a = rng.below(spec.n_actions) as i32;
    }
    let out = engine.train_step(&mut state, &batch)?;
    println!(
        "one train step: loss {:.5}, |td|_mean {:.4}",
        out.loss,
        out.td.iter().map(|t| t.abs()).sum::<f32>() / out.td.len() as f32
    );
    let (action, q) = engine.act(&state, &vec![0.01; spec.obs_dim])?;
    println!("greedy action {action} (q = {q:?})");

    // --- 2. every registered replay technique ---------------------------
    for d in replay::registry::all() {
        let mut mem = replay::make(ReplayKind::from_name(d.name), 1024);
        for i in 0..1024 {
            mem.push(
                Experience {
                    obs: vec![i as f32; 4],
                    action: 0,
                    reward: 0.0,
                    next_obs: vec![i as f32; 4],
                    done: false,
                },
                &mut rng,
            );
        }
        let idx: Vec<usize> = (0..1024).collect();
        let tds: Vec<f32> = (0..1024).map(|_| rng.f32()).collect();
        mem.update_priorities(&idx, &tds);
        let b = mem.sample(64, &mut rng);
        println!(
            "{:<9} sampled 64 (first 6 slots: {:?})",
            d.name,
            &b.indices[..6]
        );
    }

    // --- 3. the AMPER accelerator ---------------------------------------
    let mut acc = AmperAccelerator::new(8192, AccelConfig::default(), 0xACE1);
    for i in 0..8192 {
        acc.write_priority(i, rng.f32());
    }
    for variant in [Variant::Knn, Variant::Frnn] {
        let s = acc.sample(64, variant);
        println!(
            "accelerator {:?}: CSP {} entries, modeled latency {} \
             ({} TCAM searches, {} CSB writes)",
            variant,
            s.csp_len,
            amper::bench_harness::fmt_ns(s.report.total_ns),
            s.report.events.exact_searches + s.report.events.best_searches,
            s.report.events.csb_writes,
        );
    }
    println!("quickstart OK");
    Ok(())
}
