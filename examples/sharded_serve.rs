//! Sharded-service quickstart: the same actor/learner dataflow as
//! `amper_serve`, scaled across N single-owner replay shards — one
//! search/write port per bank, as in the paper's hardware, with the
//! batch fanned out as per-shard sub-batches and TD errors routed back
//! through the `(shard, slot)` global index.
//!
//! The learner is pipelined (two requests in flight) and the per-shard
//! replies land in pooled segment buffers that merge by shard-offset
//! writes into one pooled pre-sized reply — the zero-copy gathered path.
//!
//! Run: `cargo run --release --example sharded_serve [seconds] [shards]`

use std::sync::atomic::Ordering;

use amper::coordinator::{GatherPipeline, ShardedReplayService, VectorEnvDriver};
use amper::replay::{self, global_index, ReplayKind};
use amper::util::Timer;

fn main() {
    let mut args = std::env::args().skip(1);
    let secs: u64 = args.next().map(|s| s.parse().expect("seconds")).unwrap_or(3);
    let shards: usize = args.next().map(|s| s.parse().expect("shards")).unwrap_or(4);

    let svc = ShardedReplayService::spawn_partitioned(
        100_000,
        shards,
        4096,
        0,
        |_, cap| replay::make(ReplayKind::AmperFr, cap),
    );
    // batch-first ingest: one 32-row PushBatch per 32 env steps, split
    // into per-shard sub-batches inside the handle
    let driver = VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 7, 32);
    let mut learner = GatherPipeline::new(svc.handle(), 64, 2);

    let t = Timer::start();
    let mut batches = 0u64;
    let mut batch_lat_ns = Vec::new();
    while t.elapsed().as_secs() < secs {
        let bt = Timer::start();
        let b = learner.next_batch().expect("gather failed");
        if b.is_empty() {
            learner.recycle(b);
            std::thread::yield_now();
            continue;
        }
        // indices are (shard, slot) encodings — show one decode
        if batches == 0 {
            let (shard, slot) = global_index::decode(b.indices[0]);
            println!("first sampled index: shard {shard}, slot {slot}");
        }
        let td = vec![0.5; b.rows()];
        let _ = learner.feedback(&b, &td);
        learner.recycle(b);
        batch_lat_ns.push(bt.ns());
        batches += 1;
    }
    let steps = driver.stop();
    let h = svc.handle();
    let pushes = h.stats().pushes.load(Ordering::Relaxed);
    let pool_rate = h.reply_pool().stats().hit_rate_percent();
    let seg_rate = h.segment_pool().stats().hit_rate_percent();
    let mems = svc.stop();
    let stored: usize = mems.iter().map(|m| m.len()).sum();
    let lat = amper::util::stats::Summary::of(&batch_lat_ns).unwrap();
    println!(
        "{shards} shard(s) | ingest {:>8} steps ({:>9.0}/s) | served {:>7} \
         batches ({:>7.0}/s) | batch p50 {} p99 {} | stored {}",
        steps,
        steps as f64 / secs as f64,
        batches,
        batches as f64 / secs as f64,
        amper::bench_harness::fmt_ns(lat.p50),
        amper::bench_harness::fmt_ns(lat.p99),
        stored,
    );
    println!(
        "reply pool {pool_rate:.1}% hit | segment pool {seg_rate:.1}% hit \
         (steady state = allocation-free gathers)"
    );
    // per-stage histograms aggregated across all shard workers
    let stage = |name: &str, hist: &amper::metrics::LatencyHistogram| {
        if hist.count() > 0 {
            println!(
                "  stage {name:<13} p50 {} p99 {}",
                amper::bench_harness::fmt_ns(hist.quantile_ns(0.5)),
                amper::bench_harness::fmt_ns(hist.quantile_ns(0.99)),
            );
        }
    };
    let s = h.stats();
    stage("flush-accept", &s.stages.flush);
    stage("worker-gather", &s.stages.gather);
    stage("reply-merge", &s.stages.merge);
    for (i, m) in mems.iter().enumerate() {
        println!("  shard {i}: {} transitions ({})", m.len(), m.kind().name());
    }
    assert_eq!(pushes, steps);
}
