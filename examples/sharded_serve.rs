//! Sharded-service quickstart: the same actor/learner dataflow as
//! `amper_serve`, scaled across N single-owner replay shards — one
//! search/write port per bank, as in the paper's hardware, with the
//! batch fanned out as per-shard sub-batches and TD errors routed back
//! through the `(shard, slot)` global index.
//!
//! Actors run on epoch-versioned policy snapshots published by the
//! learner ([`SnapshotSlot`]) with one batched forward per vec-env tick.
//! The learner is pipelined (two requests in flight) and the per-shard
//! replies land in pooled segment buffers merged **in completion order**
//! into one pooled pre-sized reply — a slow shard never serializes the
//! fast ones, and the whole wait is bounded by a single shared deadline.
//!
//! Run: `cargo run --release --example sharded_serve [seconds] [shards]`

use std::sync::atomic::Ordering;

use amper::coordinator::{
    FlushPolicy, GatherPipeline, PolicySnapshot, ShardedReplayService, SnapshotSlot,
    VectorEnvDriver,
};
use amper::replay::{self, global_index, ReplayKind};
use amper::runtime::{Engine, EnvArtifacts, TrainScratch, TrainState};
use amper::util::Timer;

fn main() {
    let mut args = std::env::args().skip(1);
    let secs: u64 = args.next().map(|s| s.parse().expect("seconds")).unwrap_or(3);
    let shards: usize = args.next().map(|s| s.parse().expect("shards")).unwrap_or(4);

    let engine = Engine::from_spec(EnvArtifacts::builtin("cartpole").unwrap());
    let batch = engine.spec().batch;
    let obs_dim = engine.spec().obs_dim;
    let mut state = TrainState::init(engine.spec(), 0).unwrap();

    let svc = ShardedReplayService::spawn_partitioned(
        100_000,
        shards,
        4096,
        0,
        |_, cap| replay::make(ReplayKind::AmperFr, cap),
    );
    let slot = SnapshotSlot::with_stats(
        PolicySnapshot::new(state.snapshot_params(), engine.spec().dims.clone(), 0)
            .unwrap(),
        svc.handle().stats().snapshot.clone(),
    );
    // batch-first ingest: one 32-row PushBatch per 32 env steps, split
    // into per-shard sub-batches inside the handle; actions come from
    // the snapshot slot, one batched forward across all four envs
    let driver = VectorEnvDriver::spawn_snapshot(
        "cartpole",
        4,
        slot.clone(),
        svc.handle(),
        7,
        0.05,
        FlushPolicy::fixed(32),
    );
    let mut learner = GatherPipeline::new(svc.handle(), batch, 2);
    let mut scratch = TrainScratch::default();

    let t = Timer::start();
    let mut batches = 0u64;
    let mut trained = 0u64;
    let mut batch_lat_ns = Vec::new();
    while t.elapsed().as_secs() < secs {
        let bt = Timer::start();
        let b = learner.next_batch().expect("gather failed");
        if b.is_empty() {
            learner.recycle(b);
            std::thread::yield_now();
            continue;
        }
        // indices are (shard, slot) encodings — show one decode
        if batches == 0 {
            let (shard, slot) = global_index::decode(b.indices[0]);
            println!("first sampled index: shard {shard}, slot {slot}");
        }
        let n = b.rows();
        let td = if n == batch && b.obs.len() == n * obs_dim {
            let out = engine
                .train_step_scratch(&mut state, (&b).into(), &mut scratch)
                .expect("train step");
            trained += 1;
            if trained % 8 == 0 {
                slot.publish(state.snapshot_params());
            }
            out.td
        } else {
            vec![0.5; n]
        };
        let _ = learner.feedback(&b, &td);
        learner.recycle(b);
        batch_lat_ns.push(bt.ns());
        batches += 1;
    }
    let steps = driver.stop();
    let h = svc.handle();
    let pushes = h.stats().pushes.load(Ordering::Relaxed);
    let pool_rate = h.reply_pool().stats().hit_rate_percent();
    let seg_rate = h.segment_pool().stats().hit_rate_percent();
    let mems = svc.stop();
    let stored: usize = mems.iter().map(|m| m.len()).sum();
    let lat = amper::util::stats::Summary::of(&batch_lat_ns).unwrap();
    println!(
        "{shards} shard(s) | ingest {:>8} steps ({:>9.0}/s) | served {:>7} \
         batches ({:>7.0}/s, {trained} trained) | batch p50 {} p99 {} | stored {}",
        steps,
        steps as f64 / secs as f64,
        batches,
        batches as f64 / secs as f64,
        amper::bench_harness::fmt_ns(lat.p50),
        amper::bench_harness::fmt_ns(lat.p99),
        stored,
    );
    println!(
        "reply pool {pool_rate:.1}% hit | segment pool {seg_rate:.1}% hit \
         (steady state = allocation-free gathers)"
    );
    let snap = slot.stats();
    println!(
        "snapshots: {} published (epoch {}), actor p99 staleness {} epochs over \
         {} reads",
        snap.publishes.load(Ordering::Relaxed),
        slot.epoch(),
        snap.behind.quantile_ns(0.99),
        snap.behind.count(),
    );
    // per-stage histograms aggregated across all shard workers
    let stage = |name: &str, hist: &amper::metrics::LatencyHistogram| {
        if hist.count() > 0 {
            println!(
                "  stage {name:<13} p50 {} p99 {}",
                amper::bench_harness::fmt_ns(hist.quantile_ns(0.5)),
                amper::bench_harness::fmt_ns(hist.quantile_ns(0.99)),
            );
        }
    };
    let s = h.stats();
    stage("flush-accept", &s.stages.flush);
    stage("worker-gather", &s.stages.gather);
    stage("reply-merge", &s.stages.merge);
    for (i, m) in mems.iter().enumerate() {
        println!("  shard {i}: {} transitions ({})", m.len(), m.kind().name());
    }
    assert_eq!(pushes, steps);
}
