//! END-TO-END driver (DESIGN.md deliverable): online DQN on CartPole
//! through all three layers — Rust coordinator → PJRT-compiled JAX graph
//! → Pallas kernels — with the AMPER-fr replay memory, logging the loss
//! curve and episode returns, finishing with a greedy evaluation.
//!
//! Run: `cargo run --release --example train_cartpole [steps] [replay]`

use amper::agent::DqnAgent;
use amper::config::TrainConfig;
use amper::replay::ReplayKind;
use amper::util::error::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8_000);
    let replay = args
        .get(2)
        .map(|s| ReplayKind::parse(s).expect("uniform|per|amper-k|amper-fr"))
        .unwrap_or(ReplayKind::AmperFr);

    let config = TrainConfig {
        env: "cartpole".into(),
        replay,
        er_size: 2000,
        steps,
        warmup: 500,
        eps_decay_steps: steps / 2,
        target_sync: 500,
        seed: 0,
        ..Default::default()
    };
    println!(
        "== end-to-end DQN: cartpole, {} steps, replay {} ==",
        steps,
        replay.name()
    );
    let mut agent = DqnAgent::new(config)?;
    let report = agent.run()?;

    // loss curve (decimated)
    println!("\nloss curve (every ~{}th train step):", report.losses.len() / 20 + 1);
    let stride = report.losses.len() / 20 + 1;
    for (i, chunk) in report.losses.chunks(stride).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  train-step {:>6}  loss {mean:.5}", i * stride);
    }

    // learning curve
    let eps = report.returns.episodes();
    println!("\nepisode returns (smoothed, every ~{}th):", eps.len() / 15 + 1);
    let sm = report.returns.smoothed(10);
    for (i, r) in sm.iter().enumerate().step_by(eps.len() / 15 + 1) {
        println!("  episode {i:>4}  return {r:.1}");
    }

    println!("\n== phase breakdown ==\n{}", report.profile.report());
    println!(
        "episodes {} | final-10 train mean {:.1} | greedy test score {:.1}",
        report.returns.n_episodes(),
        report.returns.recent_mean(10),
        report.test_score
    );
    // CartPole: a learning agent clears ~100+ after a few thousand steps;
    // random policy scores ~20.
    if report.test_score > 100.0 {
        println!("RESULT: learned (test score > 100)");
    } else {
        println!("RESULT: below target — try more steps");
    }
    Ok(())
}
