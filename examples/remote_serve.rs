//! Remote-tier quickstart: ONE process plays a whole multi-tenant
//! topology over loopback — a standalone replay tier (`NetServer` over
//! a single-owner AMPER-fr service), **two learner clients** that each
//! train their own engine on remotely gathered batches and publish
//! policy snapshots back to the tier, and **two actor-fleet clients**
//! that wait for a relayed snapshot and then drive batched vec-envs
//! against the remote sink.
//!
//! Everything client-side is the unmodified in-process machinery
//! (`GatherPipeline`, `VectorEnvDriver`, `SnapshotSlot`) running
//! against [`RemoteReplayClient`] — the wire is just another handle
//! shape. The tier's snapshot hub merges both learners' publishes
//! monotonically (highest epoch wins) and relays to the actors
//! piggybacked on their push cadence.
//!
//! Run: `cargo run --release --example remote_serve [seconds]`

use std::sync::atomic::Ordering;
use std::time::Duration;

use amper::coordinator::{
    FlushPolicy, GatherPipeline, PolicySnapshot, ReplayService, SnapshotSlot,
    VectorEnvDriver,
};
use amper::net::{Listener, NetServer, RemoteReplayClient, Role};
use amper::replay::{self, ReplayKind};
use amper::runtime::{Engine, EnvArtifacts, TrainScratch, TrainState};
use amper::util::Timer;

fn main() {
    let mut args = std::env::args().skip(1);
    let secs: u64 = args.next().map(|s| s.parse().expect("seconds")).unwrap_or(3);

    // --- the replay tier: one process owns the memory, serves the wire
    let svc = ReplayService::spawn(
        replay::make(ReplayKind::AmperFr, 100_000),
        4096,
        0,
    );
    let listener = Listener::bind("127.0.0.1:0").expect("bind loopback");
    let server = NetServer::spawn(svc.handle(), listener).expect("spawn tier");
    let addr = server.addr().to_string();
    println!("replay tier on {addr}");

    // --- two learner tenants, each with its own engine + train state
    let mut learners = Vec::new();
    for seed in 0..2u64 {
        let addr = addr.clone();
        learners.push(std::thread::spawn(move || {
            let engine =
                Engine::from_spec(EnvArtifacts::builtin("cartpole").unwrap());
            let batch = engine.spec().batch;
            let obs_dim = engine.spec().obs_dim;
            let mut state = TrainState::init(engine.spec(), seed).unwrap();
            let client = RemoteReplayClient::connect(&addr, Role::Learner)
                .expect("learner connect");
            let slot = SnapshotSlot::with_stats(
                PolicySnapshot::new(
                    state.snapshot_params(),
                    engine.spec().dims.clone(),
                    0,
                )
                .unwrap(),
                client.service_stats().snapshot.clone(),
            );
            // ship every epoch to the tier (the initial one teaches a
            // cold tier the policy dims, unblocking the actors)
            let _relay = client.relay_snapshots(slot.clone());
            let mut pipeline = GatherPipeline::new(client.clone(), batch, 2);
            let mut scratch = TrainScratch::default();
            let t = Timer::start();
            let (mut batches, mut trained) = (0u64, 0u64);
            while t.elapsed().as_secs() < secs {
                let g = pipeline.next_batch().expect("remote gather");
                if g.is_empty() {
                    pipeline.recycle(g);
                    std::thread::yield_now();
                    continue;
                }
                let n = g.rows();
                let td = if n == batch && g.obs.len() == n * obs_dim {
                    let out = engine
                        .train_step_scratch(&mut state, (&g).into(), &mut scratch)
                        .expect("train step");
                    trained += 1;
                    if trained % 16 == 0 {
                        slot.publish(state.snapshot_params());
                    }
                    out.td
                } else {
                    vec![0.5; n]
                };
                let _ = pipeline.feedback(&g, &td);
                pipeline.recycle(g);
                batches += 1;
            }
            drop(pipeline);
            let pool = client.reply_pool().stats();
            let id = client.client_id();
            client.close();
            (id, batches, trained, slot.epoch(), pool.hit_rate_percent())
        }));
    }

    // --- two actor-fleet tenants: wait for a relayed snapshot, then
    // drive 4 batched vec-envs each against the remote sink
    let mut fleets = Vec::new();
    for seed in 0..2u64 {
        let client = RemoteReplayClient::connect(&addr, Role::Actor)
            .expect("actor connect");
        let mirror = client
            .wait_snapshot_slot(Duration::from_secs(30))
            .expect("snapshot relayed from a learner");
        let driver = VectorEnvDriver::spawn_snapshot(
            "cartpole",
            4,
            mirror,
            client.clone(),
            7 + seed,
            0.05,
            FlushPolicy::fixed(32),
        );
        fleets.push((client, driver));
    }

    // --- run, then tear the topology down in dependency order
    let mut total_trained = 0u64;
    for l in learners {
        let (id, batches, trained, epoch, pool_rate) =
            l.join().expect("learner thread");
        total_trained += trained;
        println!(
            "learner client {id}: {batches} remote batches, {trained} trained, \
             published epoch {epoch}, reply pool {pool_rate:.1}% hit"
        );
    }
    let mut total_steps = 0u64;
    for (client, driver) in fleets {
        let steps = driver.stop();
        total_steps += steps;
        let behind = client.service_stats().snapshot.behind.count();
        let id = client.client_id();
        client.close();
        println!(
            "actor fleet client {id}: {steps} env steps pushed over the wire \
             ({behind} snapshot reads)"
        );
    }

    // --- the tier's tenancy ledger: per-client accounting survives
    let clients = server.clients();
    let mut tier_pushes = 0u64;
    for c in &clients {
        let pushes = c.pushes.load(Ordering::Relaxed);
        tier_pushes += pushes;
        println!(
            "tier view of client {} ({}): {} rows pushed, {} batches served, \
             {} priority updates, {} frame errors",
            c.id,
            c.role.as_str(),
            pushes,
            c.samples.load(Ordering::Relaxed),
            c.priority_updates.load(Ordering::Relaxed),
            c.frame_errors.load(Ordering::Relaxed),
        );
    }
    assert_eq!(clients.len(), 4, "two learners + two actor fleets");
    assert_eq!(
        tier_pushes, total_steps,
        "every actor env step arrived at the tier exactly once"
    );
    let hub_epoch = server.snapshot_epoch();
    server.stop();
    let mem = svc.stop();
    println!(
        "tier held {} transitions at shutdown; hub snapshot epoch {:?}; \
         {total_trained} total train steps across tenants",
        mem.len(),
        hub_epoch,
    );
}
