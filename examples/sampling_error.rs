//! Fig 7 driver: the sampling-error study as a runnable example.
//! Prints the Fig 7a distribution table, the Fig 7b/c KL corners and a
//! Fig 7d slice; full CSVs via `amper sample-study --out results/`.
//!
//! Run: `cargo run --release --example sampling_error`

use amper::replay::amper::Variant;
use amper::replay::AmperParams;
use amper::studies::fig7::{self, Sampler};
use amper::util::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let pri = fig7::priority_list(fig7::LIST_SIZE, &mut rng);
    let params = AmperParams {
        m: 20,
        lambda: 0.3,
        lambda_prime: 0.2,
        csp_cap: usize::MAX,
        ..Default::default()
    };

    // Fig 7a: where do the sampled values land?
    println!("== Fig 7a: sampled-value distribution (10 bins) ==");
    println!("{:<10} {}", "sampler", "density per value decile (low -> high)");
    for sampler in [
        Sampler::Uniform,
        Sampler::Per,
        Sampler::AmperK,
        Sampler::AmperFr,
    ] {
        let h = fig7::value_histogram(&pri, sampler, &params, 10, 11);
        let d: Vec<String> =
            h.density().iter().map(|x| format!("{x:.3}")).collect();
        println!("{:<10} {}", sampler.name(), d.join(" "));
    }

    // KL reference points (paper §4.1.1)
    println!("\n== KL vs PER (nats; paper refs: PER-self ~140, uniform ~9000) ==");
    for sampler in [Sampler::Per, Sampler::Uniform, Sampler::AmperK, Sampler::AmperFr] {
        let kl = fig7::kl_vs_per(&pri, sampler, &params, 23);
        println!("KL({:<9}|| per) = {kl:8.1}", sampler.name());
    }

    // Fig 7b/c corners: the hyper-parameter trend
    println!("\n== Fig 7b/c: KL corners over (m, scale) ==");
    for (variant, tag) in [(Variant::Knn, "AMPER-k"), (Variant::Frnn, "AMPER-fr")] {
        let cells = fig7::heatmap(variant, &[2, 12], &[0.05, 0.25], 13);
        for c in &cells {
            println!(
                "{tag}: m={:<2} scale={:<5} KL={:8.1} nats",
                c.m, c.scale, c.kl_nats
            );
        }
    }

    // Fig 7d slice
    println!("\n== Fig 7d: KL vs CSP ratio (AMPER-k, m=8) ==");
    let cells = fig7::size_sweep(&[5_000, 20_000], &[8], &[0.03, 0.09, 0.15], 17);
    for c in &cells {
        println!(
            "er={:<6} ratio={:.2}  KL={:8.1} nats",
            c.er_size, c.csp_ratio, c.kl_nats
        );
    }
}
