//! Table 2 — hardware component latencies.
//!
//! Prints the analytic Table 2 rows (the circuit-level delays every Fig 9
//! number derives from) and, alongside, *measured host-side* costs of the
//! corresponding functional-simulation operations, so the simulation
//! overhead is visible relative to the modeled hardware.
//!
//! Run: `cargo bench --bench table2_components`

use amper::bench_harness::{black_box, Bench, BenchConfig};
use amper::hardware::latency::{table2_rows, LatencyModel};
use amper::hardware::tcam::TcamBank;
use amper::hardware::urng::Lfsr32;
use amper::replay::amper::quant;

fn main() {
    println!("== Table 2 (modeled, from 45nm synthesis + CACTI) ==");
    let model = LatencyModel::default();
    for (name, ns) in table2_rows(&model) {
        println!("{name:<24} {ns:>6.2} ns");
    }

    println!("\n== functional-simulation cost of the same operations (host) ==");
    let mut b = Bench::with_config(BenchConfig {
        warmup_ms: 100,
        samples: 40,
        iters_per_sample: 100,
    });

    let mut urng = Lfsr32::new(0xACE1);
    b.case("sim: URNG 32-bit word", || black_box(urng.next_u32()));

    let mut bank = TcamBank::new(8192);
    let mut seed = Lfsr32::new(7);
    for i in 0..8192 {
        bank.write(i, seed.next_u32());
    }
    let q = bank.value(4097);
    let mut out = Vec::with_capacity(8192);
    b.case("sim: bank exact search (128 arrays)", || {
        out.clear();
        bank.search_exact(q, 0xFFFF_0000, usize::MAX, &mut out);
        black_box(out.len())
    });
    let disabled = vec![0u64; bank.n_arrays()];
    b.case("sim: bank best-match search", || {
        black_box(bank.search_best(q, u32::MAX, &disabled))
    });
    b.case("sim: TCAM row write", || {
        bank.write(123, black_box(q));
    });
    let mut x = 0.5f32;
    b.case("sim: quantize f32->Q16.16", || {
        x = f32::from_bits(x.to_bits().wrapping_add(1) | 0x3f000000);
        black_box(quant::quantize(x))
    });

    let _ = std::fs::create_dir_all("results");
    b.write_csv("results/table2_sim_costs.csv").ok();
    println!("\nCSV -> results/table2_sim_costs.csv");
}
