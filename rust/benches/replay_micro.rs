//! Micro-benchmarks of the replay substrates — the §Perf targets for L3
//! (DESIGN.md §8): sum-tree ops (scalar walks vs the chunked batch
//! refresh), CSP construction (float sort vs integer-key sort, serial
//! and pooled), batch gather, actor inference (scalar vs batched act),
//! the learner train step at 1/2/4 engine threads, and the accelerator
//! functional-sim throughput.
//!
//! Run: `cargo bench --bench replay_micro`

use amper::bench_harness::{black_box, Bench, BenchConfig};
use amper::coordinator::{GatherPipeline, ReplayService, ShardedReplayService};
use amper::hardware::accelerator::{AccelConfig, AmperAccelerator};
use amper::replay::amper::{csp, quant, Variant};
use amper::replay::{
    AmperParams, Experience, ExperienceBatch, PerParams, PerReplay, ReplayKind,
    ReplayMemory, SampledBatch, SumTree,
};
use amper::util::Rng;

fn exp(dim: usize, v: f32) -> Experience {
    Experience {
        obs: vec![v; dim],
        action: 0,
        reward: v,
        next_obs: vec![v; dim],
        done: false,
    }
}

fn main() {
    let mut b = Bench::with_config(BenchConfig {
        warmup_ms: 150,
        samples: 50,
        iters_per_sample: 8,
    });
    let mut rng = Rng::new(0);

    // ---- sum tree (the PER baseline hot path) --------------------------
    for n in [10_000usize, 100_000, 1_000_000] {
        let mut tree = SumTree::new(n);
        for i in 0..n {
            tree.set(i, rng.f64() + 0.01);
        }
        let mut r = Rng::new(1);
        b.case(&format!("sum_tree/{n}: find"), || {
            black_box(tree.find(r.f64() * tree.total()))
        });
        b.case(&format!("sum_tree/{n}: set"), || {
            tree.set(r.below(n), r.f64());
        });
    }

    // ---- sum tree: scalar per-leaf walks vs chunked batch refresh --------
    // One batch-64 priority update, the PER feedback hot path: 64
    // root-ward walks (64·log2(n) node writes, shared ancestors written
    // repeatedly) vs 64 leaf writes + one level-by-level refresh that
    // visits each dirty ancestor once. Bit-identical by construction
    // (pinned in batch_equivalence); only speed is measured here.
    {
        let n = 100_000usize;
        let mut scalar = SumTree::new(n);
        let mut chunked = SumTree::new(n);
        let mut r = Rng::new(31);
        for i in 0..n {
            let p = r.f64() + 0.01;
            scalar.set(i, p);
            chunked.set(i, p);
        }
        let indices: Vec<usize> = (0..64).map(|_| r.below(n)).collect();
        let mut scratch = Vec::new();
        let mut p = 0.1f64;
        b.case("sum_tree/update64/scalar", || {
            p = if p > 0.9 { 0.1 } else { p + 0.001 };
            for &i in &indices {
                scalar.set(i, p);
            }
            black_box(scalar.total())
        });
        b.case("sum_tree/update64/chunked", || {
            p = if p > 0.9 { 0.1 } else { p + 0.001 };
            for &i in &indices {
                chunked.set_leaf(i, p);
            }
            chunked.refresh_leaves(&indices, &mut scratch);
            black_box(chunked.total())
        });
    }

    // ---- full PER sample+update batch-64 -------------------------------
    for n in [10_000usize, 100_000] {
        let mut mem = PerReplay::new(n, PerParams::default());
        let mut r = Rng::new(2);
        for i in 0..n {
            mem.push(exp(4, i as f32), &mut r);
            mem.set_priority_raw(i, r.f32() + 0.01);
        }
        let tds: Vec<f32> = (0..64).map(|_| r.f32()).collect();
        b.case(&format!("per/{n}: sample64+update"), || {
            let batch = mem.sample(64, &mut r);
            mem.update_priorities(&batch.indices, &tds);
            black_box(batch.indices.len())
        });
    }

    // ---- AMPER software CSP construction --------------------------------
    for n in [10_000usize, 100_000] {
        let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let params = AmperParams::default();
        let mut r = Rng::new(3);
        let mut buf = Vec::new();
        for (variant, name) in
            [(Variant::Knn, "knn"), (Variant::Frnn, "frnn")]
        {
            b.case(&format!("amper-{name}/{n}: software csp+draw64"), || {
                buf.clear();
                csp::build_csp(&pri, &pri_q, &params, variant, &mut r, &mut buf);
                black_box(csp::draw_batch(&buf, n, 64, &mut r).len())
            });
        }
    }

    // ---- CSP build: float-comparator sort vs integer-key sort ------------
    // The same Algorithm 1 selection over 100k priorities, differing only
    // in the sort that dominates the build: `(f32, usize)` pairs under
    // total_cmp vs packed u64 keys (total-order-preserving f32 -> u32
    // transform, slot in the low half) under plain integer compares —
    // serial, and with the worker-pool chunk sort + multiway merge
    // engaged. Selection identity is pinned in batch_equivalence.
    {
        let n = 100_000usize;
        let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let params = AmperParams::default();
        let mut r = Rng::new(21);
        let mut out = Vec::new();
        let mut order = Vec::new();
        b.case("csp/build/sorted-f32/100k", || {
            out.clear();
            csp::build_csp_with_scratch(
                &pri, &pri_q, &params, Variant::Frnn, &mut r, &mut out, &mut order,
            );
            black_box(out.len())
        });
        let mut scratch = csp::CspScratch::default();
        b.case("csp/build/sorted-key/100k", || {
            out.clear();
            csp::build_csp_sorted_keys(
                &pri,
                &pri_q,
                &params,
                Variant::Frnn,
                &mut r,
                &mut out,
                &mut scratch,
                None,
            );
            black_box(out.len())
        });
        let pool = amper::runtime::ThreadPool::new(4);
        b.case("csp/build/sorted-key-par4/100k", || {
            out.clear();
            csp::build_csp_sorted_keys(
                &pri,
                &pri_q,
                &params,
                Variant::Frnn,
                &mut r,
                &mut out,
                &mut scratch,
                Some(&pool),
            );
            black_box(out.len())
        });
    }

    // ---- accelerator functional sim -------------------------------------
    for n in [8192usize, 65_536] {
        let mut acc = AmperAccelerator::new(n, AccelConfig::default(), 5);
        let mut r = Rng::new(4);
        for i in 0..n {
            acc.write_priority(i, r.f32());
        }
        for (variant, name) in [(Variant::Knn, "knn"), (Variant::Frnn, "frnn")] {
            b.case(&format!("accel-{name}/{n}: functional sample64"), || {
                black_box(acc.sample(64, variant).csp_len)
            });
        }
    }

    // ---- batch gather (ring -> literals staging) ------------------------
    {
        let n = 100_000;
        let dim = 8;
        let mut mem = PerReplay::new(n, PerParams::default());
        let mut r = Rng::new(6);
        for i in 0..n {
            mem.push(exp(dim, i as f32), &mut r);
        }
        let indices: Vec<usize> = (0..64).map(|_| r.below(n)).collect();
        let mut obs = vec![0f32; 64 * dim];
        let mut act = vec![0i32; 64];
        let mut rew = vec![0f32; 64];
        let mut nobs = vec![0f32; 64 * dim];
        let mut done = vec![0f32; 64];
        b.case("ring/100k: gather batch64 (dim 8)", || {
            mem.ring()
                .gather(&indices, &mut obs, &mut act, &mut rew, &mut nobs, &mut done)
                .unwrap();
            black_box(obs[0])
        });
    }

    // ---- scalar vs batched memory ops (no service in the loop) -----------
    // The in-memory half of the batch-first claim: push_batch/chunked ring
    // memcpy + one-pass batched priority update vs the per-element loops.
    for batch in [1usize, 32, 128] {
        let er = 65_536usize;
        let mut r = Rng::new(8);
        let mut scalar = PerReplay::new(er, PerParams::default());
        let mut batched = PerReplay::new(er, PerParams::default());
        for i in 0..er {
            scalar.push(exp(4, i as f32), &mut r);
            batched.push(exp(4, i as f32), &mut r);
        }
        let rows: Vec<Experience> =
            (0..batch).map(|i| exp(4, i as f32)).collect();
        let indices: Vec<usize> = (0..batch).map(|_| r.below(er)).collect();
        let tds: Vec<f32> = (0..batch).map(|_| r.f32()).collect();
        let mut slots = Vec::new();
        // symmetric staging cost: the scalar side clones each Experience,
        // the batched side materializes its SoA batch, both inside the
        // timed body (as the svc-level sweep below does)
        b.case(&format!("mem/per/scalar/batch{batch}: push+update"), || {
            for e in &rows {
                scalar.push(e.clone(), &mut r);
            }
            scalar.update_priorities(&indices, &tds);
            black_box(scalar.len())
        });
        b.case(&format!("mem/per/batched/batch{batch}: push+update"), || {
            let eb = ExperienceBatch::from_experiences(&rows);
            slots.clear();
            batched.push_batch(&eb, &mut r, &mut slots);
            batched.update_priorities_batch(&indices, &tds);
            black_box(batched.len())
        });
    }

    // ---- new techniques: scalar vs batched memory ops --------------------
    // dpsr/dual/pper through the same sweep shape: per-iteration push of
    // `batch` rows, one sample64, one TD feedback of `batch` elements —
    // scalar loops vs the amortized batch-first overrides (state-identity
    // pinned in batch_equivalence; only speed is measured here).
    for name in ["dpsr", "dual", "pper"] {
        let kind = ReplayKind::parse(name).unwrap();
        for batch in [1usize, 32, 128] {
            let er = 65_536usize;
            let mut r = Rng::new(10);
            let mut scalar = amper::replay::make(kind, er);
            let mut batched = amper::replay::make(kind, er);
            for i in 0..er {
                scalar.push(exp(4, i as f32), &mut r);
                batched.push(exp(4, i as f32), &mut r);
            }
            let rows: Vec<Experience> =
                (0..batch).map(|i| exp(4, i as f32)).collect();
            let indices: Vec<usize> = (0..batch).map(|_| r.below(er)).collect();
            let tds: Vec<f32> = (0..batch).map(|_| r.f32()).collect();
            let mut slots = Vec::new();
            let mut out = SampledBatch::default();
            b.case(
                &format!("mem/{name}/scalar/batch{batch}: push+sample64+update"),
                || {
                    for e in &rows {
                        scalar.push(e.clone(), &mut r);
                    }
                    let sb = scalar.sample(64, &mut r);
                    scalar.update_priorities(&indices, &tds);
                    black_box(sb.indices.len())
                },
            );
            b.case(
                &format!("mem/{name}/batched/batch{batch}: push+sample64+update"),
                || {
                    let eb = ExperienceBatch::from_experiences(&rows);
                    slots.clear();
                    batched.push_batch(&eb, &mut r, &mut slots);
                    batched.sample_into(64, &mut r, &mut out);
                    batched.update_priorities_batch(&indices, &tds);
                    black_box(out.indices.len())
                },
            );
        }
    }

    // ---- actor inference: scalar act loop vs one batched forward ---------
    // The snapshot-driven actor claim: acting for a whole vec-env tick in
    // one `act_batch` forward (row-tiled GEMM, scratch reused) vs calling
    // scalar `act` once per env. Swept over vec sizes {8, 32, 128} on the
    // cartpole spec (acceptance: batched < scalar at vec >= 32; pinned
    // bit-identical by batch_equivalence, only speed is measured here).
    {
        use amper::runtime::{ActScratch, Engine, EnvArtifacts, TrainState};
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let engine = Engine::from_spec(spec.clone());
        let state = TrainState::init(&spec, 5).unwrap();
        let dim = spec.obs_dim;
        let mut r = Rng::new(9);
        for vec_envs in [8usize, 32, 128] {
            let obs: Vec<f32> =
                (0..vec_envs * dim).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mut scalar_scratch = ActScratch::default();
            b.case(&format!("act/scalar/vec{vec_envs}"), || {
                let mut acc = 0usize;
                for row in 0..vec_envs {
                    acc += engine
                        .act(&state, &obs[row * dim..(row + 1) * dim], &mut scalar_scratch)
                        .unwrap();
                }
                black_box(acc)
            });
            let mut batched_scratch = ActScratch::default();
            b.case(&format!("act/batched/vec{vec_envs}"), || {
                let actions = engine
                    .act_batch(&state.params, &obs, vec_envs, &mut batched_scratch)
                    .unwrap();
                black_box(actions[vec_envs - 1])
            });
        }
        let find = |name: &str| {
            b.results()
                .iter()
                .find(|res| res.name == name)
                .map(|res| res.ns.mean)
                .unwrap_or(f64::NAN)
        };
        let scalar = find("act/scalar/vec32");
        let batched = find("act/batched/vec32");
        println!(
            "\nact vec32: scalar-loop {} -> batched {} ({:.2}x)",
            amper::bench_harness::fmt_ns(scalar),
            amper::bench_harness::fmt_ns(batched),
            scalar / batched,
        );
    }

    // ---- learner train step: worker-pool GEMM sweep ----------------------
    // One full train step (double forward, fused TD/Huber, backward,
    // Adam) on the cartpole spec at 1/2/4 engine threads x batch
    // {32, 128}. The kernels partition disjoint output rows, so every
    // row is bit-identical to threads=1 (pinned in batch_equivalence) —
    // this sweep measures the speedup only (acceptance: threads>1 beats
    // threads=1 at batch 128, gated intra-run by bench_check.py).
    {
        use amper::runtime::{
            Engine, EnvArtifacts, TrainBatch, TrainScratch, TrainState,
        };
        for batch in [32usize, 128] {
            let mut spec = EnvArtifacts::builtin("cartpole").unwrap();
            spec.batch = batch;
            let mut r = Rng::new(13);
            let mut tb = TrainBatch::zeros(batch, spec.obs_dim);
            for x in tb.obs.iter_mut().chain(tb.next_obs.iter_mut()) {
                *x = r.normal_f32(0.0, 1.0);
            }
            for a in tb.actions.iter_mut() {
                *a = r.below(spec.n_actions) as i32;
            }
            for rew in tb.rewards.iter_mut() {
                *rew = r.f32();
            }
            for w in tb.is_weights.iter_mut() {
                *w = 1.0;
            }
            for threads in [1usize, 2, 4] {
                let mut engine = Engine::from_spec(spec.clone());
                engine.set_threads(threads);
                let mut state = TrainState::init(&spec, 7).unwrap();
                let mut scratch = TrainScratch::default();
                b.case(&format!("train/threads{threads}/batch{batch}"), || {
                    let out = engine
                        .train_step_scratch(&mut state, tb.view(), &mut scratch)
                        .unwrap();
                    let loss = out.loss;
                    scratch.recycle(out);
                    black_box(loss)
                });
            }
        }
    }

    // ---- replay service: single-owner vs sharded throughput sweep --------
    // One learner-shaped client driving push64 + sample64 + update64 per
    // iteration. The single-owner ReplayService is the baseline; the
    // ShardedReplayService rows show scaling at shards ∈ {1, 2, 4, 8}
    // (sub-batches sample concurrently across shard workers). Sampling
    // determinism per (seed, shard count) is pinned by
    // coordinator::sharded tests, not re-measured here.
    {
        let er = 65_536usize;
        let seed = 11u64;
        {
            let svc = ReplayService::spawn(
                Box::new(PerReplay::new(er, PerParams::default())),
                4096,
                seed,
            );
            let h = svc.handle();
            for i in 0..er {
                assert!(h.push(exp(4, i as f32)));
            }
            let mut k = 0u32;
            b.case("service/single-owner/65536: push64+sample64+update", || {
                for _ in 0..64 {
                    k = k.wrapping_add(1);
                    let _ = h.push(exp(4, k as f32));
                }
                let batch = h.sample(64);
                let n = batch.indices.len();
                let _ = h.update_priorities(batch.indices, vec![0.5; n]);
                black_box(n)
            });
        }
        for shards in [1usize, 2, 4, 8] {
            let svc = ShardedReplayService::spawn_partitioned(
                er,
                shards,
                4096,
                seed,
                |_, cap| Box::new(PerReplay::new(cap, PerParams::default())),
            );
            let h = svc.handle();
            for i in 0..er {
                assert!(h.push(exp(4, i as f32)));
            }
            let mut k = 0u32;
            b.case(
                &format!("service/sharded-x{shards}/65536: push64+sample64+update"),
                || {
                    for _ in 0..64 {
                        k = k.wrapping_add(1);
                        let _ = h.push(exp(4, k as f32));
                    }
                    let batch = h.sample(64);
                    let n = batch.indices.len();
                    let _ = h.update_priorities(batch.indices, vec![0.5; n]);
                    black_box(n)
                },
            );
        }
    }

    // ---- scalar vs batched service protocol sweep ------------------------
    // The end-to-end batch-first measurement: one learner-shaped client
    // driving push + sample + TD update through the sharded service.
    //   scalar:  one command per transition (today's scalar convenience
    //            path: each push is a 1-row PushBatch, so this row also
    //            carries the per-row batch-wrapping cost), one update
    //            message per TD element;
    //   batched: one PushBatch per batch, one coalesced update message
    //            (split per shard inside the handle).
    // Swept over batch {1, 8, 32, 128} x shards {1, 4} so the win is
    // measured, not asserted (acceptance: batched < scalar at batch>=32
    // on both shard counts).
    for shards in [1usize, 4] {
        for batch in [1usize, 8, 32, 128] {
            let er = 16_384usize;
            let warm = |h: &amper::coordinator::ShardedHandle| {
                let mut i = 0f32;
                for _ in 0..(er / 1024) {
                    let mut eb = ExperienceBatch::with_capacity(4, 1024);
                    for _ in 0..1024 {
                        i += 1.0;
                        eb.push_parts(&[i; 4], 0, i, &[i; 4], false);
                    }
                    assert!(h.push_batch(eb));
                }
            };
            {
                let svc = ShardedReplayService::spawn_partitioned(
                    er,
                    shards,
                    4096,
                    17,
                    |_, cap| Box::new(PerReplay::new(cap, PerParams::default())),
                );
                let h = svc.handle();
                warm(&h);
                let mut k = 0u32;
                b.case(
                    &format!("svc/scalar/shards{shards}/batch{batch}: push+sample+update"),
                    || {
                        for _ in 0..batch {
                            k = k.wrapping_add(1);
                            let _ = h.push(exp(4, k as f32));
                        }
                        let sb = h.sample(batch);
                        for &g in &sb.indices {
                            let _ = h.update_priorities(vec![g], vec![0.5]);
                        }
                        black_box(sb.indices.len())
                    },
                );
            }
            {
                let svc = ShardedReplayService::spawn_partitioned(
                    er,
                    shards,
                    4096,
                    17,
                    |_, cap| Box::new(PerReplay::new(cap, PerParams::default())),
                );
                let h = svc.handle();
                warm(&h);
                let mut k = 0u32;
                b.case(
                    &format!("svc/batched/shards{shards}/batch{batch}: push+sample+update"),
                    || {
                        let mut eb = ExperienceBatch::with_capacity(4, batch);
                        for _ in 0..batch {
                            k = k.wrapping_add(1);
                            let v = k as f32;
                            eb.push_parts(&[v; 4], 0, v, &[v; 4], false);
                        }
                        let _ = h.push_batch(eb);
                        let sb = h.sample(batch);
                        let n = sb.indices.len();
                        let _ = h.update_priorities(sb.indices, vec![0.5; n]);
                        black_box(n)
                    },
                );
            }
        }
    }

    // ---- gathered replies: allocating sync vs pooled pipelined -----------
    // The zero-copy tentpole measurement on the learner-facing path.
    //   sync-alloc:       the PR-4 reply protocol — pools disabled, the
    //                     learner blocks on each round trip, and every
    //                     reply (segments + merge) allocates fresh;
    //   pipelined-pooled: the steady-state path — two requests in flight
    //                     (GatherPipeline depth 2), every consumed reply
    //                     recycled, per-shard replies merged by offset
    //                     writes into a pooled pre-sized reply.
    // The pooled rows also *assert* the zero-allocation claim: after a
    // fixed warm loop, pool misses must stay flat through the whole
    // measured region (every gathered reply is a pool hit).
    for shards in [1usize, 4] {
        for batch in [32usize, 128] {
            let er = 16_384usize;
            let spawn_warm = || {
                let svc = ShardedReplayService::spawn_partitioned(
                    er,
                    shards,
                    4096,
                    23,
                    |_, cap| Box::new(PerReplay::new(cap, PerParams::default())),
                );
                let h = svc.handle();
                let mut i = 0f32;
                for _ in 0..(er / 1024) {
                    let mut eb = ExperienceBatch::with_capacity(4, 1024);
                    for _ in 0..1024 {
                        i += 1.0;
                        eb.push_parts(&[i; 4], 0, i, &[i; 4], false);
                    }
                    assert!(h.push_batch(eb));
                }
                svc
            };
            {
                let svc = spawn_warm();
                let h = svc.handle();
                // true allocating baseline: pooling disabled end to end, so
                // per-shard segments AND the merged reply allocate fresh on
                // every request (nothing recycles anywhere)
                h.reply_pool().set_capacity(0);
                h.segment_pool().set_capacity(0);
                b.case(
                    &format!("svc/gathered/sync-alloc/shards{shards}/batch{batch}"),
                    || {
                        let g = h.sample_gathered(batch).unwrap();
                        let n = g.rows();
                        let _ = h.update_priorities(g.indices.clone(), vec![0.5; n]);
                        black_box(n)
                    },
                );
            }
            {
                let svc = spawn_warm();
                let h = svc.handle();
                let mut pl = GatherPipeline::new(h.clone(), batch, 2);
                // reach the steady state before measuring
                for _ in 0..32 {
                    let g = pl.next_batch().unwrap();
                    let td = vec![0.5; g.rows()];
                    let _ = pl.feedback(&g, &td);
                    pl.recycle(g);
                }
                use std::sync::atomic::Ordering::Relaxed;
                let misses = || {
                    h.reply_pool().stats().misses.load(Relaxed)
                        + h.segment_pool().stats().misses.load(Relaxed)
                };
                let misses_before = misses();
                b.case(
                    &format!(
                        "svc/gathered/pipelined-pooled/shards{shards}/batch{batch}"
                    ),
                    || {
                        let g = pl.next_batch().unwrap();
                        let n = g.rows();
                        let td = vec![0.5; n];
                        let _ = pl.feedback(&g, &td);
                        pl.recycle(g);
                        black_box(n)
                    },
                );
                assert_eq!(
                    misses(),
                    misses_before,
                    "steady-state gathered replies must be pool hits \
                     (zero allocations per batch)"
                );
                // dump the per-stage service report for the headline case —
                // the CI bench-smoke job uploads this next to BENCH_*.json
                if shards == 4 && batch == 128 {
                    let stats_path = concat!(
                        env!("CARGO_MANIFEST_DIR"),
                        "/../STATS_replay_micro.json"
                    );
                    let report = h.stats_json();
                    match std::fs::write(stats_path, format!("{report}\n")) {
                        Ok(()) => println!("stage stats -> {stats_path}"),
                        Err(e) => {
                            eprintln!("stats write failed ({stats_path}): {e}")
                        }
                    }
                }
            }
        }
    }
    // headline: the acceptance ratio at batch 128 x 4 shards
    {
        let find = |name: &str| {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.ns.mean)
                .unwrap_or(f64::NAN)
        };
        let alloc = find("svc/gathered/sync-alloc/shards4/batch128");
        let pooled = find("svc/gathered/pipelined-pooled/shards4/batch128");
        println!(
            "\ngathered batch128 x 4 shards: sync-alloc {} -> pipelined-pooled {} \
             ({:+.1}% latency)",
            amper::bench_harness::fmt_ns(alloc),
            amper::bench_harness::fmt_ns(pooled),
            100.0 * (pooled - alloc) / alloc,
        );
    }

    // ---- wire tax: in-process handle vs the net tier over loopback -------
    // The same learner-shaped gathered workload (one PushBatch of 64
    // rows, one gathered sample, one coalesced update, reply recycled)
    // against identically seeded single-owner services — once through
    // the in-process `ServiceHandle`, once through `NetServer` +
    // `RemoteReplayClient` on 127.0.0.1. The pair quantifies the wire
    // tax (framing, syscalls, one socket round trip per gather);
    // bench_check.py bounds the loopback/inproc ratio so a transport
    // regression (lost TCP_NODELAY, per-row encoding creep) fails CI.
    {
        use amper::coordinator::LearnerPort;
        use amper::net::{Listener, NetServer, RemoteReplayClient, Role};
        let er = 16_384usize;
        let spawn_warm = || {
            let svc = ReplayService::spawn(
                Box::new(PerReplay::new(er, PerParams::default())),
                4096,
                29,
            );
            let h = svc.handle();
            let mut i = 0f32;
            for _ in 0..(er / 1024) {
                let mut eb = ExperienceBatch::with_capacity(4, 1024);
                for _ in 0..1024 {
                    i += 1.0;
                    eb.push_parts(&[i; 4], 0, i, &[i; 4], false);
                }
                assert!(h.push_batch(eb));
            }
            svc
        };
        for batch in [32usize, 128] {
            {
                let svc = spawn_warm();
                let h = svc.handle();
                let mut k = 0u32;
                b.case(&format!("net/inproc/batch{batch}"), || {
                    let mut eb = ExperienceBatch::with_capacity(4, 64);
                    for _ in 0..64 {
                        k = k.wrapping_add(1);
                        let v = k as f32;
                        eb.push_parts(&[v; 4], 0, v, &[v; 4], false);
                    }
                    let _ = h.push_batch(eb);
                    let g = h.sample_gathered(batch).unwrap();
                    let n = g.rows();
                    let _ = h.update_priorities(g.indices.clone(), vec![0.5; n]);
                    h.recycle(g);
                    black_box(n)
                });
            }
            {
                use amper::coordinator::ReplaySink;
                let svc = spawn_warm();
                let listener = Listener::bind("127.0.0.1:0").unwrap();
                let server = NetServer::spawn(svc.handle(), listener).unwrap();
                let client =
                    RemoteReplayClient::connect(server.addr(), Role::Learner)
                        .unwrap();
                let mut k = 0u32;
                b.case(&format!("net/loopback/batch{batch}"), || {
                    let mut eb = ExperienceBatch::with_capacity(4, 64);
                    for _ in 0..64 {
                        k = k.wrapping_add(1);
                        let v = k as f32;
                        eb.push_parts(&[v; 4], 0, v, &[v; 4], false);
                    }
                    let _ = client.push_experience_batch(eb);
                    let g = client.sample_gathered(batch).unwrap();
                    let n = g.rows();
                    let _ =
                        client.update_priorities(g.indices.clone(), vec![0.5; n]);
                    client.recycle(g);
                    black_box(n)
                });
                client.close();
                server.stop();
            }
        }
        let find = |name: &str| {
            b.results()
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.ns.mean)
                .unwrap_or(f64::NAN)
        };
        let inproc = find("net/inproc/batch128");
        let loopback = find("net/loopback/batch128");
        println!(
            "\nnet batch128: in-process {} -> loopback {} ({:.2}x wire tax)",
            amper::bench_harness::fmt_ns(inproc),
            amper::bench_harness::fmt_ns(loopback),
            loopback / inproc,
        );
    }

    let _ = std::fs::create_dir_all("results");
    b.write_csv("results/replay_micro.csv").ok();
    println!("\nCSV -> results/replay_micro.csv");
    // machine-readable perf trajectory at the repo root (BENCH_*.json)
    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_replay_micro.json");
    match b.write_json(json_path) {
        Ok(()) => println!("JSON -> {json_path}"),
        Err(e) => eprintln!("JSON write failed ({json_path}): {e}"),
    }
}
