//! Ablations of the design choices DESIGN.md §7 calls out:
//!
//! 1. **Prefix-query approximation** (§3.4.2): frNN with the pow2-snapped
//!    prefix block vs an exact fixed-radius search — how much selection
//!    error does the single-exact-match trick introduce, and what would
//!    exact-radius cost in searches?
//! 2. **kNN vs frNN selection overlap**: how similar are the CSPs?
//! 3. **Stratified vs plain inverse-CDF PER sampling**: the baseline's
//!    own design knob (affects the Fig 7 reference distribution).
//! 4. **Quantization width**: selection drift of Q16.16 vs f32 CSPs.
//!
//! Run: `cargo bench --bench ablations`

use amper::metrics::kl_divergence_counts;
use amper::replay::amper::{csp, frnn, quant, AmperParams, Variant};
use amper::replay::SumTree;
use amper::studies::fig7;
use amper::util::Rng;

fn jaccard(a: &[usize], b: &[usize]) -> f64 {
    let sa: std::collections::HashSet<_> = a.iter().collect();
    let sb: std::collections::HashSet<_> = b.iter().collect();
    let inter = sa.intersection(&sb).count();
    let union = sa.union(&sb).count();
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

fn main() {
    let mut rng = Rng::new(7);
    let n = 10_000;
    let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
    let mut order: Vec<(f32, usize)> = pri.iter().copied().zip(0..n).collect();
    order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    // ---- 1. prefix approximation vs exact radius ------------------------
    println!("== ablation 1: prefix-query vs exact fixed-radius selection ==");
    println!("{:<8} {:>10} {:>10} {:>9} {:>12}", "delta", "|exact|", "|prefix|", "jaccard", "extra/miss");
    for delta in [0.002f32, 0.01, 0.05, 0.1] {
        let mut sel_sizes = (0f64, 0f64);
        let mut jac = 0f64;
        let mut extra = 0usize;
        let mut missed = 0usize;
        let reps = 50;
        for _ in 0..reps {
            let v = rng.f32();
            // exact radius: |p - v| <= delta (what ideal frNN returns)
            let exact: Vec<usize> = (0..n)
                .filter(|&i| (pri[i] - v).abs() <= delta)
                .collect();
            let mut prefix = Vec::new();
            frnn::select_frnn(&order, &pri_q, v, delta, usize::MAX, &mut prefix);
            jac += jaccard(&exact, &prefix);
            sel_sizes.0 += exact.len() as f64;
            sel_sizes.1 += prefix.len() as f64;
            let pset: std::collections::HashSet<_> = prefix.iter().collect();
            let eset: std::collections::HashSet<_> = exact.iter().collect();
            extra += prefix.iter().filter(|i| !eset.contains(i)).count();
            missed += exact.iter().filter(|i| !pset.contains(i)).count();
        }
        println!(
            "{:<8} {:>10.1} {:>10.1} {:>9.3} {:>6}/{:<6}",
            delta,
            sel_sizes.0 / reps as f64,
            sel_sizes.1 / reps as f64,
            jac / reps as f64,
            extra / reps,
            missed / reps
        );
    }
    println!(
        "(prefix needs 1 exact-match search; exact radius would need a \
         range scan or 2·Δ·2^16 ternary probes)"
    );

    // ---- 2. kNN vs frNN CSP overlap -------------------------------------
    println!("\n== ablation 2: kNN vs frNN CSP overlap (matched ratios) ==");
    for (lambda, lambda_prime) in [(0.1f32, 0.066f32), (0.3, 0.2), (0.5, 0.33)] {
        let params_k = AmperParams { m: 20, lambda, csp_cap: usize::MAX, ..Default::default() };
        let params_f = AmperParams {
            m: 20,
            lambda_prime,
            csp_cap: usize::MAX,
            ..Default::default()
        };
        let mut rk = Rng::new(42);
        let mut rf = Rng::new(42); // same representative draws
        let mut ck = Vec::new();
        let mut cf = Vec::new();
        csp::build_csp(&pri, &pri_q, &params_k, Variant::Knn, &mut rk, &mut ck);
        csp::build_csp(&pri, &pri_q, &params_f, Variant::Frnn, &mut rf, &mut cf);
        println!(
            "λ={lambda:<4} λ'={lambda_prime:<5} |k|={:<5} |fr|={:<5} jaccard={:.3}",
            ck.len(),
            cf.len(),
            jaccard(&ck, &cf)
        );
    }

    // ---- 3. stratified vs plain PER sampling -----------------------------
    println!("\n== ablation 3: stratified vs plain PER draws (KL vs plain ref) ==");
    let mut tree = SumTree::new(n);
    for (i, &p) in pri.iter().enumerate() {
        tree.set(i, p as f64);
    }
    let draws = 6400;
    let plain = |rng: &mut Rng| {
        let mut counts = vec![0u32; n];
        for _ in 0..draws {
            counts[tree.find(rng.f64() * tree.total())] += 1;
        }
        counts
    };
    let stratified = |rng: &mut Rng| {
        let mut counts = vec![0u32; n];
        let batches = draws / 64;
        for _ in 0..batches {
            let seg = tree.total() / 64.0;
            for j in 0..64 {
                let y = seg * j as f64 + rng.f64() * seg;
                counts[tree.find(y)] += 1;
            }
        }
        counts
    };
    let mut r1 = Rng::new(1);
    let mut r2 = Rng::new(2);
    let mut r3 = Rng::new(3);
    let ref_counts = plain(&mut r1);
    let plain2 = plain(&mut r2);
    let strat = stratified(&mut r3);
    let bin = |c: &[u32]| fig7::bin_counts(&pri, c, 250);
    println!(
        "KL(plain‖plain)      = {:.1} nats (noise floor)",
        kl_divergence_counts(&bin(&plain2), &bin(&ref_counts), 0.5)
    );
    println!(
        "KL(stratified‖plain) = {:.1} nats (should match floor: same marginal)",
        kl_divergence_counts(&bin(&strat), &bin(&ref_counts), 0.5)
    );

    // ---- 4. quantization width -------------------------------------------
    println!("\n== ablation 4: Q16.16 quantization drift of the CSP ==");
    for frac_bits_drop in [0u32, 8, 12] {
        // emulate coarser storage by masking low mantissa bits
        let coarse: Vec<u32> =
            pri_q.iter().map(|&q| q & (!0u32 << frac_bits_drop)).collect();
        let params = AmperParams { m: 20, lambda_prime: 0.2, csp_cap: usize::MAX, ..Default::default() };
        let mut ra = Rng::new(11);
        let mut rb = Rng::new(11);
        let mut full = Vec::new();
        let mut deg = Vec::new();
        csp::build_csp(&pri, &pri_q, &params, Variant::Frnn, &mut ra, &mut full);
        csp::build_csp(&pri, &coarse, &params, Variant::Frnn, &mut rb, &mut deg);
        println!(
            "effective frac bits {:>2}: |csp|={:<5} jaccard vs Q16.16 = {:.3}",
            16i32 - frac_bits_drop as i32,
            deg.len(),
            jaccard(&full, &deg)
        );
    }
}
