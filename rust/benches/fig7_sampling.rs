//! Fig 7 — sampling-error study (KL heat maps + histograms) plus the
//! host-side cost of each sampler, regenerating the paper's §4.1.1 data.
//!
//! Run: `cargo bench --bench fig7_sampling`

use amper::bench_harness::{black_box, Bench, BenchConfig};
use amper::replay::amper::{csp, quant, Variant};
use amper::replay::{AmperParams, SumTree};
use amper::studies::fig7::{self, Sampler};
use amper::util::csv::CsvWriter;
use amper::util::Rng;

fn main() {
    let _ = std::fs::create_dir_all("results");
    let mut rng = Rng::new(7);
    let pri = fig7::priority_list(fig7::LIST_SIZE, &mut rng);
    let params = AmperParams {
        m: 20,
        lambda: 0.3,
        lambda_prime: 0.2,
        csp_cap: usize::MAX,
        ..Default::default()
    };

    // ---- KL table (the paper's Fig 7 numbers) --------------------------
    println!("== KL vs PER (nats), batch 64 x 100 runs, 10k priorities ==");
    let mut w =
        CsvWriter::create("results/fig7_kl_summary.csv", &["sampler", "kl_nats"])
            .unwrap();
    for s in [Sampler::Per, Sampler::Uniform, Sampler::AmperK, Sampler::AmperFr] {
        let kl = fig7::kl_vs_per(&pri, s, &params, 23);
        println!("KL({:<9} || per) = {kl:9.1}", s.name());
        w.write_row(&[s.name().to_string(), format!("{kl:.2}")]).unwrap();
    }
    w.flush().unwrap();

    // ---- heat maps (Fig 7b/c) ------------------------------------------
    let ms = [2usize, 4, 6, 8, 10, 12];
    let scales = [0.05f32, 0.10, 0.15, 0.20, 0.25];
    for (variant, tag) in [(Variant::Knn, "fig7b_knn"), (Variant::Frnn, "fig7c_frnn")] {
        let cells = fig7::heatmap(variant, &ms, &scales, 13);
        let mut w = CsvWriter::create(
            format!("results/{tag}_kl.csv"),
            &["m", "scale", "kl_nats"],
        )
        .unwrap();
        for c in &cells {
            w.write_nums(&[c.m as f64, c.scale as f64, c.kl_nats]).unwrap();
        }
        w.flush().unwrap();
        println!(
            "{tag}: corner KLs  (m=2,s=0.05) {:.0}  (m=12,s=0.25) {:.0}  -> results/{tag}_kl.csv",
            cells.iter().find(|c| c.m == 2 && c.scale == 0.05).unwrap().kl_nats,
            cells.iter().find(|c| c.m == 12 && c.scale == 0.25).unwrap().kl_nats,
        );
    }

    // ---- Fig 7d ---------------------------------------------------------
    let cells =
        fig7::size_sweep(&[5_000, 10_000, 20_000], &[4, 8, 12], &[0.03, 0.09, 0.15], 17);
    let mut w = CsvWriter::create(
        "results/fig7d_size_sweep.csv",
        &["er_size", "m", "csp_ratio", "kl_nats"],
    )
    .unwrap();
    for c in &cells {
        w.write_nums(&[c.er_size as f64, c.m as f64, c.csp_ratio, c.kl_nats])
            .unwrap();
    }
    w.flush().unwrap();
    println!("fig7d -> results/fig7d_size_sweep.csv");

    // ---- sampler cost on this host (context for Fig 4/9 claims) --------
    println!("\n== host-side cost per batch-64 sample (10k priorities) ==");
    let mut b = Bench::with_config(BenchConfig {
        warmup_ms: 150,
        samples: 40,
        iters_per_sample: 4,
    });
    let mut tree = SumTree::new(pri.len());
    for (i, &p) in pri.iter().enumerate() {
        tree.set(i, p as f64);
    }
    let mut r = Rng::new(1);
    b.case("per: sum-tree sample x64", || {
        let mut acc = 0usize;
        for _ in 0..64 {
            acc ^= tree.find(r.f64() * tree.total());
        }
        black_box(acc)
    });
    let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
    let mut buf = Vec::new();
    for (variant, name) in [(Variant::Knn, "amper-k"), (Variant::Frnn, "amper-fr")] {
        let p2 = params;
        b.case(&format!("{name}: CSP build + draw x64 (software)"), || {
            buf.clear();
            csp::build_csp(&pri, &pri_q, &p2, variant, &mut r, &mut buf);
            black_box(csp::draw_batch(&buf, pri.len(), 64, &mut r).len())
        });
    }
    b.write_csv("results/fig7_sampler_costs.csv").ok();
}
