//! Fig 4 — DQN execution-latency breakdown (UER vs PER across ER sizes)
//! through the full three-layer stack. Requires `make artifacts`.
//!
//! The paper's finding to reproduce: the ER-operation share grows with
//! memory size under PER (tree depth) and dwarfs UER's; at 1e5 entries it
//! approaches half of the non-train step cost on their GPU setup.
//!
//! Run: `cargo bench --bench fig4_breakdown` (AMPER_FIG4_STEPS to resize)

use amper::studies::fig4;
use amper::util::csv::CsvWriter;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("fig4_breakdown: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }
    let steps: u64 = std::env::var("AMPER_FIG4_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000);
    let _ = std::fs::create_dir_all("results");
    let mut w = CsvWriter::create(
        "results/fig4_breakdown.csv",
        &[
            "env", "replay", "er_size", "steps", "store_share", "er_op_share",
            "train_share", "action_share", "er_op_mean_ns",
        ],
    )
    .unwrap();

    // CartPole (small MLP) and the Pong proxy (large MLP), UER vs PER.
    for (env, sizes) in [
        ("cartpole", vec![1_000usize, 10_000, 100_000]),
        ("pongproxy", vec![10_000usize, 100_000]),
    ] {
        let env_steps = if env == "pongproxy" { steps.min(600) } else { steps };
        match fig4::breakdown_grid(env, &sizes, env_steps, 0) {
            Ok(rows) => {
                fig4::print_rows(&rows);
                for r in &rows {
                    w.write_row(&[
                        r.env.clone(),
                        r.replay.to_string(),
                        r.er_size.to_string(),
                        r.steps.to_string(),
                        format!("{:.4}", r.shares[0]),
                        format!("{:.4}", r.shares[1]),
                        format!("{:.4}", r.shares[2]),
                        format!("{:.4}", r.shares[3]),
                        format!("{:.1}", r.er_op_mean_ns),
                    ])
                    .unwrap();
                }
            }
            Err(e) => eprintln!("{env}: {e:#}"),
        }
    }
    w.flush().unwrap();
    println!("\nCSV -> results/fig4_breakdown.csv");
}
