//! Fig 9 — end-to-end per-batch sampling latency (modeled accelerator vs
//! paper GPU reference vs measured host sum-tree PER).
//!
//! Regenerates all three panels as printed series + CSVs under results/.
//!
//! Run: `cargo bench --bench fig9_latency`

use amper::bench_harness::fmt_ns;
use amper::hardware::gpu_model;
use amper::studies::fig9;
use amper::util::csv::CsvWriter;

fn main() {
    let _ = std::fs::create_dir_all("results");
    let batch = 64;

    for (rows, tag, desc) in [
        (fig9::fig9a(batch, 1), "fig9a_vs_gpu", "Fig 9a: vs GPU (m=20, ratio 0.15)"),
        (fig9::fig9b(batch, 2), "fig9b_group_sweep", "Fig 9b: vs group number m"),
        (fig9::fig9c(batch, 3), "fig9c_csp_sweep", "Fig 9c: vs CSP ratio"),
    ] {
        println!("\n== {desc} ==");
        let mut w = CsvWriter::create(
            format!("results/{tag}.csv"),
            &["er_size", "m", "csp_ratio", "variant", "latency_ns", "csp_len"],
        )
        .unwrap();
        for r in &rows {
            w.write_row(&[
                r.er_size.to_string(),
                r.m.to_string(),
                format!("{:.2}", r.csp_ratio),
                r.variant.to_string(),
                format!("{:.1}", r.latency_ns),
                r.csp_len.to_string(),
            ])
            .unwrap();
            println!(
                "er={:<6} m={:<2} ratio={:.2} {:<18} {:>12}",
                r.er_size,
                r.m,
                r.csp_ratio,
                r.variant,
                fmt_ns(r.latency_ns)
            );
        }
        w.flush().unwrap();
    }

    println!("\n== headline speedups (paper: k 55-170x, fr 118-270x) ==");
    let rows = fig9::fig9a(batch, 1);
    for &size in &gpu_model::FIG9A_SIZES {
        let get = |v: &str| {
            rows.iter()
                .find(|r| r.er_size == size && r.variant == v)
                .unwrap()
                .latency_ns
        };
        println!(
            "ER {size:>6}: vs paper-GPU  k={:>5.0}x fr={:>5.0}x | vs measured-CPU-PER  k={:>5.1}x fr={:>5.1}x",
            get("per-gpu(paper)") / get("amper-k"),
            get("per-gpu(paper)") / get("amper-fr"),
            get("per-cpu(measured)") / get("amper-k"),
            get("per-cpu(measured)") / get("amper-fr"),
        );
    }
    println!("\nCSVs -> results/fig9*.csv");
}
