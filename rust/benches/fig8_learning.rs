//! Fig 8 / Table 1 — DQN learning performance, short-horizon rendition.
//!
//! The full-budget runs are `amper suite` (hours); this bench target runs
//! the same 4-env × 3-replay grid with a reduced step budget so the table
//! regenerates in minutes and the *ordering* (AMPER ≈ PER, both ≫ start)
//! is visible. Requires `make artifacts`.
//!
//! Env overrides: AMPER_FIG8_STEPS (default 4000), AMPER_FIG8_SEEDS.

use amper::replay::ReplayKind;
use amper::studies::table1;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("fig8_learning: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }
    let steps: u64 = std::env::var("AMPER_FIG8_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4000);
    let seeds: Vec<u64> = std::env::var("AMPER_FIG8_SEEDS")
        .unwrap_or_else(|_| "0".into())
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let _ = std::fs::create_dir_all("results");

    let presets = [
        "cartpole-2000",
        "cartpole-5000",
        "acrobot-10000",
        "mountaincar-10000",
    ];
    let kinds = [ReplayKind::Per, ReplayKind::AmperK, ReplayKind::AmperFr];
    match table1::table1(
        &presets,
        &kinds,
        &seeds,
        Some(steps),
        Some("results/fig8_curves.csv"),
    ) {
        Ok(rows) => {
            println!(
                "\n== Table 1 (short horizon: {steps} steps, {} seed(s)) ==",
                seeds.len()
            );
            table1::print_table(&rows);
            println!("\ncurves -> results/fig8_curves.csv");
        }
        Err(e) => eprintln!("fig8_learning failed: {e:#}"),
    }
}
