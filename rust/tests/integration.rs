//! Cross-layer integration tests: the Rust coordinator driving the
//! native DQN engine, the agent learning loop, the sharded replay
//! service under a real env driver, and a bit-level cross-check of the
//! TCAM search fabric.
//!
//! The engine is spec-driven: when `artifacts/manifest.json` exists
//! (built by `make artifacts`) its network dims win; otherwise the
//! built-in env specs apply, so these tests run on a clean checkout.
//! Heavy learning tests gate on release builds — `cargo test --release`
//! exercises them; debug runs keep the suite fast. (The Pallas-kernel
//! vs Rust bit cross-check lives in `python/tests/test_kernel.py`; the
//! PJRT execution path was replaced by the native engine.)

use std::path::PathBuf;
use std::sync::OnceLock;

use amper::agent::DqnAgent;
use amper::config::TrainConfig;
use amper::coordinator::{ShardedReplayService, VectorEnvDriver};
use amper::replay::{global_index, ReplayKind};
use amper::util::Rng;

/// A small spec manifest (hidden 64, batch 32) written once to a temp
/// dir: integration trains stay fast in debug while exercising the real
/// manifest-loading path.
fn test_artifacts_dir() -> &'static str {
    static DIR: OnceLock<String> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir: PathBuf =
            std::env::temp_dir().join(format!("amper-test-artifacts-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create test artifacts dir");
        let manifest = r#"{
            "version": 1,
            "envs": {
                "cartpole": {
                    "obs_dim": 4, "n_actions": 2, "hidden": 64, "batch": 32,
                    "gamma": 0.99, "lr": 0.001, "double_dqn": true,
                    "dims": [4, 64, 64, 2],
                    "train_artifact": "cartpole_train.hlo.txt",
                    "act_artifact": "cartpole_act.hlo.txt"
                }
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest)
            .expect("write test manifest");
        dir.to_string_lossy().into_owned()
    })
}

fn smoke_config(replay: ReplayKind, steps: u64) -> TrainConfig {
    TrainConfig {
        env: "cartpole".into(),
        replay,
        er_size: 500,
        steps,
        warmup: 150,
        eps_decay_steps: steps / 2,
        target_sync: 200,
        test_episodes: 5,
        seed: 0,
        artifacts_dir: test_artifacts_dir().to_string(),
        ..Default::default()
    }
}

#[test]
fn agent_runs_with_every_replay_kind() {
    for d in amper::replay::registry::all() {
        let kind = ReplayKind::from_name(d.name);
        let mut agent = DqnAgent::new(smoke_config(kind, 600)).unwrap();
        let report = agent.run().unwrap();
        assert_eq!(report.steps, 600);
        assert!(report.returns.n_episodes() > 0, "{kind:?}: no episodes");
        assert!(
            report.losses.iter().all(|l| l.is_finite()),
            "{kind:?}: non-finite loss"
        );
        assert!(report.profile.count(amper::profiling::Phase::Train) > 0);
    }
}

#[test]
fn cartpole_learns_above_random_baseline() {
    if cfg!(debug_assertions) {
        return; // heavy: release-only (cargo test --release)
    }
    // random policy on CartPole scores ~20-25 per episode
    let mut agent = DqnAgent::new(smoke_config(ReplayKind::AmperFr, 6000)).unwrap();
    let report = agent.run().unwrap();
    assert!(
        report.test_score > 60.0,
        "test score {} not above random baseline",
        report.test_score
    );
}

#[test]
fn per_and_amper_learn_comparably_on_smoke_horizon() {
    if cfg!(debug_assertions) {
        return; // heavy: release-only (cargo test --release)
    }
    // Table 1's qualitative claim on a tiny budget: AMPER within a
    // factor of the PER score (loose—short horizon is noisy).
    let score = |kind| {
        let mut agent = DqnAgent::new(smoke_config(kind, 4000)).unwrap();
        agent.run().unwrap().test_score
    };
    let per = score(ReplayKind::Per);
    let fr = score(ReplayKind::AmperFr);
    assert!(per > 40.0, "PER failed to learn at all: {per}");
    assert!(fr > per * 0.33, "AMPER-fr {fr} collapsed vs PER {per}");
}

#[test]
fn epsilon_schedule_decays() {
    let config = smoke_config(ReplayKind::Uniform, 10);
    let agent = DqnAgent::new(config).unwrap();
    assert!((agent.epsilon() - 1.0).abs() < 1e-5);
}

#[test]
fn tcam_bank_matches_linear_ternary_scan() {
    // Bit-level cross-check of the TCAM search fabric: the bank's
    // array-parallel exact-match must agree with a linear ternary scan
    // on random contents for every prefix width. (The same contract is
    // checked against the Pallas tcam_match kernel in
    // python/tests/test_kernel.py.)
    let n = 8192usize;
    let mut rng = Rng::new(99);
    let rows: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let mut bank = amper::hardware::TcamBank::new(n);
    for (i, &r) in rows.iter().enumerate() {
        bank.write(i, r);
    }
    for prefix_bits in [32u32, 24, 16, 8, 4] {
        let query = rows[rng.below(n)];
        let qcare: u32 = (!0u32) << (32 - prefix_bits);
        let mut hw = Vec::new();
        bank.search_exact(query & qcare, qcare, usize::MAX, &mut hw);
        let want: Vec<usize> = (0..n)
            .filter(|&i| (rows[i] ^ query) & qcare == 0)
            .collect();
        assert_eq!(hw, want, "prefix {prefix_bits}: bank vs linear scan");
        assert!(!hw.is_empty(), "query must match itself");
    }
}

#[test]
fn builtin_specs_match_env_spaces() {
    for name in ["cartpole", "acrobot", "lunarlander", "mountaincar"] {
        let spec = amper::runtime::EnvArtifacts::builtin(name).unwrap();
        let env = amper::envs::make(name).unwrap();
        assert_eq!(env.obs_dim(), spec.obs_dim, "{name}");
        assert_eq!(env.n_actions(), spec.n_actions, "{name}");
    }
}

#[test]
fn repo_manifest_matches_env_spaces_if_present() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return; // artifacts not built
    }
    let manifest = amper::runtime::Manifest::load(&dir).unwrap();
    for name in ["cartpole", "acrobot", "lunarlander", "mountaincar"] {
        let spec = manifest.env(name).unwrap();
        let env = amper::envs::make(name).unwrap();
        assert_eq!(env.obs_dim(), spec.obs_dim, "{name}");
        assert_eq!(env.n_actions(), spec.n_actions, "{name}");
    }
}

#[test]
fn acrobot_engine_roundtrip() {
    let engine =
        amper::runtime::Engine::load(std::path::Path::new("no-artifacts"), "acrobot")
            .unwrap();
    let spec = engine.spec().clone();
    let mut state = amper::runtime::TrainState::init(&spec, 3).unwrap();
    let mut batch = amper::runtime::TrainBatch::zeros(spec.batch, spec.obs_dim);
    let mut rng = Rng::new(4);
    for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
        *x = rng.normal_f32(0.0, 1.0);
    }
    for (i, a) in batch.actions.iter_mut().enumerate() {
        *a = (i % spec.n_actions) as i32;
    }
    let out = engine.train_step(&mut state, &batch).unwrap();
    assert_eq!(out.td.len(), spec.batch);
    assert!(out.loss.is_finite());
}

#[test]
fn sharded_service_serves_real_env_traffic() {
    // actors ingest real CartPole transitions across 4 shards while the
    // test thread drains gathered batches and routes TD errors back
    let svc = ShardedReplayService::spawn_partitioned(8192, 4, 1024, 0, |_, cap| {
        amper::replay::make(ReplayKind::Per, cap)
    });
    // batched ingest: actors flush 16-row PushBatch commands, split into
    // per-shard sub-batches by the handle
    let driver = VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 42, 16);
    let h = svc.handle();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut batches = 0usize;
    while batches < 20 && std::time::Instant::now() < deadline {
        let b = h.sample_gathered(64).expect("gather failed");
        if b.indices.is_empty() {
            std::thread::yield_now();
            continue;
        }
        assert_eq!(b.obs.len(), b.indices.len() * 4);
        let n = b.indices.len();
        assert!(h.update_priorities(b.indices, vec![0.5; n]));
        batches += 1;
    }
    assert!(batches >= 20, "only {batches} gathered batches served");
    let steps = driver.stop();
    assert!(steps > 0);
    let mems = svc.stop();
    let total: usize = mems.iter().map(|m| m.len()).sum();
    assert!(total > 0);
    // shards stay balanced under round-robin ingest
    let max = mems.iter().map(|m| m.len()).max().unwrap();
    let min = mems.iter().map(|m| m.len()).min().unwrap();
    assert!(max - min <= 1, "unbalanced shards: {max} vs {min}");
    // and all sampled indices decoded to live shards (implicitly checked
    // by update_priorities routing); spot-check the encoding space
    assert!(global_index::MAX_SHARDS >= 4);
}
