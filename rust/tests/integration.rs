//! Cross-layer integration tests: the Rust coordinator driving the
//! PJRT-compiled JAX/Pallas artifacts, the agent learning loop, and a
//! bit-level three-layer cross-check of the TCAM search (Rust functional
//! sim vs the Pallas `tcam_match` kernel lowered to HLO).
//!
//! Tests skip silently when `artifacts/` has not been built
//! (`make artifacts`).

use std::path::{Path, PathBuf};

use amper::agent::DqnAgent;
use amper::config::TrainConfig;
use amper::replay::ReplayKind;
use amper::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn smoke_config(replay: ReplayKind, steps: u64) -> TrainConfig {
    TrainConfig {
        env: "cartpole".into(),
        replay,
        er_size: 500,
        steps,
        warmup: 150,
        eps_decay_steps: steps / 2,
        target_sync: 200,
        test_episodes: 5,
        seed: 0,
        artifacts_dir: artifacts_dir().unwrap().to_string_lossy().into_owned(),
        ..Default::default()
    }
}

#[test]
fn agent_runs_with_every_replay_kind() {
    if artifacts_dir().is_none() {
        return;
    }
    for kind in ReplayKind::ALL {
        let mut agent = DqnAgent::new(smoke_config(kind, 600)).unwrap();
        let report = agent.run().unwrap();
        assert_eq!(report.steps, 600);
        assert!(report.returns.n_episodes() > 0, "{kind:?}: no episodes");
        assert!(
            report.losses.iter().all(|l| l.is_finite()),
            "{kind:?}: non-finite loss"
        );
        assert!(report.profile.count(amper::profiling::Phase::Train) > 0);
    }
}

#[test]
fn cartpole_learns_above_random_baseline() {
    if artifacts_dir().is_none() {
        return;
    }
    // random policy on CartPole scores ~20-25 per episode
    let mut agent = DqnAgent::new(smoke_config(ReplayKind::AmperFr, 4000)).unwrap();
    let report = agent.run().unwrap();
    assert!(
        report.test_score > 60.0,
        "test score {} not above random baseline",
        report.test_score
    );
}

#[test]
fn per_and_amper_learn_comparably_on_smoke_horizon() {
    if artifacts_dir().is_none() {
        return;
    }
    // Table 1's qualitative claim on a tiny budget: AMPER within a
    // factor of the PER score (loose—short horizon is noisy).
    let score = |kind| {
        let mut agent = DqnAgent::new(smoke_config(kind, 3000)).unwrap();
        agent.run().unwrap().test_score
    };
    let per = score(ReplayKind::Per);
    let fr = score(ReplayKind::AmperFr);
    assert!(per > 40.0, "PER failed to learn at all: {per}");
    assert!(fr > per * 0.33, "AMPER-fr {fr} collapsed vs PER {per}");
}

#[test]
fn epsilon_schedule_decays() {
    if artifacts_dir().is_none() {
        return;
    }
    let config = smoke_config(ReplayKind::Uniform, 10);
    let agent = DqnAgent::new(config).unwrap();
    assert!((agent.epsilon() - 1.0).abs() < 1e-5);
}

#[test]
fn tcam_artifact_matches_rust_functional_sim() {
    // THE hw-codesign cross-check: the Pallas ternary-match kernel
    // (L1, lowered through L2 to HLO and executed via PJRT) must agree
    // bit-for-bit with the Rust TcamBank functional simulation (L3).
    let Some(dir) = artifacts_dir() else { return };
    let path = dir.join("tcam_search_8192.hlo.txt");
    if !path.exists() {
        return;
    }
    let n = 8192usize;
    let client = xla::PjRtClient::cpu().unwrap();
    let proto =
        xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();

    let mut rng = Rng::new(99);
    let rows: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let care = vec![u32::MAX; n];

    let mut bank = amper::hardware::TcamBank::new(n);
    for (i, &r) in rows.iter().enumerate() {
        bank.write(i, r);
    }

    for prefix_bits in [32u32, 24, 16, 8] {
        let query = rows[rng.below(n)];
        let qcare: u32 = if prefix_bits == 0 {
            0
        } else {
            (!0u32) << (32 - prefix_bits)
        };
        // L1/L2 path
        let rows_l = xla::Literal::vec1(&rows);
        let care_l = xla::Literal::vec1(&care);
        let q_l = xla::Literal::vec1(&[query]);
        let qc_l = xla::Literal::vec1(&[qcare]);
        let result = exe
            .execute::<xla::Literal>(&[rows_l, care_l, q_l, qc_l])
            .unwrap();
        let out = result[0][0].to_literal_sync().unwrap();
        let parts = out.to_tuple().unwrap();
        let match_vec = parts[0].to_vec::<u32>().unwrap();
        // L3 functional sim
        let mut hw = Vec::new();
        bank.search_exact(query & qcare, qcare, usize::MAX, &mut hw);
        let pallas_matches: Vec<usize> = match_vec
            .iter()
            .enumerate()
            .filter(|(_, &m)| m == 1)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(
            pallas_matches, hw,
            "prefix {prefix_bits}: Pallas kernel and Rust TCAM disagree"
        );
        assert!(!pallas_matches.is_empty(), "query must match itself");
    }
}

#[test]
fn all_envs_have_matching_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = amper::runtime::Manifest::load(&dir).unwrap();
    for name in ["cartpole", "acrobot", "lunarlander", "mountaincar"] {
        let spec = manifest.env(name).unwrap();
        let env = amper::envs::make(name).unwrap();
        assert_eq!(env.obs_dim(), spec.obs_dim, "{name}");
        assert_eq!(env.n_actions(), spec.n_actions, "{name}");
    }
}

#[test]
fn acrobot_engine_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = amper::runtime::Engine::load(&dir, "acrobot").unwrap();
    let spec = engine.spec().clone();
    let mut state = amper::runtime::TrainState::init(&spec, 3).unwrap();
    let mut batch = amper::runtime::TrainBatch::zeros(spec.batch, spec.obs_dim);
    let mut rng = Rng::new(4);
    for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
        *x = rng.normal_f32(0.0, 1.0);
    }
    for (i, a) in batch.actions.iter_mut().enumerate() {
        *a = (i % spec.n_actions) as i32;
    }
    let out = engine.train_step(&mut state, &batch).unwrap();
    assert_eq!(out.td.len(), spec.batch);
    assert!(out.loss.is_finite());
}
