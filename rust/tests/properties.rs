//! Property-based invariant tests (in-repo `prop` framework) over the
//! replay memories, the AMPER selection math, and the hardware sim.

use amper::hardware::accelerator::{AccelConfig, AmperAccelerator};
use amper::hardware::query_gen;
use amper::prop::{property, property_res};
use amper::replay::amper::{csp, frnn, quant, AmperParams, Variant};
use amper::replay::{self, Experience, ReplayKind, SumTree};

fn exp(dim: usize, v: f32) -> Experience {
    Experience {
        obs: vec![v; dim],
        action: 0,
        reward: v,
        next_obs: vec![v + 1.0; dim],
        done: false,
    }
}

#[test]
fn prop_sum_tree_total_equals_leaf_sum() {
    property("sum tree total == Σ leaves under random ops", |g| {
        let n = g.usize_in(1..200);
        let mut tree = SumTree::new(n);
        let mut shadow = vec![0.0f64; n];
        for _ in 0..g.usize_in(1..500) {
            let i = g.usize_in(0..n);
            let p = g.f64_in(0.0, 10.0);
            tree.set(i, p);
            shadow[i] = p;
        }
        let want: f64 = shadow.iter().sum();
        (tree.total() - want).abs() < 1e-6 * (1.0 + want)
    });
}

#[test]
fn prop_sum_tree_find_is_consistent_with_prefix_sums() {
    property_res("find(y) returns the leaf whose range contains y", |g| {
        let n = g.usize_in(1..100);
        let mut tree = SumTree::new(n);
        let mut ps = vec![0.0f64; n];
        for i in 0..n {
            ps[i] = g.f64_in(0.0, 5.0);
            tree.set(i, ps[i]);
        }
        let total: f64 = ps.iter().sum();
        if total <= 0.0 {
            return Ok(());
        }
        let y = g.f64_in(0.0, total * 0.999);
        let leaf = tree.find(y);
        let before: f64 = ps[..leaf].iter().sum();
        if y < before - 1e-6 || y >= before + ps[leaf] + 1e-6 {
            return Err(format!(
                "y={y} leaf={leaf} range=[{before},{})",
                before + ps[leaf]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_monotone_and_tight() {
    property("quantization is monotone with bounded error", |g| {
        let a = g.f32_in(0.0, 1000.0);
        let b = g.f32_in(0.0, 1000.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let ql = quant::quantize(lo);
        let qh = quant::quantize(hi);
        ql <= qh && (quant::dequantize(ql) - lo).abs() <= 1.0 / quant::SCALE
    });
}

#[test]
fn prop_prefix_query_block_contains_v_and_radius_side() {
    property_res("prefix block is pow2-aligned and contains V", |g| {
        let v = g.f32_in(0.0, 2.0);
        let delta = g.f32_in(0.0, 0.5);
        let (word, care) = frnn::prefix_query(v, delta);
        let (base, size) = frnn::accepted_range(word, care);
        let qv = quant::quantize(v);
        if (qv & care) != word {
            return Err("v does not match its own query".into());
        }
        if qv < base || (qv as u64) >= base as u64 + size {
            return Err(format!("v {qv} outside block [{base}, {base}+{size})"));
        }
        if !size.is_power_of_two() {
            return Err(format!("block size {size} not a power of two"));
        }
        // the block must be at least as wide as Δ (it may snap larger)
        let qd = quant::quantize(delta) as u64;
        if size < qd.max(1) && care != u32::MAX {
            return Err(format!("block {size} narrower than Δ {qd}"));
        }
        Ok(())
    });
}

#[test]
fn prop_frnn_selection_equals_tcam_scan() {
    property_res("software frNN == linear ternary-match scan", |g| {
        let n = g.usize_in(1..400);
        let pri: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let mut order: Vec<(f32, usize)> = pri.iter().copied().zip(0..n).collect();
        order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let v = g.f32_in(0.0, 1.0);
        let delta = g.f32_in(0.0, 0.2);
        let mut got = Vec::new();
        frnn::select_frnn(&order, &pri_q, v, delta, usize::MAX, &mut got);
        got.sort_unstable();
        let (word, care) = frnn::prefix_query(v, delta);
        let mut want: Vec<usize> =
            (0..n).filter(|&i| (pri_q[i] ^ word) & care == 0).collect();
        want.sort_unstable();
        if got != want {
            return Err(format!("v={v} delta={delta}: {got:?} != {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_accelerator_frnn_matches_software_selection() {
    property_res("hardware frNN CSP ⊆ software selection, same queries", |g| {
        let n = 64 * g.usize_in(1..8);
        let pri: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let config = AccelConfig {
            m: g.usize_in(1..12),
            lambda: 0.3,
            lambda_prime: g.f32_in(0.01, 0.4),
            csb_capacity: usize::MAX,
        };
        let mut acc = AmperAccelerator::new(n, config, 0xBEEF);
        for (i, &p) in pri.iter().enumerate() {
            acc.write_priority(i, p);
        }
        let mut events = Default::default();
        let reps = acc.draw_representatives(&mut events);
        acc.build_csp(Variant::Frnn, &reps);
        let mut hw: Vec<usize> = Vec::new();
        // software selection for the same representatives
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let lpm_q = quant::quantize(config.lambda_prime / config.m as f32);
        let mut sw = Vec::new();
        for &v_q in &reps {
            let delta_q = query_gen::frnn_delta(lpm_q, v_q);
            let (word, care) = query_gen::frnn_query(v_q, delta_q);
            for i in 0..n {
                if (pri_q[i] ^ word) & care == 0 {
                    sw.push(i);
                }
            }
        }
        sw.sort_unstable();
        sw.dedup();
        // and the accelerator again with identical reps
        let mut acc2 = AmperAccelerator::new(n, config, 0xBEEF);
        for (i, &p) in pri.iter().enumerate() {
            acc2.write_priority(i, p);
        }
        acc2.build_csp(Variant::Frnn, &reps);
        let out = acc2.sample(8, Variant::Frnn);
        hw.extend(out.indices.iter().copied());
        for &slot in &hw {
            if !sw.contains(&slot) && !sw.is_empty() {
                return Err(format!("hw slot {slot} not in software selection"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_replay_samples_always_in_range() {
    property("every sampled index addresses a stored experience", |g| {
        // draw from every registered technique, new ones included
        let kinds = amper::replay::registry::all();
        let kind = ReplayKind::from_name(kinds[g.usize_in(0..kinds.len())].name);
        let cap = g.usize_in(1..300);
        let pushes = g.usize_in(1..600);
        let mut mem = replay::make(kind, cap);
        let mut rng = amper::util::Rng::new(g.u64());
        for i in 0..pushes {
            mem.push(exp(3, i as f32), &mut rng);
        }
        let n = mem.len();
        let batch = g.usize_in(1..128);
        let b = mem.sample(batch, &mut rng);
        b.indices.len() == batch && b.indices.iter().all(|&i| i < n)
    });
}

#[test]
fn prop_replay_priority_update_roundtrip() {
    property("updated priorities readable and positive", |g| {
        let kind = if g.bool() { ReplayKind::Per } else { ReplayKind::AmperFr };
        let n = g.usize_in(1..200);
        let mut mem = replay::make(kind, n);
        let mut rng = amper::util::Rng::new(g.u64());
        for i in 0..n {
            mem.push(exp(2, i as f32), &mut rng);
        }
        let indices: Vec<usize> = (0..n).collect();
        let tds: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
        mem.update_priorities(&indices, &tds);
        (0..n).all(|i| {
            let p = mem.priority_of(i);
            p > 0.0 && p.is_finite()
        })
    });
}

#[test]
fn prop_csp_draw_covers_only_csp_members() {
    property("batch draws come from the CSP (or uniform fallback)", |g| {
        let n = g.usize_in(1..500);
        let pri: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let params = AmperParams {
            m: g.usize_in(1..16),
            lambda: g.f32_in(0.01, 1.0),
            lambda_prime: g.f32_in(0.01, 0.5),
            csp_cap: g.usize_in(1..5000),
            ..Default::default()
        };
        let variant = if g.bool() { Variant::Knn } else { Variant::Frnn };
        let mut rng = amper::util::Rng::new(g.u64());
        let mut buf = Vec::new();
        csp::build_csp(&pri, &pri_q, &params, variant, &mut rng, &mut buf);
        if buf.len() > params.csp_cap {
            return false;
        }
        let drawn = csp::draw_batch(&buf, n, 32, &mut rng);
        if buf.is_empty() {
            drawn.iter().all(|&i| i < n)
        } else {
            drawn.iter().all(|i| buf.contains(i))
        }
    });
}

#[test]
fn prop_reply_pool_accounting_identity_under_random_ops() {
    use amper::coordinator::ReplyPool;
    use amper::replay::GatheredBatch;
    use std::sync::atomic::Ordering;
    property_res("take/put/note_lost interleavings keep the pool identities", |g| {
        let pool = ReplyPool::new(g.usize_in(0..6));
        // buffers currently lent out (a miss "allocates" one, like the
        // worker does); every one must settle via put or note_lost
        let mut outstanding: Vec<GatheredBatch> = Vec::new();
        let mut takes = 0u64;
        let mut settles = 0u64;
        for _ in 0..g.usize_in(1..300) {
            match g.usize_in(0..6) {
                0 | 1 => {
                    let buf = pool.take().unwrap_or_else(|| {
                        let mut b = GatheredBatch::default();
                        if g.bool() {
                            b.reset(g.usize_in(1..16), g.usize_in(1..8));
                        }
                        b
                    });
                    takes += 1;
                    outstanding.push(buf);
                }
                2 | 3 => {
                    if let Some(b) = outstanding.pop() {
                        pool.put(b);
                        settles += 1;
                    }
                }
                4 => {
                    // fault path: the buffer never comes back (timeout,
                    // dead worker) — the owner accounts it as lost
                    if outstanding.pop().is_some() {
                        pool.note_lost();
                        settles += 1;
                    }
                }
                _ => pool.set_capacity(g.usize_in(0..6)),
            }
            if pool.idle() > pool.capacity() {
                return Err(format!(
                    "idle {} exceeds capacity {}",
                    pool.idle(),
                    pool.capacity()
                ));
            }
        }
        while let Some(b) = outstanding.pop() {
            pool.put(b);
            settles += 1;
        }
        let s = pool.stats();
        let hits = s.hits.load(Ordering::Relaxed);
        let misses = s.misses.load(Ordering::Relaxed);
        let recycled = s.recycled.load(Ordering::Relaxed);
        let dropped = s.dropped.load(Ordering::Relaxed);
        if hits + misses != takes {
            return Err(format!("hits {hits} + misses {misses} != takes {takes}"));
        }
        if recycled + dropped != settles {
            return Err(format!(
                "recycled {recycled} + dropped {dropped} != settles {settles}"
            ));
        }
        // a hit pops a buffer that some earlier put pooled
        if hits > recycled {
            return Err(format!("hits {hits} exceed recycled {recycled}"));
        }
        Ok(())
    });
}

#[test]
fn prop_reply_pool_hits_always_carry_capacity() {
    use amper::coordinator::ReplyPool;
    use amper::replay::GatheredBatch;
    property("a pool hit returns a buffer that can refill in place", |g| {
        let pool = ReplyPool::new(g.usize_in(1..8));
        for _ in 0..g.usize_in(1..150) {
            if g.bool() {
                // served replies come back warm; learner warmup loops
                // also recycle capacity-less empties — the pool must
                // only ever hand the former back out
                let mut b = GatheredBatch::default();
                b.reset(g.usize_in(1..16), g.usize_in(1..8));
                pool.put(b);
            } else {
                pool.put(GatheredBatch::default());
            }
            if g.bool() {
                if let Some(b) = pool.take() {
                    if b.obs.capacity() == 0 && b.indices.capacity() == 0 {
                        return false; // this "hit" would still allocate
                    }
                    pool.put(b);
                }
            }
        }
        true
    });
}

#[test]
fn prop_wire_push_batch_roundtrip_bit_identical() {
    use amper::net::wire;
    use amper::replay::ExperienceBatch;
    property_res("arbitrary batches encode→decode bit-identical", |g| {
        // arbitrary bit patterns (including NaN/inf/-0.0): the wire
        // must reproduce every f32 by bits, not by value
        let f = |g: &mut amper::prop::Gen| f32::from_bits(g.u64() as u32);
        let obs_dim = g.usize_in(1..8);
        let rows = g.usize_in(0..50);
        let mut b = ExperienceBatch::with_capacity(obs_dim, rows);
        for _ in 0..rows {
            let obs: Vec<f32> = (0..obs_dim).map(|_| f(g)).collect();
            let next: Vec<f32> = (0..obs_dim).map(|_| f(g)).collect();
            b.push_parts(&obs, g.u64() as u32, f(g), &next, g.bool());
        }
        let mut buf = Vec::new();
        wire::encode_push_batch(&mut buf, &b);
        let d = wire::decode_push_batch(&buf).map_err(|e| e.to_string())?;
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        if d.len() != b.len() || d.obs_dim() != b.obs_dim() {
            return Err("shape mismatch".into());
        }
        if bits(d.obs_flat()) != bits(b.obs_flat())
            || bits(d.next_obs_flat()) != bits(b.next_obs_flat())
            || bits(d.rewards()) != bits(b.rewards())
            || d.actions() != b.actions()
            || d.dones() != b.dones()
        {
            return Err("column mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_wire_gathered_roundtrip_bit_identical_into_warm_buffer() {
    use amper::net::wire;
    use amper::replay::GatheredBatch;
    property_res("gathered replies decode bit-identical into pooled buffers", |g| {
        let f = |g: &mut amper::prop::Gen| f32::from_bits(g.u64() as u32);
        let obs_dim = g.usize_in(1..8);
        let rows = g.usize_in(0..40);
        let mut src = GatheredBatch::default();
        src.reset(rows, obs_dim);
        for i in 0..rows {
            src.indices[i] = g.usize_in(0..1 << 40);
            src.is_weights[i] = f(g);
            src.actions[i] = g.u64() as i32;
            src.rewards[i] = f(g);
            src.dones[i] = f(g);
        }
        for x in src.obs.iter_mut().chain(src.next_obs.iter_mut()) {
            *x = f(g);
        }
        let mut buf = Vec::new();
        wire::encode_gathered(&mut buf, &src);
        // decode into a warm buffer of unrelated prior shape (the pool
        // path) and into a fresh allocation — both must be bit-exact
        let mut warm = GatheredBatch::default();
        warm.reset(g.usize_in(0..64), g.usize_in(1..10));
        wire::decode_gathered_into(&buf, &mut warm).map_err(|e| e.to_string())?;
        let fresh = wire::decode_gathered(&buf).map_err(|e| e.to_string())?;
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for d in [&warm, &fresh] {
            if d.rows() != rows || d.indices != src.indices {
                return Err("indices mismatch".into());
            }
            if bits(&d.obs) != bits(&src.obs)
                || bits(&d.next_obs) != bits(&src.next_obs)
                || bits(&d.is_weights) != bits(&src.is_weights)
                || bits(&d.rewards) != bits(&src.rewards)
                || bits(&d.dones) != bits(&src.dones)
                || d.actions != src.actions
            {
                return Err("column mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_update_priorities_roundtrip() {
    use amper::net::wire;
    property_res("priority updates roundtrip indices and TD bits", |g| {
        let n = g.usize_in(0..200);
        let indices: Vec<usize> = (0..n).map(|_| g.usize_in(0..1 << 44)).collect();
        let td: Vec<f32> = (0..n).map(|_| f32::from_bits(g.u64() as u32)).collect();
        let mut buf = Vec::new();
        wire::encode_update_priorities(&mut buf, &indices, &td);
        let (di, dt) = wire::decode_update_priorities(&buf).map_err(|e| e.to_string())?;
        if di != indices {
            return Err("indices mismatch".into());
        }
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        if bits(&dt) != bits(&td) {
            return Err("td bits mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_wire_truncated_frames_error_never_panic() {
    use amper::net::wire;
    use amper::replay::ExperienceBatch;
    property_res("any strict prefix of a valid frame reads as Err", |g| {
        let obs_dim = g.usize_in(1..5);
        let rows = g.usize_in(0..20);
        let mut b = ExperienceBatch::with_capacity(obs_dim, rows);
        for i in 0..rows {
            let v = i as f32;
            b.push_parts(&vec![v; obs_dim], 0, v, &vec![v + 1.0; obs_dim], false);
        }
        let mut payload = Vec::new();
        wire::encode_push_batch(&mut payload, &b);
        let mut frame = Vec::new();
        wire::write_frame(&mut frame, wire::Opcode::PushBatch, 3, &payload)
            .map_err(|e| e.to_string())?;
        let cut = g.usize_in(0..frame.len());
        let mut r = std::io::Cursor::new(&frame[..cut]);
        let mut out = Vec::new();
        if wire::read_frame(&mut r, &mut out).is_ok() {
            return Err(format!("cut at {cut}/{} still read a frame", frame.len()));
        }
        // a clean close at the frame boundary is Ok(None), not an error
        let mut r = std::io::Cursor::new(&frame[..0]);
        match wire::read_frame_opt(&mut r, &mut out) {
            Ok(None) => Ok(()),
            other => Err(format!("empty stream misread: {:?}", other.is_ok())),
        }
    });
}

#[test]
fn prop_wire_corrupt_payload_errors_or_decodes_faithfully() {
    use amper::net::wire;
    use amper::replay::ExperienceBatch;
    property_res("byte corruption: Err, or a decode that re-encodes the same", |g| {
        let obs_dim = g.usize_in(1..5);
        let rows = g.usize_in(1..20);
        let mut b = ExperienceBatch::with_capacity(obs_dim, rows);
        for i in 0..rows {
            let v = i as f32 * 0.25;
            b.push_parts(
                &vec![v; obs_dim],
                i as u32,
                v,
                &vec![v + 1.0; obs_dim],
                i % 3 == 0,
            );
        }
        let mut payload = Vec::new();
        wire::encode_push_batch(&mut payload, &b);
        let at = g.usize_in(0..payload.len());
        let flip = (g.u64() as u8) | 1; // never a no-op xor
        payload[at] ^= flip;
        match wire::decode_push_batch(&payload) {
            // structural corruption (header fields, done bytes) → Err
            Err(_) => Ok(()),
            // value corruption (inside a float/action column) must
            // decode to something that re-encodes byte-for-byte — the
            // wire never reinterprets or normalizes values
            Ok(d) => {
                let mut re = Vec::new();
                wire::encode_push_batch(&mut re, &d);
                if re == payload {
                    Ok(())
                } else {
                    Err(format!("lossy decode after flipping byte {at}"))
                }
            }
        }
    });
}

#[test]
fn prop_lfsr_distinct_from_recent_history() {
    property("LFSR words don't repeat in short windows", |g| {
        let mut lfsr = amper::hardware::Lfsr32::new(g.u64() as u32 | 1);
        let mut seen = std::collections::HashSet::new();
        (0..256).all(|_| seen.insert(lfsr.next_u32()))
    });
}
