//! Fault-injection suite for the replay services (requires the
//! `testing` cargo feature — `cargo test --features testing --test
//! fault_injection`).
//!
//! Each scenario wires a [`FaultPlan`] into one or more service workers
//! and asserts the recovery contract from README §Operability:
//!
//! * a **slow shard** truncates the merged batch instead of stalling the
//!   learner, with the loss accounted in `ServiceStats`;
//! * a **crashed worker** surfaces as an `Err` (never a panic, never a
//!   hang), the healthy shards drain, and no pooled buffer leaks —
//!   `hits + misses == recycled + dropped` at quiescence;
//! * a **full command queue** makes the adaptive actor flush grow
//!   toward `push_batch_max`, and `stop()` still drains cleanly;
//! * an abandoned **learner pipeline** settles its in-flight requests on
//!   drop at any depth, even mid-crash;
//! * a **killed net client** that vanishes mid-gather has its lent pool
//!   buffer recycled and its disconnect accounted, while the other
//!   tenants keep training against the same tier;
//! * a **stalled net client** that stops reading replies fails its own
//!   connection after `write_timeout` — never the server, never the
//!   healthy tenants.

#![cfg(feature = "testing")]

use std::sync::atomic::Ordering;
use std::time::Duration;

use amper::coordinator::{
    FaultPlan, FlushPolicy, GatherPipeline, PoolStats, ReplayService, ShardedReplayService,
    VectorEnvDriver,
};
use amper::replay::{self, Experience, PerParams, PerReplay, ReplayKind};

fn exp(v: f32) -> Experience {
    Experience {
        obs: vec![v; 4],
        action: 0,
        reward: v,
        next_obs: vec![v; 4],
        done: false,
    }
}

/// The quiescent pool identity: every take (hit or miss — a miss makes
/// the worker allocate the reply) settled in exactly one put or loss.
fn assert_pool_balanced(stats: &PoolStats, tag: &str) {
    let taken = stats.hits.load(Ordering::Relaxed) + stats.misses.load(Ordering::Relaxed);
    let settled = stats.recycled.load(Ordering::Relaxed) + stats.dropped.load(Ordering::Relaxed);
    assert_eq!(taken, settled, "{tag}: lent buffers not fully accounted");
}

/// A plan that stalls every gather on the worker it is given to.
fn slow_gather(delay_ms: u64) -> FaultPlan {
    FaultPlan { delay_gather: Some(Duration::from_millis(delay_ms)), ..FaultPlan::default() }
}

#[test]
fn slow_shard_truncates_the_merge_instead_of_stalling() {
    // shard 0 sleeps 200ms inside every gather; the handle's timeout is
    // 50ms, so its 16 rows are truncated while shards 1-3 serve theirs.
    // The merge consumes replies in completion order behind one shared
    // deadline: the fast shards' columns are copied while shard 0 is
    // still asleep, and the whole wait is bounded by a single timeout —
    // never one timeout per slow shard.
    let svc = ShardedReplayService::spawn_with_faults(
        4,
        256,
        1,
        |_| Box::new(PerReplay::new(128, PerParams::default())),
        |shard| {
            if shard == 0 {
                slow_gather(200)
            } else {
                FaultPlan::default()
            }
        },
    );
    let h = svc.handle();
    for i in 0..400 {
        assert!(h.push(exp(i as f32)));
    }
    h.set_gather_timeout(Duration::from_millis(50));
    let t = std::time::Instant::now();
    let g = h.sample_gathered(64).expect("slow shard must not fail the batch");
    let waited = t.elapsed();
    assert!(
        waited < Duration::from_millis(190),
        "wait must be bounded by the shared deadline, not the 200ms \
         sleeping shard (waited {waited:?})"
    );
    assert_eq!(g.rows(), 48, "three healthy shards serve 16 rows each");
    assert_eq!(g.obs.len(), 48 * 4, "columns truncated consistently");
    // compaction packs the healthy shards' rows in shard order, so
    // every surviving index decodes to a live shard (never shard 0)
    for &gi in &g.indices {
        let (shard, _) = amper::replay::traits::global_index::decode(gi);
        assert_ne!(shard, 0, "timed-out shard 0 must contribute no rows");
    }
    h.recycle(g);
    let stats = h.stats();
    assert_eq!(stats.shard_timeouts.load(Ordering::Relaxed), 1);
    assert_eq!(stats.truncated_rows.load(Ordering::Relaxed), 16);
    // the stalled shard must show in the gather tail once it wakes; stop
    // joins every worker, so the sleeping shard cannot wedge the drain
    let (mems, report) = svc.stop_with_report();
    assert_eq!(mems.len(), 4);
    let stages = report.get("stages").unwrap();
    let gather = stages.get("worker_gather").unwrap();
    assert_eq!(gather.get("count").and_then(|v| v.as_usize()), Some(4));
    let merge = stages.get("reply_merge").unwrap();
    assert_eq!(merge.get("count").and_then(|v| v.as_usize()), Some(1));
    assert_pool_balanced(h.segment_pool().stats(), "segment pool");
    assert_pool_balanced(h.reply_pool().stats(), "reply pool");
}

#[test]
fn crashed_shard_worker_errors_and_leaks_nothing() {
    // shard 2 crashes on its second command: the push is command 1, so
    // the first gather request kills it mid-request
    let svc = ShardedReplayService::spawn_with_faults(
        4,
        256,
        2,
        |_| Box::new(PerReplay::new(64, PerParams::default())),
        |shard| {
            if shard == 2 {
                FaultPlan { die_after_commands: Some(2), ..FaultPlan::default() }
            } else {
                FaultPlan::default()
            }
        },
    );
    let h = svc.handle();
    let exps: Vec<Experience> = (0..64).map(|i| exp(i as f32)).collect();
    assert!(h.push_batch(replay::ExperienceBatch::from_experiences(&exps)));
    let msg = format!("{}", h.sample_gathered(32).unwrap_err());
    assert!(msg.contains("shard 2"), "error must name the dead shard: {msg}");
    // a later request sees the disconnected channel at send time and
    // still resolves to an error with the healthy shards drained
    assert!(h.sample_gathered(32).is_err());
    assert_pool_balanced(h.segment_pool().stats(), "segment pool");
    assert_pool_balanced(h.reply_pool().stats(), "reply pool");
    // stop never deadlocks on the crashed worker, and the final report
    // still carries the per-stage histograms of the healthy work
    let (mems, report) = svc.stop_with_report();
    assert_eq!(mems.len(), 4, "every worker joined, including the crashed one");
    let gather = report.get("stages").unwrap().get("worker_gather").unwrap();
    assert!(
        gather.get("count").and_then(|v| v.as_usize()).unwrap() >= 3,
        "healthy shards must have recorded their gathers"
    );
    let depth = report.get("queue").unwrap().get("depth").unwrap();
    assert_eq!(depth.as_usize(), Some(0), "queues drained after stop");
}

#[test]
fn dropped_reply_times_out_then_service_recovers() {
    // the worker swallows exactly one gather reply; that request times
    // out (bounded wait), the next one is served normally
    let svc = ReplayService::spawn_with_faults(
        replay::make(ReplayKind::Uniform, 128),
        64,
        3,
        FaultPlan { drop_gather_replies: 1, ..FaultPlan::default() },
    );
    let h = svc.handle();
    for i in 0..64 {
        assert!(h.push(exp(i as f32)));
    }
    h.set_gather_timeout(Duration::from_millis(50));
    let msg = format!("{}", h.sample_gathered(16).unwrap_err());
    assert!(msg.contains("timed out"), "swallowed reply must surface as a timeout: {msg}");
    let g = h.sample_gathered(16).expect("service must recover after the drop");
    assert_eq!(g.rows(), 16);
    h.recycle(g);
    assert_pool_balanced(h.reply_pool().stats(), "reply pool");
    let stats = h.stats();
    assert_eq!(stats.stages.gather.count(), 2, "both gathers ran in the worker");
    drop(svc);
}

#[test]
fn full_queue_grows_the_adaptive_flush_and_stop_drains() {
    // a slow consumer (2ms per push) behind a depth-2 queue: senders
    // block, the gauge reads saturated, and every actor's controller
    // must climb from push_batch_min toward push_batch_max
    let svc = ReplayService::spawn_with_faults(
        replay::make(ReplayKind::Uniform, 10_000),
        2,
        4,
        FaultPlan { delay_push: Some(Duration::from_millis(2)), ..FaultPlan::default() },
    );
    let driver = VectorEnvDriver::spawn_with_policy(
        "cartpole",
        4,
        svc.handle(),
        7,
        FlushPolicy::adaptive(1, 64),
    );
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while driver.steps() < 64 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let hwm = driver.max_flush();
    assert!(hwm > 1, "adaptive flush never backed off the full queue (hwm {hwm})");
    assert!(hwm <= 64, "flush exceeded push_batch_max (hwm {hwm})");
    let total = driver.stop();
    assert!(total >= 64, "only {total} steps ingested");
    // graceful drain: every accepted push lands before the worker exits
    let (mem, report) = svc.stop_with_report();
    assert_eq!(mem.len() as u64, total.min(10_000));
    let pushes = report.get("service").unwrap().get("pushes").unwrap();
    assert_eq!(pushes.as_usize(), Some(total as usize));
    let depth = report.get("queue").unwrap().get("depth").unwrap();
    assert_eq!(depth.as_usize(), Some(0), "stop left commands in the queue");
}

#[test]
fn killed_net_client_mid_gather_recycles_and_tier_survives() {
    // a raw wire client handshakes, requests a gather, and vanishes
    // while the worker is still inside the (fault-delayed) gather. The
    // handler must recycle the lent reply buffer into the client's
    // private pool, mark it disconnected, and leave every other tenant
    // untouched.
    use amper::coordinator::{LearnerPort, ReplaySink};
    use amper::net::{wire, Listener, NetServer, Opcode, RemoteReplayClient, Role, Stream};

    let svc = ReplayService::spawn_with_faults(
        replay::make(ReplayKind::Uniform, 128),
        64,
        9,
        slow_gather(100),
    );
    let listener = Listener::bind("127.0.0.1:0").expect("bind loopback");
    let server = NetServer::spawn(svc.handle(), listener).expect("spawn tier");

    let good = RemoteReplayClient::connect(server.addr(), Role::Learner)
        .expect("good client");
    let exps: Vec<Experience> = (0..64).map(|i| exp(i as f32)).collect();
    assert!(good.push_experience_batch(replay::ExperienceBatch::from_experiences(&exps)));
    let g = good.sample_gathered(16).expect("healthy gather before the kill");
    good.recycle(g);

    // the victim: Hello, one gather request, then gone mid-gather
    {
        let mut victim = Stream::connect(server.addr()).expect("victim connect");
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf, Role::Learner);
        wire::write_frame(&mut victim, Opcode::Hello, 0, &buf).expect("hello");
        let mut payload = Vec::new();
        let h = wire::read_frame(&mut victim, &mut payload).expect("ack");
        assert_eq!(h.opcode, Opcode::HelloAck);
        wire::encode_sample_gathered(&mut buf, 16);
        wire::write_frame(&mut victim, Opcode::SampleGathered, h.client, &buf)
            .expect("request");
        victim.shutdown();
    } // drop closes the socket while the 100ms gather is in flight

    // the handler finishes the gather, fails or wastes the reply write,
    // recycles the buffer, and retires the client
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let victim_stats = loop {
        let clients = server.clients();
        if let Some(c) = clients.iter().find(|c| c.id == 2) {
            if !c.connected.load(Ordering::Relaxed) {
                break c.clone();
            }
        }
        assert!(std::time::Instant::now() < deadline, "victim never retired");
        std::thread::sleep(Duration::from_millis(5));
    };
    // the lent buffer came back: one take, one settle — never a leak
    let pool = victim_stats.reply_pool().stats();
    assert_eq!(pool.misses.load(Ordering::Relaxed), 1, "one cold take");
    assert_eq!(pool.recycled.load(Ordering::Relaxed), 1, "buffer recycled");
    assert_pool_balanced(pool, "killed client pool");
    assert_eq!(victim_stats.pushes.load(Ordering::Relaxed), 0);
    // whether the reply write raced the FIN is OS timing; the ledger may
    // record the served batch or a cut read, but never more than one
    assert!(victim_stats.samples.load(Ordering::Relaxed) <= 1);
    assert!(victim_stats.frame_errors.load(Ordering::Relaxed) <= 1);

    // the surviving tenant keeps training against the same tier
    let g = good.sample_gathered(16).expect("tier must survive the kill");
    let n = g.indices.len();
    assert!(good.update_priorities(g.indices.clone(), vec![0.5; n]));
    good.recycle(g);
    assert_eq!(server.clients().len(), 2);
    assert_eq!(server.handshake_errors(), 0, "the victim's Hello was valid");
    assert_pool_balanced(good.reply_pool().stats(), "good client pool");
    good.close();
    server.stop();
    let _ = svc.stop();
}

#[test]
fn stalled_net_client_fails_after_write_timeout_and_tier_survives() {
    // a client that requests gathers but never reads the replies: the
    // socket buffers fill, the handler's bounded write times out, and
    // ONLY that connection dies — with its pool settled and the stall
    // visible as a frame error in the ledger.
    use amper::coordinator::{LearnerPort, ReplaySink};
    use amper::net::{
        wire, Listener, NetServer, NetServerOptions, Opcode, RemoteReplayClient,
        Role, Stream,
    };

    let svc = ReplayService::spawn(replay::make(ReplayKind::Uniform, 2048), 64, 11);
    let listener = Listener::bind("127.0.0.1:0").expect("bind loopback");
    let server = NetServer::spawn_with(
        svc.handle(),
        listener,
        NetServerOptions {
            write_timeout: Duration::from_millis(100),
            ..NetServerOptions::default()
        },
    )
    .expect("spawn tier");

    // wide rows make each gathered reply ~260KB, so a reader that never
    // drains blocks the handler's write well inside the request burst
    let good = RemoteReplayClient::connect(server.addr(), Role::Learner)
        .expect("good client");
    let dim = 128usize;
    let row = vec![0.5f32; dim];
    let mut eb = replay::ExperienceBatch::with_capacity(dim, 512);
    for i in 0..512 {
        eb.push_parts(&row, (i % 4) as u32, i as f32, &row, false);
    }
    assert!(good.push_experience_batch(eb));
    let g = good.sample_gathered(256).expect("healthy gather");
    good.recycle(g);

    // the staller: handshake, burst 64 gather requests, read nothing
    let mut staller = Stream::connect(server.addr()).expect("staller connect");
    let mut buf = Vec::new();
    wire::encode_hello(&mut buf, Role::Learner);
    wire::write_frame(&mut staller, Opcode::Hello, 0, &buf).expect("hello");
    let mut payload = Vec::new();
    let h = wire::read_frame(&mut staller, &mut payload).expect("ack");
    assert_eq!(h.opcode, Opcode::HelloAck);
    wire::encode_sample_gathered(&mut buf, 256);
    for _ in 0..128 {
        wire::write_frame(&mut staller, Opcode::SampleGathered, h.client, &buf)
            .expect("request burst");
    }

    // the handler serves replies until the write blocks past the bound;
    // the staller's socket stays open the whole time — this is a stall,
    // not a disconnect
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let stalled_stats = loop {
        let clients = server.clients();
        if let Some(c) = clients.iter().find(|c| c.id == 2) {
            if !c.connected.load(Ordering::Relaxed) {
                break c.clone();
            }
        }
        assert!(std::time::Instant::now() < deadline, "stall never detected");
        std::thread::sleep(Duration::from_millis(10));
    };
    let served = stalled_stats.samples.load(Ordering::Relaxed);
    assert!(served < 128, "every reply fit the buffers — no stall exercised");
    assert_eq!(
        stalled_stats.frame_errors.load(Ordering::Relaxed),
        1,
        "the timed-out write must be accounted"
    );
    assert_pool_balanced(stalled_stats.reply_pool().stats(), "stalled client pool");

    // the healthy tenant never noticed
    let g = good.sample_gathered(256).expect("tier must survive the stall");
    assert_eq!(g.rows(), 256);
    good.recycle(g);
    assert_pool_balanced(good.reply_pool().stats(), "good client pool");
    drop(staller);
    good.close();
    server.stop();
    let _ = svc.stop();
}

#[test]
fn pipeline_drains_cleanly_at_depths_1_and_2_even_mid_crash() {
    for depth in [1usize, 2] {
        // healthy drain: abandon a pipeline with requests in flight,
        // then stop — nothing hangs, nothing leaks
        {
            let svc = ReplayService::spawn(replay::make(ReplayKind::Uniform, 128), 64, 5);
            let h = svc.handle();
            for i in 0..64 {
                assert!(h.push(exp(i as f32)));
            }
            let mut pipe = GatherPipeline::new(svc.handle(), 8, depth);
            let g = pipe.next_batch().expect("healthy gather");
            pipe.recycle(g);
            drop(pipe); // depth-1 in-flight requests settle via Drop
            assert_pool_balanced(h.reply_pool().stats(), "healthy reply pool");
            let _ = svc.stop();
        }
        // crash drain: the worker dies on the first gather (5 pushes =
        // commands 1..=5, so command 6 is the kill); next_batch errors
        // without hanging and the drop-drain settles instantly
        {
            let svc = ReplayService::spawn_with_faults(
                replay::make(ReplayKind::Uniform, 128),
                64,
                6,
                FaultPlan { die_after_commands: Some(6), ..FaultPlan::default() },
            );
            let h = svc.handle();
            for i in 0..5 {
                assert!(h.push(exp(i as f32)));
            }
            let mut pipe = GatherPipeline::new(svc.handle(), 8, depth);
            let r = pipe.next_batch();
            assert!(r.is_err(), "depth {depth}: dying worker must error");
            drop(pipe);
            assert_pool_balanced(h.reply_pool().stats(), "crashed reply pool");
            let _ = svc.stop();
        }
    }
}
