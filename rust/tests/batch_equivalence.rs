//! Scalar-vs-batched equivalence: the batch-first replay methods
//! (`push_batch` / `sample_into` / `update_priorities_batch`) must
//! produce **bit-identical** state to the scalar loops for every
//! technique — same ring contents, same priorities, same subsequent
//! sample stream under the same seed — including interleaved capacity
//! wrap-around. Plus the sharded batch-split roundtrip under the
//! `(shard, slot)` global index, the pooled-reply roundtrip (a recycled
//! buffer refilled by the worker must be bit-identical to a freshly
//! allocated reply, including the sharded completion-order merge),
//! pipelined-learner determinism (pipeline depth 1 vs 2 produce
//! identical training streams for a fixed seed), and the inference
//! side: batched `act_batch` vs scalar `act` bit-identity for every
//! built-in env spec, and the snapshot-driven [`VecEnvTicker`] vs a
//! direct-engine scalar driver producing bitwise-equal transitions.
//!
//! The engine-parallelism suite pins the worker-pool kernels: train
//! steps and `act_batch` bit-identical across `engine_threads` ∈
//! {1, 2, 4}, the chunked sum-tree refresh bit-identical to per-leaf
//! root-ward walks, and the integer-key CSP build (serial and parallel
//! chunk sort) selecting exactly what the float-sort reference selects.

use amper::coordinator::{GatherPipeline, ReplayService, ShardedReplayService};
use amper::replay::amper::Variant;
use amper::replay::{
    self, global_index, Experience, ExperienceBatch, GatheredBatch, HwAmperReplay,
    ReplayKind, ReplayMemory,
};
use amper::util::Rng;

const DIM: usize = 3;

fn exp(v: f32, done: bool) -> Experience {
    Experience {
        obs: vec![v, v + 0.25, v + 0.5],
        action: (v as u32) % 4,
        reward: v * 0.5,
        next_obs: vec![v + 1.0, v + 1.25, v + 1.5],
        done,
    }
}

/// Assert both memories hold identical ring + priority state.
fn assert_state_identical(a: &dyn ReplayMemory, b: &dyn ReplayMemory, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: len");
    let (ra, rb) = (a.ring(), b.ring());
    for slot in 0..a.len() {
        assert_eq!(ra.obs_of(slot), rb.obs_of(slot), "{tag}: obs slot {slot}");
        assert_eq!(
            ra.next_obs_of(slot),
            rb.next_obs_of(slot),
            "{tag}: next_obs slot {slot}"
        );
        assert_eq!(
            ra.action_of(slot),
            rb.action_of(slot),
            "{tag}: action slot {slot}"
        );
        assert_eq!(
            ra.reward_of(slot),
            rb.reward_of(slot),
            "{tag}: reward slot {slot}"
        );
        assert_eq!(ra.done_of(slot), rb.done_of(slot), "{tag}: done slot {slot}");
        // bit-identical priorities, not approximately equal
        assert_eq!(
            a.priority_of(slot).to_bits(),
            b.priority_of(slot).to_bits(),
            "{tag}: priority slot {slot}"
        );
    }
}

/// Drive one memory pair through interleaved scalar/batched rounds and
/// check equivalence after every round.
fn run_equivalence(
    kind_tag: &str,
    mut scalar: Box<dyn ReplayMemory>,
    mut batched: Box<dyn ReplayMemory>,
    seed: u64,
) {
    // push rngs are never consumed by push paths today, but keep the
    // streams mirrored so the contract survives rng-consuming memories
    let mut push_rng_a = Rng::new(seed);
    let mut push_rng_b = Rng::new(seed);
    let mut data_rng = Rng::new(seed ^ 0xD47A);
    let mut next_v = 0.0f32;
    // batch sizes chosen to wrap the ring mid-batch and to exceed the
    // whole capacity in one batch (cap is 41 below)
    for (round, &batch_len) in [1usize, 7, 19, 50, 3, 64].iter().enumerate() {
        let exps: Vec<Experience> = (0..batch_len)
            .map(|_| {
                next_v += 1.0;
                exp(next_v, next_v as usize % 5 == 0)
            })
            .collect();
        let scalar_slots: Vec<usize> = exps
            .iter()
            .map(|e| scalar.push(e.clone(), &mut push_rng_a))
            .collect();
        let eb = ExperienceBatch::from_experiences(&exps);
        let mut batch_slots = Vec::new();
        batched.push_batch(&eb, &mut push_rng_b, &mut batch_slots);
        assert_eq!(
            batch_slots, scalar_slots,
            "{kind_tag} round {round}: slot order"
        );
        assert_state_identical(
            scalar.as_ref(),
            batched.as_ref(),
            &format!("{kind_tag} round {round} after push"),
        );

        // TD feedback over a deterministic index spread (wraps included)
        let n = scalar.len();
        let indices: Vec<usize> =
            (0..batch_len.min(n)).map(|j| (j * 7 + round) % n).collect();
        let tds: Vec<f32> =
            indices.iter().map(|_| data_rng.f32() * 2.0 - 0.5).collect();
        scalar.update_priorities(&indices, &tds);
        batched.update_priorities_batch(&indices, &tds);
        assert_state_identical(
            scalar.as_ref(),
            batched.as_ref(),
            &format!("{kind_tag} round {round} after update"),
        );

        // identical state + identical rng stream => identical samples,
        // whichever of sample / sample_into serves the request
        let mut rng_a = Rng::new(seed ^ round as u64);
        let mut rng_b = Rng::new(seed ^ round as u64);
        let sampled_a = scalar.sample(16, &mut rng_a);
        let mut sampled_b = amper::replay::SampledBatch::default();
        batched.sample_into(16, &mut rng_b, &mut sampled_b);
        assert_eq!(
            sampled_a.indices, sampled_b.indices,
            "{kind_tag} round {round}: sampled indices"
        );
        let wa: Vec<u32> =
            sampled_a.is_weights.iter().map(|w| w.to_bits()).collect();
        let wb: Vec<u32> =
            sampled_b.is_weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(wa, wb, "{kind_tag} round {round}: IS weights");
    }
}

#[test]
fn batched_paths_bit_identical_to_scalar_for_all_kinds() {
    // resolve through the registry so a newly registered technique is
    // pinned to the scalar/batched contract automatically
    for d in amper::replay::registry::all() {
        let kind = ReplayKind::from_name(d.name);
        for seed in [0u64, 11, 1234] {
            run_equivalence(
                kind.name(),
                replay::make(kind, 41),
                replay::make(kind, 41),
                seed,
            );
        }
    }
}

#[test]
fn non_finite_and_zero_td_feedback_stays_bit_identical() {
    // the new techniques sanitize NaN/inf TD errors instead of poisoning
    // their trees; both feedback paths must agree bit-for-bit on the
    // sanitized state, wrap-around included (50 pushes into capacity 41)
    for name in ["dpsr", "pper", "dual"] {
        let kind = ReplayKind::parse(name).unwrap();
        let mut scalar = replay::make(kind, 41);
        let mut batched = replay::make(kind, 41);
        let mut push_a = Rng::new(3);
        let mut push_b = Rng::new(3);
        for i in 0..50 {
            let e = exp(i as f32, i % 5 == 0);
            let sa = scalar.push(e.clone(), &mut push_a);
            let mut slots = Vec::new();
            let eb = ExperienceBatch::from_experiences(&[e]);
            batched.push_batch(&eb, &mut push_b, &mut slots);
            assert_eq!(slots, vec![sa], "{name}: slot for push {i}");
        }
        let indices: Vec<usize> = (0..41).collect();
        let mut tds = vec![0.0f32; 41];
        tds[3] = f32::NAN;
        tds[5] = f32::INFINITY;
        tds[7] = f32::NEG_INFINITY;
        tds[11] = -2.5;
        scalar.update_priorities(&indices, &tds);
        batched.update_priorities_batch(&indices, &tds);
        assert_state_identical(scalar.as_ref(), batched.as_ref(), name);
        for i in 0..41 {
            assert!(
                scalar.priority_of(i).is_finite(),
                "{name}: slot {i} priority not finite"
            );
        }
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let a = scalar.sample(16, &mut rng_a);
        let mut b = amper::replay::SampledBatch::default();
        batched.sample_into(16, &mut rng_b, &mut b);
        assert_eq!(a.indices, b.indices, "{name}: post-poison sample");
    }
}

#[test]
fn sharded_split_roundtrip_covers_new_techniques() {
    // dpsr/dual/pper behind the sharded service: payloads roundtrip under
    // the (shard, slot) global index and TD feedback routes to the right
    // shard — dual keeps unit priorities, the prioritized pair lands the
    // exact PER-transform value
    for name in ["dpsr", "dual", "pper"] {
        let kind = ReplayKind::parse(name).unwrap();
        let shards = 4usize;
        let svc = ShardedReplayService::spawn_partitioned(
            400,
            shards,
            256,
            9,
            |_, cap| replay::make(kind, cap),
        );
        let h = svc.handle();
        let rows = 87usize; // not a multiple of the shard count
        let exps: Vec<Experience> =
            (0..rows).map(|i| exp(i as f32, false)).collect();
        assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
        let g = h.sample_gathered(64).expect("gather failed");
        assert_eq!(g.indices.len(), 64, "{name}");
        for (row, &gi) in g.indices.iter().enumerate() {
            let (shard, slot) = global_index::decode(gi);
            assert!(shard < shards, "{name}: index {gi:#x}");
            let global_row = slot * shards + shard;
            assert!(global_row < rows, "{name}: decoded row {global_row}");
            assert_eq!(
                g.obs[row * DIM],
                global_row as f32,
                "{name} row {row}: payload mismatch for {gi:#x}"
            );
        }
        let target_row = 42usize;
        let target =
            global_index::encode(target_row % shards, target_row / shards);
        assert!(h.update_priorities(vec![target], vec![3.0]));
        let mems = svc.stop();
        let got = mems[target_row % shards].priority_of(target_row / shards);
        if name == "dual" {
            assert_eq!(got, 1.0, "dual keeps unit priorities");
        } else {
            let want = replay::priority_from_td(3.0, 1e-2, 0.6);
            assert!(
                (got - want).abs() < 1e-5,
                "{name}: TD error did not land: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn hw_backed_batched_push_matches_scalar_priorities() {
    // the hw-backed memory issues one wide device op per batch instead of
    // one per row; the visible state (ring + quantized priorities) must
    // still match the scalar path — only the device-op count may differ
    use amper::hardware::accelerator::AccelConfig;
    let mut scalar = HwAmperReplay::new(37, AccelConfig::default(), Variant::Frnn, 5);
    let mut batched = HwAmperReplay::new(37, AccelConfig::default(), Variant::Frnn, 5);
    let mut rng = Rng::new(1);
    let mut v = 0.0f32;
    for batch_len in [1usize, 9, 40, 17] {
        let exps: Vec<Experience> = (0..batch_len)
            .map(|_| {
                v += 1.0;
                exp(v, false)
            })
            .collect();
        let scalar_slots: Vec<usize> =
            exps.iter().map(|e| scalar.push(e.clone(), &mut rng)).collect();
        let eb = ExperienceBatch::from_experiences(&exps);
        let mut batch_slots = Vec::new();
        batched.push_batch(&eb, &mut rng, &mut batch_slots);
        assert_eq!(batch_slots, scalar_slots);
    }
    assert_state_identical(&scalar, &batched, "hw-backed");
    assert!(
        batched.device_ops < scalar.device_ops,
        "batched path must issue fewer device ops ({} vs {})",
        batched.device_ops,
        scalar.device_ops
    );
}

/// Bitwise equality of two gathered replies.
fn assert_gathered_identical(a: &GatheredBatch, b: &GatheredBatch, tag: &str) {
    assert_eq!(a.indices, b.indices, "{tag}: indices");
    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }
    assert_eq!(bits(&a.is_weights), bits(&b.is_weights), "{tag}: is_weights");
    assert_eq!(bits(&a.obs), bits(&b.obs), "{tag}: obs");
    assert_eq!(a.actions, b.actions, "{tag}: actions");
    assert_eq!(bits(&a.rewards), bits(&b.rewards), "{tag}: rewards");
    assert_eq!(bits(&a.next_obs), bits(&b.next_obs), "{tag}: next_obs");
    assert_eq!(bits(&a.dones), bits(&b.dones), "{tag}: dones");
}

/// Scribble over a reply buffer (content *and* shape) before recycling
/// it, so a refill that forgot to reset anything cannot pass.
fn poison(g: &mut GatheredBatch) {
    g.indices.iter_mut().for_each(|x| *x = usize::MAX);
    for col in [&mut g.is_weights, &mut g.rewards, &mut g.dones] {
        col.iter_mut().for_each(|x| *x = f32::NAN);
        col.push(7.25);
    }
    g.obs.iter_mut().for_each(|x| *x = f32::NAN);
    g.next_obs.clear();
    g.actions.iter_mut().for_each(|x| *x = -9);
    g.indices.push(3);
}

#[test]
fn pooled_reply_roundtrip_bit_identical_to_allocating_path() {
    // lent buffer -> worker fill -> (offset-write merge) -> recycle ->
    // refill must equal the allocating path exactly, for both service
    // shapes. Two identical services receive the same command sequence:
    // `alloc` never recycles (every reply freshly allocated — the PR-4
    // path), `pooled` recycles a poisoned buffer after every batch.
    for shards in [1usize, 4] {
        let mk = || {
            let svc = ShardedReplayService::spawn_partitioned(
                400,
                shards,
                256,
                31,
                |_, cap| replay::make(ReplayKind::Per, cap),
            );
            let h = svc.handle();
            let exps: Vec<Experience> =
                (0..300).map(|i| exp(i as f32, i % 7 == 0)).collect();
            assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
            svc
        };
        let alloc_svc = mk();
        let pooled_svc = mk();
        let alloc = alloc_svc.handle();
        let pooled = pooled_svc.handle();
        for round in 0..6 {
            let a = alloc.sample_gathered(64).expect("alloc gather");
            let mut p = pooled.sample_gathered(64).expect("pooled gather");
            assert_gathered_identical(
                &a,
                &p,
                &format!("shards {shards} round {round}"),
            );
            // same TD feedback keeps the two services' states identical
            let n = a.indices.len();
            assert!(alloc.update_priorities(a.indices.clone(), vec![0.9; n]));
            assert!(pooled.update_priorities(p.indices.clone(), vec![0.9; n]));
            poison(&mut p);
            pooled.recycle(p);
        }
        // the pooled side really exercised the pool: first request may
        // miss, every later one must hit
        use std::sync::atomic::Ordering;
        let stats = pooled.reply_pool().stats();
        assert_eq!(stats.misses.load(Ordering::Relaxed), 1, "shards {shards}");
        assert_eq!(stats.hits.load(Ordering::Relaxed), 5, "shards {shards}");
    }
}

#[test]
fn single_owner_pooled_reply_refills_the_same_buffer() {
    // the single-owner service gathers directly into the lent buffer:
    // a pool hit reuses the very same heap allocations
    let svc = ReplayService::spawn(replay::make(ReplayKind::Uniform, 128), 64, 17);
    let h = svc.handle();
    for i in 0..100 {
        assert!(h.push(exp(i as f32, false)));
    }
    let mut g1 = h.sample_gathered(32).expect("gather");
    let obs_ptr = g1.obs.as_ptr();
    let first = g1.clone();
    poison(&mut g1);
    h.recycle(g1);
    let g2 = h.sample_gathered(32).expect("gather");
    assert_eq!(
        g2.obs.as_ptr(),
        obs_ptr,
        "pool hit must refill the recycled buffer in place"
    );
    assert_eq!(g2.rows(), 32);
    assert_eq!(g2.obs.len(), 32 * DIM);
    // distinct draws from the same rng stream — not a stale copy
    assert_ne!(first.indices, g2.indices, "second draw must advance the rng");
}

#[test]
fn remote_single_learner_stream_bit_identical_to_in_process() {
    // N=1 tenancy pin (ISSUE 8): one learner over the wire is the same
    // machine as the in-process handle. A single client serializes its
    // commands onto one FIFO socket, the tier's handler enqueues them
    // into the same service queue in the same order, so the worker
    // consumes an identical command stream and its rng draws identical
    // samples. Two identically seeded services — one driven directly,
    // one through `NetServer` + `RemoteReplayClient` over loopback —
    // must therefore produce bit-identical gathered replies round after
    // round, including priority feedback between rounds, and end with
    // bit-identical ring + priority state.
    use amper::coordinator::{LearnerPort, ReplaySink};
    use amper::net::{Listener, NetServer, RemoteReplayClient, Role};

    let mk = || ReplayService::spawn(replay::make(ReplayKind::Per, 400), 256, 4242);
    let local_svc = mk();
    let remote_svc = mk();
    let local = local_svc.handle();
    let listener = Listener::bind("127.0.0.1:0").expect("bind loopback");
    let server = NetServer::spawn(remote_svc.handle(), listener).expect("spawn tier");
    let remote = RemoteReplayClient::connect(server.addr(), Role::Learner)
        .expect("connect learner");

    // identical push stream, chunked so pushes and gathers interleave
    let exps: Vec<Experience> =
        (0..300).map(|i| exp(i as f32, i % 7 == 0)).collect();
    for chunk in exps.chunks(50) {
        let eb = ExperienceBatch::from_experiences(chunk);
        assert!(local.push_batch(eb.clone()));
        assert!(remote.push_experience_batch(eb));
    }

    for round in 0..6 {
        let a = local.sample_gathered(64).expect("local gather");
        let b = remote.sample_gathered(64).expect("remote gather");
        assert_gathered_identical(&a, &b, &format!("remote round {round}"));
        // identical TD feedback keeps the priority state identical
        let n = a.indices.len();
        let tds: Vec<f32> = (0..n).map(|j| 0.1 + j as f32 * 0.01).collect();
        assert!(local.update_priorities(a.indices.clone(), tds.clone()));
        assert!(remote.update_priorities(b.indices.clone(), tds));
        local.recycle(a);
        remote.recycle(b);
    }

    // the remote path really ran pooled: first gather misses, rest hit
    use std::sync::atomic::Ordering;
    let pool = remote.reply_pool().stats();
    assert!(pool.hits.load(Ordering::Relaxed) >= 4, "remote pool unused");

    remote.close();
    server.stop();
    let lm = local_svc.stop();
    let rm = remote_svc.stop();
    assert_state_identical(lm.as_ref(), rm.as_ref(), "remote vs in-process");
}

#[test]
fn pipelined_depth_1_and_2_produce_identical_training_streams() {
    use amper::runtime::{Engine, EnvArtifacts, TrainScratch, TrainState};

    // fixed seed, quiescent service (no concurrent pushes), uniform
    // replay (priority updates are no-ops, so request timing cannot
    // shift the sampled stream): depth 1 (synchronous) and depth 2
    // (double-buffered) must produce bit-identical sampled indices,
    // gathered columns, losses, and final parameters.
    let mut spec = EnvArtifacts::builtin("cartpole").unwrap();
    spec.hidden = 16;
    spec.batch = 16;
    spec.dims = vec![spec.obs_dim, 16, 16, spec.n_actions];

    let run = |depth: usize, shards: usize| {
        let svc = ShardedReplayService::spawn_partitioned(
            512,
            shards,
            256,
            77,
            |_, cap| replay::make(ReplayKind::Uniform, cap),
        );
        let h = svc.handle();
        // transitions shaped for the engine spec: obs_dim 4, 2 actions
        let mut rng = Rng::new(5);
        let exps: Vec<Experience> = (0..400)
            .map(|_| {
                let v = rng.below(1000) as f32 * 0.25;
                Experience {
                    obs: vec![v, v + 0.1, v + 0.2, v + 0.3],
                    action: rng.below(spec.n_actions) as u32,
                    reward: v * 0.01,
                    next_obs: vec![v + 1.0, v + 1.1, v + 1.2, v + 1.3],
                    done: rng.chance(0.1),
                }
            })
            .collect();
        assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));

        let engine = Engine::from_spec(spec.clone());
        let mut state = TrainState::init(&spec, 13).unwrap();
        let mut scratch = TrainScratch::default();
        let mut pipeline = GatherPipeline::new(h, spec.batch, depth);
        let mut stream: Vec<(Vec<usize>, Vec<u32>, u32)> = Vec::new();
        for _ in 0..12 {
            let g = pipeline.next_batch().expect("gather");
            assert_eq!(g.rows(), spec.batch);
            let out = engine
                .train_step_scratch(&mut state, (&g).into(), &mut scratch)
                .expect("train");
            assert!(pipeline.feedback(&g, &out.td));
            stream.push((
                g.indices.clone(),
                g.obs.iter().map(|x| x.to_bits()).collect(),
                out.loss.to_bits(),
            ));
            pipeline.recycle(g);
        }
        let params: Vec<Vec<u32>> = state
            .params
            .iter()
            .map(|p| p.iter().map(|x| x.to_bits()).collect())
            .collect();
        (stream, params)
    };

    for shards in [1usize, 4] {
        let (s1, p1) = run(1, shards);
        let (s2, p2) = run(2, shards);
        assert_eq!(s1, s2, "shards {shards}: training stream diverged");
        assert_eq!(p1, p2, "shards {shards}: final params diverged");
    }
}

#[test]
fn adaptive_flush_at_fixed_point_is_bit_identical_to_fixed_path() {
    // the adaptive controller with push_batch_min == push_batch_max must
    // degenerate to the fixed flush exactly: same PushBatch commands in,
    // same sampled stream and worker state out — even while it observes
    // the real queue load after every flush
    use amper::coordinator::{FlushController, FlushPolicy};
    for shards in [1usize, 4] {
        let mk = || {
            ShardedReplayService::spawn_partitioned(400, shards, 256, 21, |_, cap| {
                replay::make(ReplayKind::Per, cap)
            })
        };
        let fixed_svc = mk();
        let adapt_svc = mk();
        let fixed = fixed_svc.handle();
        let adapt = adapt_svc.handle();
        let rows = 171usize; // 21 full flushes of 8 + a 3-row tail
        let exps: Vec<Experience> =
            (0..rows).map(|i| exp(i as f32, i % 6 == 0)).collect();
        for chunk in exps.chunks(8) {
            assert!(fixed.push_batch(ExperienceBatch::from_experiences(chunk)));
        }
        let mut ctl = FlushController::new(FlushPolicy::adaptive(8, 8));
        let mut pending = ExperienceBatch::with_capacity(DIM, 8);
        for (i, e) in exps.iter().enumerate() {
            pending.push_parts(&e.obs, e.action, e.reward, &e.next_obs, e.done);
            if pending.len() >= ctl.flush_at() {
                let full = std::mem::replace(
                    &mut pending,
                    ExperienceBatch::with_capacity(DIM, 8),
                );
                assert!(adapt.push_batch(full));
                ctl.observe(adapt.queue_load());
                assert_eq!(ctl.flush_at(), 8, "controller moved at row {i}");
            }
        }
        assert!(adapt.push_batch(pending)); // tail flush
        for round in 0..4 {
            let a = fixed.sample_gathered(32).expect("fixed gather");
            let b = adapt.sample_gathered(32).expect("adaptive gather");
            assert_gathered_identical(
                &a,
                &b,
                &format!("shards {shards} round {round}"),
            );
            let n = a.indices.len();
            assert!(fixed.update_priorities(a.indices.clone(), vec![0.8; n]));
            assert!(adapt.update_priorities(b.indices.clone(), vec![0.8; n]));
        }
        let fm = fixed_svc.stop();
        let am = adapt_svc.stop();
        for (s, (x, y)) in fm.iter().zip(am.iter()).enumerate() {
            assert_state_identical(
                x.as_ref(),
                y.as_ref(),
                &format!("shards {shards} shard {s}"),
            );
        }
    }
}

#[test]
fn sharded_batch_split_roundtrip_under_global_index() {
    // one incoming batch splits into per-shard sub-batches; sampling
    // gathers the same payloads back under (shard, slot) encodings and
    // TD errors route to the slots the split placed the rows in
    let shards = 4usize;
    let svc = ShardedReplayService::spawn_partitioned(400, shards, 256, 9, |_, cap| {
        replay::make(ReplayKind::Per, cap)
    });
    let h = svc.handle();
    let rows = 87usize; // not a multiple of the shard count
    let exps: Vec<Experience> = (0..rows).map(|i| exp(i as f32, false)).collect();
    assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));

    // gathered samples decode to live (shard, slot) pairs whose payload
    // matches the original row: the split placed global row g on shard
    // g % shards at slot g / shards
    let g = h.sample_gathered(64).expect("gather failed");
    assert_eq!(g.indices.len(), 64);
    assert_eq!(g.obs.len(), 64 * DIM);
    for (row, &gi) in g.indices.iter().enumerate() {
        let (shard, slot) = global_index::decode(gi);
        assert!(shard < shards, "index {gi:#x}");
        let global_row = slot * shards + shard;
        assert!(global_row < rows, "decoded row {global_row} out of range");
        assert_eq!(
            g.obs[row * DIM],
            global_row as f32,
            "row {row}: payload mismatch for {gi:#x}"
        );
        assert_eq!(g.rewards[row], global_row as f32 * 0.5);
    }

    // route one TD error to a specific row through its global index
    let target_row = 42usize;
    let target =
        global_index::encode(target_row % shards, target_row / shards);
    assert!(h.update_priorities(vec![target], vec![3.0]));
    let mems = svc.stop();
    let want = replay::priority_from_td(3.0, 1e-2, 0.6);
    let got = mems[target_row % shards].priority_of(target_row / shards);
    assert!(
        (got - want).abs() < 1e-5,
        "TD error did not land: got {got}, want {want}"
    );
    // and the split really partitioned the batch: shard sizes differ by
    // at most one and sum to the batch
    let sizes: Vec<usize> = mems.iter().map(|m| m.len()).collect();
    assert_eq!(sizes.iter().sum::<usize>(), rows);
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
}

#[test]
fn batched_act_bit_identical_to_scalar_act_for_all_builtin_specs() {
    // one forward over all rows vs one forward per row: same actions,
    // same q bits, for every network shape in the built-in table — and
    // the engine-free snapshot path must agree with both
    use amper::coordinator::{ActScratch, PolicySnapshot};
    use amper::runtime::{Engine, EnvArtifacts, TrainState};

    for env in ["cartpole", "acrobot", "lunarlander", "mountaincar", "pongproxy"] {
        let spec = EnvArtifacts::builtin(env).unwrap();
        let engine = Engine::from_spec(spec.clone());
        let state = TrainState::init(&spec, 29).unwrap();
        let snap = PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 0)
            .unwrap();
        let mut rng = Rng::new(17);
        let rows = 5usize;
        let obs: Vec<f32> = (0..rows * spec.obs_dim)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();

        let mut batched = ActScratch::default();
        let actions = engine
            .act_batch(&state.params, &obs, rows, &mut batched)
            .unwrap()
            .to_vec();
        let q_batched: Vec<u32> = batched.q().iter().map(|x| x.to_bits()).collect();

        let mut snap_scratch = ActScratch::default();
        let via_snapshot = snap.greedy_actions(&obs, rows, &mut snap_scratch).unwrap();
        assert_eq!(actions, via_snapshot, "{env}: snapshot path diverged");

        let mut scalar = ActScratch::default();
        for r in 0..rows {
            let row = &obs[r * spec.obs_dim..(r + 1) * spec.obs_dim];
            let a = engine.act(&state, row, &mut scalar).unwrap();
            assert_eq!(a as u32, actions[r], "{env} row {r}: action");
            let q_row: Vec<u32> = scalar.q().iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                &q_row[..],
                &q_batched[r * spec.n_actions..(r + 1) * spec.n_actions],
                "{env} row {r}: q bits"
            );
        }
    }
}

#[test]
fn train_step_bit_identical_across_engine_thread_counts() {
    // the worker-pool kernels partition disjoint output rows, so the
    // per-element accumulation order is literally the scalar order: a
    // multi-step PER-driven run (sample -> gather -> train -> priority
    // feedback, so later samples depend on earlier TDs) must produce
    // bit-identical sampled indices, TD errors, losses, and final
    // parameters at 1, 2, and 4 engine threads — for every env shape
    use amper::runtime::{Engine, EnvArtifacts, TrainBatch, TrainScratch, TrainState};

    for env in ["cartpole", "acrobot", "lunarlander", "mountaincar", "pongproxy"] {
        let mut spec = EnvArtifacts::builtin(env).unwrap();
        spec.hidden = 16;
        spec.batch = 16;
        spec.dims = vec![spec.obs_dim, 16, 16, spec.n_actions];

        let run = |threads: usize| {
            let mut engine = Engine::from_spec(spec.clone());
            engine.set_threads(threads);
            assert_eq!(engine.threads(), threads);
            let mut state = TrainState::init(&spec, 42).unwrap();
            let mut scratch = TrainScratch::default();
            let mut mem = replay::make(ReplayKind::Per, 256);
            let mut rng = Rng::new(9);
            let mut data = Rng::new(1000);
            for i in 0..300usize {
                let obs: Vec<f32> = (0..spec.obs_dim)
                    .map(|_| data.normal_f32(0.0, 1.0))
                    .collect();
                let next: Vec<f32> = (0..spec.obs_dim)
                    .map(|_| data.normal_f32(0.0, 1.0))
                    .collect();
                mem.push(
                    Experience {
                        obs,
                        action: (i % spec.n_actions) as u32,
                        reward: data.normal_f32(0.0, 1.0),
                        next_obs: next,
                        done: i % 9 == 0,
                    },
                    &mut rng,
                );
            }
            let mut sampled = amper::replay::SampledBatch::default();
            let mut batch = TrainBatch::zeros(spec.batch, spec.obs_dim);
            let mut stream: Vec<(Vec<usize>, Vec<u32>, u32)> = Vec::new();
            for _ in 0..8 {
                mem.sample_into(spec.batch, &mut rng, &mut sampled);
                mem.ring()
                    .gather(
                        &sampled.indices,
                        &mut batch.obs,
                        &mut batch.actions,
                        &mut batch.rewards,
                        &mut batch.next_obs,
                        &mut batch.dones,
                    )
                    .unwrap();
                batch.is_weights.copy_from_slice(&sampled.is_weights);
                let out = engine
                    .train_step_scratch(&mut state, batch.view(), &mut scratch)
                    .unwrap();
                mem.update_priorities_batch(&sampled.indices, &out.td);
                stream.push((
                    sampled.indices.clone(),
                    out.td.iter().map(|x| x.to_bits()).collect(),
                    out.loss.to_bits(),
                ));
                scratch.recycle(out);
            }
            let params: Vec<Vec<u32>> = state
                .params
                .iter()
                .map(|p| p.iter().map(|x| x.to_bits()).collect())
                .collect();
            (stream, params)
        };

        let (s1, p1) = run(1);
        for threads in [2usize, 4] {
            let (s, p) = run(threads);
            assert_eq!(s1, s, "{env}: training stream diverged at {threads} threads");
            assert_eq!(p1, p, "{env}: final params diverged at {threads} threads");
        }
    }
}

#[test]
fn act_batch_bit_identical_across_engine_thread_counts() {
    // inference tiles are disjoint output rows too: actions and q bits
    // must match the single-threaded engine at any worker count,
    // including a row count that leaves a partial tile
    use amper::coordinator::ActScratch;
    use amper::runtime::{Engine, EnvArtifacts, TrainState};

    for env in ["cartpole", "acrobot", "lunarlander", "mountaincar", "pongproxy"] {
        let spec = EnvArtifacts::builtin(env).unwrap();
        let state = TrainState::init(&spec, 29).unwrap();
        let mut rng = Rng::new(33);
        let rows = 33usize; // 4 full 8-row tiles + 1 partial
        let obs: Vec<f32> = (0..rows * spec.obs_dim)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();

        let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
        for threads in [1usize, 2, 4] {
            let mut engine = Engine::from_spec(spec.clone());
            engine.set_threads(threads);
            let mut scratch = ActScratch::default();
            let actions = engine
                .act_batch(&state.params, &obs, rows, &mut scratch)
                .unwrap()
                .to_vec();
            let q: Vec<u32> = scratch.q().iter().map(|x| x.to_bits()).collect();
            match &reference {
                None => reference = Some((actions, q)),
                Some((a1, q1)) => {
                    assert_eq!(a1, &actions, "{env}: actions at {threads} threads");
                    assert_eq!(q1, &q, "{env}: q bits at {threads} threads");
                }
            }
        }
    }
}

#[test]
fn chunked_sum_tree_refresh_bit_identical_to_scalar_sets() {
    // the chunked update path (leaf writes + one level-by-level ancestor
    // refresh that visits shared parents once) must leave the whole heap
    // array bit-identical to per-leaf root-ward walks — duplicates and
    // non-power-of-two capacities included
    use amper::replay::SumTree;

    for cap in [1usize, 5, 33, 128] {
        let mut scalar = SumTree::new(cap);
        let mut chunked = SumTree::new(cap);
        let mut rng = Rng::new(cap as u64 + 0xBEEF);
        let mut scratch = Vec::new();
        for round in 0..8 {
            let k = 1 + rng.below(cap * 2);
            let updates: Vec<(usize, f64)> = (0..k)
                .map(|_| (rng.below(cap), rng.f32() as f64 + 0.001))
                .collect();
            for &(i, p) in &updates {
                scalar.set(i, p);
            }
            for &(i, p) in &updates {
                chunked.set_leaf(i, p);
            }
            let indices: Vec<usize> = updates.iter().map(|u| u.0).collect();
            chunked.refresh_leaves(&indices, &mut scratch);
            let a: Vec<u64> = scalar.raw_nodes().iter().map(|x| x.to_bits()).collect();
            let b: Vec<u64> =
                chunked.raw_nodes().iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "cap {cap} round {round}: heap diverged");
            assert_eq!(scalar.total().to_bits(), chunked.total().to_bits());
        }
    }
}

#[test]
fn integer_key_csp_build_identical_to_float_sort_reference() {
    // the integer-key CSP build (total-order-preserving f32 -> u32 keys,
    // packed with the slot so every key is unique) must select exactly
    // the slots the float-comparator reference selects — duplicated
    // priorities, zeros, and a NaN included — serial and with the
    // parallel chunk sort engaged
    use amper::replay::amper::csp::{self, CspScratch};
    use amper::replay::amper::AmperParams;
    use amper::runtime::ThreadPool;

    let pool = ThreadPool::new(4);
    for variant in [Variant::Knn, Variant::Frnn] {
        // 40_000 crosses the parallel-sort threshold (1 << 15)
        for n in [0usize, 1, 17, 500, 5000, 40_000] {
            let mut data = Rng::new(n as u64 ^ 0x77);
            let mut pri: Vec<f32> = (0..n).map(|_| data.f32()).collect();
            if n > 10 {
                pri[3] = f32::NAN; // must not panic or diverge
                pri[5] = pri[9]; // duplicate value, distinct slots
                pri[7] = 0.0;
            }
            let pri_q: Vec<u32> = pri
                .iter()
                .map(|&p| if p.is_nan() { 0 } else { (p * 4096.0) as u32 })
                .collect();
            let params = AmperParams::default();

            let mut float_rng = Rng::new(123);
            let mut float_out = Vec::new();
            let mut order = Vec::new();
            csp::build_csp_with_scratch(
                &pri,
                &pri_q,
                &params,
                variant,
                &mut float_rng,
                &mut float_out,
                &mut order,
            );
            let mut scratch = CspScratch::default();
            for pool_arg in [None, Some(&pool)] {
                let mut key_rng = Rng::new(123);
                let mut key_out = Vec::new();
                csp::build_csp_sorted_keys(
                    &pri,
                    &pri_q,
                    &params,
                    variant,
                    &mut key_rng,
                    &mut key_out,
                    &mut scratch,
                    pool_arg,
                );
                assert_eq!(
                    float_out,
                    key_out,
                    "{variant:?} n={n} pool={}",
                    pool_arg.is_some()
                );
            }
        }
    }
}

#[test]
fn snapshot_ticker_bit_identical_to_direct_engine_driver() {
    // the decoupled actor (snapshot slot + batched forward) against a
    // reference driver holding the engine directly and acting row by
    // row: with a publish before every tick — the worst-case snapshot
    // churn — both must produce bitwise-equal transition streams
    use amper::coordinator::{ActScratch, PolicySnapshot, SnapshotSlot, VecEnvTicker};
    use amper::envs::{self, Environment};
    use amper::runtime::{Engine, EnvArtifacts, TrainState};

    let (env_name, n_envs, seed, eps) = ("cartpole", 5usize, 1234u64, 0.3f64);
    let spec = EnvArtifacts::builtin(env_name).unwrap();
    let engine = Engine::from_spec(spec.clone());
    let mut state = TrainState::init(&spec, 99).unwrap();
    let slot = SnapshotSlot::new(
        PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 0).unwrap(),
    );
    let mut ticker = VecEnvTicker::new(env_name, n_envs, slot.clone(), seed, eps);

    // reference state: same env instances, same per-env rng derivation
    let dim = spec.obs_dim;
    let mut ref_envs: Vec<Box<dyn Environment>> =
        (0..n_envs).map(|_| envs::make(env_name).unwrap()).collect();
    let mut rngs: Vec<Rng> = (0..n_envs)
        .map(|i| Rng::new(seed ^ (i as u64).wrapping_mul(0xA5A5_A5A5)))
        .collect();
    let mut obs = vec![0.0f32; n_envs * dim];
    for (i, env) in ref_envs.iter_mut().enumerate() {
        let first = env.reset(&mut rngs[i]);
        obs[i * dim..(i + 1) * dim].copy_from_slice(&first);
    }
    let mut scratch = ActScratch::default();

    let mut got = ExperienceBatch::new(dim);
    let mut want = ExperienceBatch::new(dim);
    for round in 0..40u64 {
        // the learner moves before every tick: the ticker must pick up
        // each new epoch and act on the perturbed parameters
        state.params[0][0] += 0.01;
        slot.publish(state.snapshot_params());
        let behind = ticker.tick(&mut got);
        assert_eq!(behind, 1, "round {round}: one publish per tick");
        for i in 0..n_envs {
            let rng = &mut rngs[i];
            // mirror the ticker exactly: the explore draw is consumed
            // every step, the action draw only on exploration
            let action = if rng.chance(eps) {
                rng.below(spec.n_actions)
            } else {
                engine
                    .act(&state, &obs[i * dim..(i + 1) * dim], &mut scratch)
                    .unwrap()
            };
            let step = ref_envs[i].step(action, rng);
            want.push_parts(
                &obs[i * dim..(i + 1) * dim],
                action as u32,
                step.reward,
                &step.obs,
                step.terminated,
            );
            let next = if step.done() { ref_envs[i].reset(rng) } else { step.obs };
            obs[i * dim..(i + 1) * dim].copy_from_slice(&next);
        }
    }
    assert_eq!(got.len(), 40 * n_envs);
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(got.obs_flat()), bits(want.obs_flat()), "obs");
    assert_eq!(bits(got.next_obs_flat()), bits(want.next_obs_flat()), "next_obs");
    assert_eq!(got.actions(), want.actions(), "actions");
    assert_eq!(bits(got.rewards()), bits(want.rewards()), "rewards");
    assert_eq!(got.dones(), want.dones(), "dones");
    assert_eq!(slot.stats().behind.count(), 40, "one staleness sample per tick");
}
