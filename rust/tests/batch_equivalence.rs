//! Scalar-vs-batched equivalence: the batch-first replay methods
//! (`push_batch` / `sample_into` / `update_priorities_batch`) must
//! produce **bit-identical** state to the scalar loops for every
//! technique — same ring contents, same priorities, same subsequent
//! sample stream under the same seed — including interleaved capacity
//! wrap-around. Plus the sharded batch-split roundtrip under the
//! `(shard, slot)` global index.

use amper::coordinator::ShardedReplayService;
use amper::replay::amper::Variant;
use amper::replay::{
    self, global_index, Experience, ExperienceBatch, HwAmperReplay, ReplayKind,
    ReplayMemory,
};
use amper::util::Rng;

const DIM: usize = 3;

fn exp(v: f32, done: bool) -> Experience {
    Experience {
        obs: vec![v, v + 0.25, v + 0.5],
        action: (v as u32) % 4,
        reward: v * 0.5,
        next_obs: vec![v + 1.0, v + 1.25, v + 1.5],
        done,
    }
}

/// Assert both memories hold identical ring + priority state.
fn assert_state_identical(a: &dyn ReplayMemory, b: &dyn ReplayMemory, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: len");
    let (ra, rb) = (a.ring(), b.ring());
    for slot in 0..a.len() {
        assert_eq!(ra.obs_of(slot), rb.obs_of(slot), "{tag}: obs slot {slot}");
        assert_eq!(
            ra.next_obs_of(slot),
            rb.next_obs_of(slot),
            "{tag}: next_obs slot {slot}"
        );
        assert_eq!(
            ra.action_of(slot),
            rb.action_of(slot),
            "{tag}: action slot {slot}"
        );
        assert_eq!(
            ra.reward_of(slot),
            rb.reward_of(slot),
            "{tag}: reward slot {slot}"
        );
        assert_eq!(ra.done_of(slot), rb.done_of(slot), "{tag}: done slot {slot}");
        // bit-identical priorities, not approximately equal
        assert_eq!(
            a.priority_of(slot).to_bits(),
            b.priority_of(slot).to_bits(),
            "{tag}: priority slot {slot}"
        );
    }
}

/// Drive one memory pair through interleaved scalar/batched rounds and
/// check equivalence after every round.
fn run_equivalence(
    kind_tag: &str,
    mut scalar: Box<dyn ReplayMemory>,
    mut batched: Box<dyn ReplayMemory>,
    seed: u64,
) {
    // push rngs are never consumed by push paths today, but keep the
    // streams mirrored so the contract survives rng-consuming memories
    let mut push_rng_a = Rng::new(seed);
    let mut push_rng_b = Rng::new(seed);
    let mut data_rng = Rng::new(seed ^ 0xD47A);
    let mut next_v = 0.0f32;
    // batch sizes chosen to wrap the ring mid-batch and to exceed the
    // whole capacity in one batch (cap is 41 below)
    for (round, &batch_len) in [1usize, 7, 19, 50, 3, 64].iter().enumerate() {
        let exps: Vec<Experience> = (0..batch_len)
            .map(|_| {
                next_v += 1.0;
                exp(next_v, next_v as usize % 5 == 0)
            })
            .collect();
        let scalar_slots: Vec<usize> = exps
            .iter()
            .map(|e| scalar.push(e.clone(), &mut push_rng_a))
            .collect();
        let eb = ExperienceBatch::from_experiences(&exps);
        let mut batch_slots = Vec::new();
        batched.push_batch(&eb, &mut push_rng_b, &mut batch_slots);
        assert_eq!(
            batch_slots, scalar_slots,
            "{kind_tag} round {round}: slot order"
        );
        assert_state_identical(
            scalar.as_ref(),
            batched.as_ref(),
            &format!("{kind_tag} round {round} after push"),
        );

        // TD feedback over a deterministic index spread (wraps included)
        let n = scalar.len();
        let indices: Vec<usize> =
            (0..batch_len.min(n)).map(|j| (j * 7 + round) % n).collect();
        let tds: Vec<f32> =
            indices.iter().map(|_| data_rng.f32() * 2.0 - 0.5).collect();
        scalar.update_priorities(&indices, &tds);
        batched.update_priorities_batch(&indices, &tds);
        assert_state_identical(
            scalar.as_ref(),
            batched.as_ref(),
            &format!("{kind_tag} round {round} after update"),
        );

        // identical state + identical rng stream => identical samples,
        // whichever of sample / sample_into serves the request
        let mut rng_a = Rng::new(seed ^ round as u64);
        let mut rng_b = Rng::new(seed ^ round as u64);
        let sampled_a = scalar.sample(16, &mut rng_a);
        let mut sampled_b = amper::replay::SampledBatch::default();
        batched.sample_into(16, &mut rng_b, &mut sampled_b);
        assert_eq!(
            sampled_a.indices, sampled_b.indices,
            "{kind_tag} round {round}: sampled indices"
        );
        let wa: Vec<u32> =
            sampled_a.is_weights.iter().map(|w| w.to_bits()).collect();
        let wb: Vec<u32> =
            sampled_b.is_weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(wa, wb, "{kind_tag} round {round}: IS weights");
    }
}

#[test]
fn batched_paths_bit_identical_to_scalar_for_all_kinds() {
    for kind in ReplayKind::ALL {
        for seed in [0u64, 11, 1234] {
            run_equivalence(
                kind.name(),
                replay::make(kind, 41),
                replay::make(kind, 41),
                seed,
            );
        }
    }
}

#[test]
fn hw_backed_batched_push_matches_scalar_priorities() {
    // the hw-backed memory issues one wide device op per batch instead of
    // one per row; the visible state (ring + quantized priorities) must
    // still match the scalar path — only the device-op count may differ
    use amper::hardware::accelerator::AccelConfig;
    let mut scalar = HwAmperReplay::new(37, AccelConfig::default(), Variant::Frnn, 5);
    let mut batched = HwAmperReplay::new(37, AccelConfig::default(), Variant::Frnn, 5);
    let mut rng = Rng::new(1);
    let mut v = 0.0f32;
    for batch_len in [1usize, 9, 40, 17] {
        let exps: Vec<Experience> = (0..batch_len)
            .map(|_| {
                v += 1.0;
                exp(v, false)
            })
            .collect();
        let scalar_slots: Vec<usize> =
            exps.iter().map(|e| scalar.push(e.clone(), &mut rng)).collect();
        let eb = ExperienceBatch::from_experiences(&exps);
        let mut batch_slots = Vec::new();
        batched.push_batch(&eb, &mut rng, &mut batch_slots);
        assert_eq!(batch_slots, scalar_slots);
    }
    assert_state_identical(&scalar, &batched, "hw-backed");
    assert!(
        batched.device_ops < scalar.device_ops,
        "batched path must issue fewer device ops ({} vs {})",
        batched.device_ops,
        scalar.device_ops
    );
}

#[test]
fn sharded_batch_split_roundtrip_under_global_index() {
    // one incoming batch splits into per-shard sub-batches; sampling
    // gathers the same payloads back under (shard, slot) encodings and
    // TD errors route to the slots the split placed the rows in
    let shards = 4usize;
    let svc = ShardedReplayService::spawn_partitioned(400, shards, 256, 9, |_, cap| {
        replay::make(ReplayKind::Per, cap)
    });
    let h = svc.handle();
    let rows = 87usize; // not a multiple of the shard count
    let exps: Vec<Experience> = (0..rows).map(|i| exp(i as f32, false)).collect();
    assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));

    // gathered samples decode to live (shard, slot) pairs whose payload
    // matches the original row: the split placed global row g on shard
    // g % shards at slot g / shards
    let g = h.sample_gathered(64).expect("gather failed");
    assert_eq!(g.indices.len(), 64);
    assert_eq!(g.obs.len(), 64 * DIM);
    for (row, &gi) in g.indices.iter().enumerate() {
        let (shard, slot) = global_index::decode(gi);
        assert!(shard < shards, "index {gi:#x}");
        let global_row = slot * shards + shard;
        assert!(global_row < rows, "decoded row {global_row} out of range");
        assert_eq!(
            g.obs[row * DIM],
            global_row as f32,
            "row {row}: payload mismatch for {gi:#x}"
        );
        assert_eq!(g.rewards[row], global_row as f32 * 0.5);
    }

    // route one TD error to a specific row through its global index
    let target_row = 42usize;
    let target =
        global_index::encode(target_row % shards, target_row / shards);
    assert!(h.update_priorities(vec![target], vec![3.0]));
    let mems = svc.stop();
    let want = replay::priority_from_td(3.0, 1e-2, 0.6);
    let got = mems[target_row % shards].priority_of(target_row / shards);
    assert!(
        (got - want).abs() < 1e-5,
        "TD error did not land: got {got}, want {want}"
    );
    // and the split really partitioned the batch: shard sizes differ by
    // at most one and sum to the batch
    let sizes: Vec<usize> = mems.iter().map(|m| m.len()).collect();
    assert_eq!(sizes.iter().sum::<usize>(), rows);
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
}
