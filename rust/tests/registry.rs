//! The open-registry contract (ISSUE 10 acceptance): adding a replay
//! technique is ONE `ReplayDescriptor` registration — after that, config
//! parsing, parameter routing, CLI-style name resolution, memory
//! construction, the agent path and the (sharded) serve path all pick it
//! up with no match-arm edits anywhere.
//!
//! This lives in its own integration-test binary so the dummy
//! registration cannot leak into other binaries' `registry::all()`
//! iteration tests. Everything runs inside one `#[test]` because the
//! registry is process-global.

use amper::config::TrainConfig;
use amper::coordinator::ShardedReplayService;
use amper::replay::registry::{self, ReplayDescriptor, ReplayParams};
use amper::replay::{
    Experience, ExperienceBatch, ExperienceRing, ReplayKind, ReplayMemory,
    SampledBatch, UniformReplay,
};
use amper::util::Rng;

/// A minimal technique: uniform storage, but its own identity, one
/// config field (`boost`, routed through `ReplayParams::extra`), and a
/// capacity override so the test can prove `build` really saw the
/// parsed parameters.
struct DummyReplay {
    inner: UniformReplay,
}

impl ReplayMemory for DummyReplay {
    fn push(&mut self, e: Experience, rng: &mut Rng) -> usize {
        self.inner.push(e, rng)
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        self.inner.push_batch(batch, rng, slots)
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        self.inner.sample(batch, rng)
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        self.inner.sample_into(batch, rng, out)
    }

    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]) {
        self.inner.update_priorities(indices, td_errors)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        self.inner.ring()
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        self.inner.ring_mut()
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::from_name("dummy")
    }

    fn priority_of(&self, idx: usize) -> f32 {
        self.inner.priority_of(idx)
    }
}

const DUMMY_FIELDS: &[&str] = &["boost"];

fn build_dummy(cap: usize, params: &ReplayParams) -> Box<dyn ReplayMemory> {
    // a set `boost` halves the capacity: visible proof that the parsed
    // parameter reached the build function
    let cap = match params.extra_get("boost") {
        Some(_) => (cap / 2).max(1),
        None => cap,
    };
    Box::new(DummyReplay { inner: UniformReplay::new(cap) })
}

fn set_dummy(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    match field {
        "boost" => {
            val.parse::<f32>().map_err(|_| {
                format!("invalid value '{val}' for key 'replay.dummy.boost'")
            })?;
            p.extra.push(("boost".into(), val.into()));
            Ok(())
        }
        _ => Err(registry::unknown_field_error("dummy", field, DUMMY_FIELDS)),
    }
}

fn dummy_descriptor() -> ReplayDescriptor {
    ReplayDescriptor {
        name: "dummy",
        aliases: &["dummy-er"],
        help: "test-only uniform technique registered at runtime",
        paper: "n/a",
        param_ns: "dummy",
        param_fields: DUMMY_FIELDS,
        servable: true,
        shardable: true,
        build: build_dummy,
        hw_build: None,
        set_param: set_dummy,
    }
}

fn exp(v: f32) -> Experience {
    Experience {
        obs: vec![v, v + 0.25, v + 0.5],
        action: 0,
        reward: v,
        next_obs: vec![v + 1.0, v + 1.25, v + 1.5],
        done: false,
    }
}

#[test]
fn one_registration_reaches_config_build_and_serve() {
    let n_before = registry::all().len();
    registry::register(dummy_descriptor()).expect("register dummy");
    assert_eq!(registry::all().len(), n_before + 1);
    // double registration (and alias collisions) are rejected
    assert!(registry::register(dummy_descriptor()).is_err());

    // ---- CLI-style name resolution, case-insensitive, alias included --
    for name in ["dummy", "DUMMY", "dummy-er", "Dummy-ER"] {
        let kind = ReplayKind::parse(name)
            .unwrap_or_else(|| panic!("'{name}' did not parse"));
        assert_eq!(kind.name(), "dummy", "{name}");
    }
    assert!(ReplayKind::valid_names().contains("dummy|dummy-er"));

    // ---- config parse: technique key + parameter namespace ------------
    let mut config = TrainConfig::default();
    config.set("replay", "dummy").expect("set replay=dummy");
    assert_eq!(config.replay.name(), "dummy");
    config.set("replay.dummy.boost", "2.5").expect("set boost");
    assert_eq!(config.replay_params.extra_get("boost"), Some("2.5"));
    // unknown fields error with the accepted list
    let err = config.set("replay.dummy.bogus", "1").unwrap_err();
    assert!(err.contains("boost"), "error did not name the field: {err}");
    // bad values error with the full key
    let err = config.set("replay.dummy.boost", "not-a-number").unwrap_err();
    assert!(err.contains("replay.dummy.boost"), "{err}");

    // ---- build resolves through the registry and sees the params ------
    let d = registry::find("dummy").unwrap();
    let mem = (d.build)(64, &config.replay_params);
    assert_eq!(mem.capacity(), 32, "build ignored the parsed boost field");
    assert_eq!(mem.kind().name(), "dummy");
    let plain = (d.build)(64, &ReplayParams::default());
    assert_eq!(plain.capacity(), 64);

    // ---- the generic replay::build path works too ---------------------
    let mem = amper::replay::build(config.replay, 48, &config.replay_params);
    assert_eq!(mem.kind().name(), "dummy");

    // ---- serve path: the sharded service hosts the dummy technique ----
    let params = config.replay_params.clone();
    let svc = ShardedReplayService::spawn_partitioned(400, 4, 256, 7, |_, cap| {
        amper::replay::build(ReplayKind::from_name("dummy"), cap, &params)
    });
    let h = svc.handle();
    let exps: Vec<Experience> = (0..100).map(|i| exp(i as f32)).collect();
    assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
    let g = h.sample_gathered(32).expect("gather from dummy shards");
    assert_eq!(g.indices.len(), 32);
    let n = g.indices.len();
    assert!(h.update_priorities(g.indices.clone(), vec![0.5; n]));
    let mems = svc.stop();
    assert_eq!(mems.len(), 4);
    for m in &mems {
        assert_eq!(m.kind().name(), "dummy");
        // boost halves each shard's 100-slot partition
        assert_eq!(m.capacity(), 50);
    }

    // ---- every registered name (dummy included) roundtrips ------------
    for d in registry::all() {
        for name in std::iter::once(d.name).chain(d.aliases.iter().copied()) {
            let upper = name.to_ascii_uppercase();
            for variant in [name.to_string(), upper] {
                let kind = ReplayKind::parse(&variant)
                    .unwrap_or_else(|| panic!("'{variant}' did not parse"));
                assert_eq!(kind.name(), d.name, "{variant}");
                let mut c = TrainConfig::default();
                c.set("replay", &variant).expect("config set");
                assert_eq!(c.replay.name(), d.name, "{variant} via config");
            }
        }
    }
}
