//! Acrobot-v1 dynamics (Sutton 1996), transcribed from the Gym reference:
//! two-link underactuated pendulum, RK4 integration at 0.2 s, actions
//! apply torque {−1, 0, +1} to the second joint, −1 reward per step until
//! the tip reaches height 1.0 above the pivot, 500-step limit.
//!
//! Observation is the Gym 6-vector
//! `[cosθ1, sinθ1, cosθ2, sinθ2, θ̇1, θ̇2]`.

use super::{Environment, StepResult};
use crate::util::Rng;

const DT: f32 = 0.2;
const LINK_LENGTH_1: f32 = 1.0;
const LINK_MASS_1: f32 = 1.0;
const LINK_MASS_2: f32 = 1.0;
const LINK_COM_POS_1: f32 = 0.5;
const LINK_COM_POS_2: f32 = 0.5;
const LINK_MOI: f32 = 1.0;
const MAX_VEL_1: f32 = 4.0 * std::f32::consts::PI;
const MAX_VEL_2: f32 = 9.0 * std::f32::consts::PI;
const G: f32 = 9.8;
const TORQUES: [f32; 3] = [-1.0, 0.0, 1.0];
const MAX_STEPS: usize = 500;

/// The acrobot swing-up task.
#[derive(Debug, Clone)]
pub struct Acrobot {
    // internal state: theta1, theta2, dtheta1, dtheta2
    s: [f32; 4],
    steps: usize,
}

impl Acrobot {
    pub fn new() -> Self {
        Acrobot { s: [0.0; 4], steps: 0 }
    }

    fn observe(&self) -> Vec<f32> {
        vec![
            self.s[0].cos(),
            self.s[0].sin(),
            self.s[1].cos(),
            self.s[1].sin(),
            self.s[2],
            self.s[3],
        ]
    }

    /// Gym's `_dsdt`: state derivative including the action torque.
    fn dsdt(s: [f32; 5]) -> [f32; 5] {
        let [theta1, theta2, dtheta1, dtheta2, a] = s;
        let m1 = LINK_MASS_1;
        let m2 = LINK_MASS_2;
        let l1 = LINK_LENGTH_1;
        let lc1 = LINK_COM_POS_1;
        let lc2 = LINK_COM_POS_2;
        let i1 = LINK_MOI;
        let i2 = LINK_MOI;

        let d1 = m1 * lc1 * lc1
            + m2 * (l1 * l1 + lc2 * lc2 + 2.0 * l1 * lc2 * theta2.cos())
            + i1
            + i2;
        let d2 = m2 * (lc2 * lc2 + l1 * lc2 * theta2.cos()) + i2;
        let phi2 = m2 * lc2 * G
            * (theta1 + theta2 - std::f32::consts::FRAC_PI_2).cos();
        let phi1 = -m2 * l1 * lc2 * dtheta2 * dtheta2 * theta2.sin()
            - 2.0 * m2 * l1 * lc2 * dtheta2 * dtheta1 * theta2.sin()
            + (m1 * lc1 + m2 * l1)
                * G
                * (theta1 - std::f32::consts::FRAC_PI_2).cos()
            + phi2;
        // "book" dynamics (Gym default)
        let ddtheta2 = (a + d2 / d1 * phi1
            - m2 * l1 * lc2 * dtheta1 * dtheta1 * theta2.sin()
            - phi2)
            / (m2 * lc2 * lc2 + i2 - d2 * d2 / d1);
        let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
        [dtheta1, dtheta2, ddtheta1, ddtheta2, 0.0]
    }

    /// One RK4 step of the augmented state (Gym's `rk4`).
    fn rk4_step(y0: [f32; 5], dt: f32) -> [f32; 5] {
        let add = |y: [f32; 5], k: [f32; 5], c: f32| {
            let mut out = [0.0f32; 5];
            for i in 0..5 {
                out[i] = y[i] + c * k[i];
            }
            out
        };
        let k1 = Self::dsdt(y0);
        let k2 = Self::dsdt(add(y0, k1, dt / 2.0));
        let k3 = Self::dsdt(add(y0, k2, dt / 2.0));
        let k4 = Self::dsdt(add(y0, k3, dt));
        let mut out = [0.0f32; 5];
        for i in 0..5 {
            out[i] = y0[i] + dt / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
        }
        out
    }
}

impl Default for Acrobot {
    fn default() -> Self {
        Self::new()
    }
}

fn wrap(x: f32, lo: f32, hi: f32) -> f32 {
    let range = hi - lo;
    let mut x = x;
    while x > hi {
        x -= range;
    }
    while x < lo {
        x += range;
    }
    x
}

impl Environment for Acrobot {
    fn obs_dim(&self) -> usize {
        6
    }

    fn n_actions(&self) -> usize {
        3
    }

    fn name(&self) -> &'static str {
        "acrobot"
    }

    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        for s in self.s.iter_mut() {
            *s = rng.range_f32(-0.1, 0.1);
        }
        self.steps = 0;
        self.observe()
    }

    fn step(&mut self, action: usize, _rng: &mut Rng) -> StepResult {
        debug_assert!(action < 3);
        let torque = TORQUES[action];
        let y0 = [self.s[0], self.s[1], self.s[2], self.s[3], torque];
        let ns = Self::rk4_step(y0, DT);

        self.s[0] = wrap(ns[0], -std::f32::consts::PI, std::f32::consts::PI);
        self.s[1] = wrap(ns[1], -std::f32::consts::PI, std::f32::consts::PI);
        self.s[2] = ns[2].clamp(-MAX_VEL_1, MAX_VEL_1);
        self.s[3] = ns[3].clamp(-MAX_VEL_2, MAX_VEL_2);
        self.steps += 1;

        // terminal: tip above the bar, -cos(t1) - cos(t1 + t2) > 1
        let terminated =
            -self.s[0].cos() - (self.s[0] + self.s[1]).cos() > 1.0;
        let truncated = !terminated && self.steps >= MAX_STEPS;
        StepResult {
            obs: self.observe(),
            reward: if terminated { 0.0 } else { -1.0 },
            terminated,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observation_is_trig_encoded() {
        let mut env = Acrobot::new();
        let obs = env.reset(&mut Rng::new(0));
        // cos/sin components must be consistent unit vectors
        assert!((obs[0] * obs[0] + obs[1] * obs[1] - 1.0).abs() < 1e-5);
        assert!((obs[2] * obs[2] + obs[3] * obs[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn hanging_still_is_not_terminal() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let r = env.step(1, &mut rng); // no torque
        assert!(!r.terminated);
        assert_eq!(r.reward, -1.0);
    }

    #[test]
    fn velocities_bounded() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        for _ in 0..MAX_STEPS {
            let r = env.step(2, &mut rng);
            assert!(r.obs[4].abs() <= MAX_VEL_1 + 1e-4);
            assert!(r.obs[5].abs() <= MAX_VEL_2 + 1e-4);
            if r.done() {
                break;
            }
        }
    }

    #[test]
    fn angle_wrap() {
        assert!((wrap(4.0 * std::f32::consts::PI + 0.1,
                      -std::f32::consts::PI, std::f32::consts::PI) - 0.1)
            .abs() < 1e-5);
    }

    #[test]
    fn energy_pumping_raises_the_tip() {
        // A simple energy-pumping policy (torque in the direction of dθ1)
        // must pump energy into the system: the tip height
        // (-cosθ1 - cos(θ1+θ2)) should rise far above its resting value.
        let mut env = Acrobot::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        let height =
            |e: &Acrobot| -e.s[0].cos() - (e.s[0] + e.s[1]).cos();
        let start = height(&env);
        let mut best = start;
        for _ in 0..MAX_STEPS {
            let a = if env.s[2] > 0.0 { 2 } else { 0 };
            let r = env.step(a, &mut rng);
            best = best.max(height(&env));
            if r.done() {
                break;
            }
        }
        assert!(
            best > start + 0.8,
            "no energy pumped: start {start}, best {best}"
        );
    }
}
