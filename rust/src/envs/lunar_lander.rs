//! LunarLander-v2 (discrete), re-implemented without Box2D (DESIGN.md §4).
//!
//! The Gym version simulates a 6-DoF rigid body with two legs in Box2D.
//! Here the lander is a single rigid body (x, y, ẋ, ẏ, θ, θ̇) with the same
//! observation layout, action set (noop / left engine / main engine /
//! right engine), reward shaping (potential-based distance+velocity+angle
//! shaping, ±100 terminal, leg-contact bonus, fuel costs) and termination
//! rules as Gym. Leg contact is modeled geometrically from the body pose.
//!
//! The substitution preserves what the paper's experiment needs: an 8-dim
//! observation, 4 actions, dense shaped rewards spanning positive and
//! negative values, and episodes of a few hundred steps.

use super::{Environment, StepResult};
use crate::util::Rng;

const FPS: f32 = 50.0;
const DT: f32 = 1.0 / FPS;
const GRAVITY: f32 = -10.0;
const MAIN_ENGINE_POWER: f32 = 13.0;
const SIDE_ENGINE_POWER: f32 = 0.6;
// viewport scaling constants mirror Gym's normalized observation
const VIEWPORT_W: f32 = 600.0;
const VIEWPORT_H: f32 = 400.0;
const SCALE: f32 = 30.0;
const W: f32 = VIEWPORT_W / SCALE; // 20 world units
const H: f32 = VIEWPORT_H / SCALE; // 13.33
const HELIPAD_Y: f32 = H / 4.0;
const LEG_DOWN: f32 = 0.3; // leg extent below the hull center
const LEG_SPREAD: f32 = 0.35; // legs' horizontal offset
const MAX_STEPS: usize = 1000;
const INITIAL_Y: f32 = H * 0.95;

/// The lunar-lander task (discrete actions).
#[derive(Debug, Clone)]
pub struct LunarLander {
    x: f32,
    y: f32,
    vx: f32,
    vy: f32,
    angle: f32,
    vang: f32,
    steps: usize,
    prev_shaping: Option<f32>,
    crashed: bool,
    landed: bool,
}

impl LunarLander {
    pub fn new() -> Self {
        LunarLander {
            x: 0.0,
            y: INITIAL_Y,
            vx: 0.0,
            vy: 0.0,
            angle: 0.0,
            vang: 0.0,
            steps: 0,
            prev_shaping: None,
            crashed: false,
            landed: false,
        }
    }

    fn leg_heights(&self) -> (f32, f32) {
        // world-space y of each foot given hull pose
        let (s, c) = self.angle.sin_cos();
        let left = self.y - LEG_DOWN * c - LEG_SPREAD * s;
        let right = self.y - LEG_DOWN * c + LEG_SPREAD * s;
        (left, right)
    }

    fn contacts(&self) -> (bool, bool) {
        let (l, r) = self.leg_heights();
        (l <= HELIPAD_Y + 0.02, r <= HELIPAD_Y + 0.02)
    }

    fn observe(&self) -> Vec<f32> {
        let (lc, rc) = self.contacts();
        // Gym's normalization
        vec![
            self.x / (W / 2.0),
            (self.y - (HELIPAD_Y + LEG_DOWN)) / (H / 2.0),
            self.vx * (W / 2.0) / FPS,
            self.vy * (H / 2.0) / FPS,
            self.angle,
            20.0 * self.vang / FPS,
            lc as u8 as f32,
            rc as u8 as f32,
        ]
    }

    fn shaping(&self, obs: &[f32]) -> f32 {
        // Gym's potential function
        -100.0 * (obs[0] * obs[0] + obs[1] * obs[1]).sqrt()
            - 100.0 * (obs[2] * obs[2] + obs[3] * obs[3]).sqrt()
            - 100.0 * obs[4].abs()
            + 10.0 * obs[6]
            + 10.0 * obs[7]
    }
}

impl Default for LunarLander {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for LunarLander {
    fn obs_dim(&self) -> usize {
        8
    }

    fn n_actions(&self) -> usize {
        4
    }

    fn name(&self) -> &'static str {
        "lunarlander"
    }

    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        *self = LunarLander::new();
        // Gym applies a random initial force; equivalent velocity kick.
        self.vx = rng.range_f32(-1.0, 1.0);
        self.vy = rng.range_f32(-0.5, 0.0);
        self.x = rng.range_f32(-0.5, 0.5);
        self.angle = rng.range_f32(-0.05, 0.05);
        let obs = self.observe();
        self.prev_shaping = Some(self.shaping(&obs));
        obs
    }

    fn step(&mut self, action: usize, rng: &mut Rng) -> StepResult {
        debug_assert!(action < 4);
        let (sin_a, cos_a) = self.angle.sin_cos();

        let mut fuel_cost = 0.0f32;
        // Main engine (action 2): thrust along the body's up axis, with
        // the same ±0.5% dispersion noise Gym injects.
        if action == 2 {
            let disp = 1.0 + rng.range_f32(-0.005, 0.005);
            self.vx += -sin_a * MAIN_ENGINE_POWER / SCALE * disp * DT * FPS / 10.0;
            self.vy += cos_a * MAIN_ENGINE_POWER / SCALE * disp * DT * FPS / 10.0;
            fuel_cost = 0.3;
        }
        // Side engines (1 = left engine fires → push right & CCW torque;
        // 3 = right engine → push left & CW torque).
        if action == 1 || action == 3 {
            let dir = if action == 1 { -1.0 } else { 1.0 };
            let disp = 1.0 + rng.range_f32(-0.005, 0.005);
            self.vx += cos_a * dir * SIDE_ENGINE_POWER / SCALE * disp * DT * FPS;
            self.vy += sin_a * dir * SIDE_ENGINE_POWER / SCALE * disp * DT * FPS;
            self.vang -= dir * SIDE_ENGINE_POWER * disp * DT * FPS / 5.0;
            fuel_cost = 0.03;
        }

        // gravity + integration
        self.vy += GRAVITY / SCALE * DT * FPS / 10.0;
        self.x += self.vx * DT;
        self.y += self.vy * DT;
        self.angle += self.vang * DT;
        self.vang *= 0.99; // rotational damping (Box2D angularDamping)
        self.steps += 1;

        let (lc, rc) = self.contacts();
        let ground = lc || rc;
        if ground {
            // ground reaction: stop descent, damp horizontal slide
            if self.vy < 0.0 {
                // crash if impact too hard or too tilted
                if self.vy < -1.2 || self.angle.abs() > 0.6 {
                    self.crashed = true;
                }
                self.vy = 0.0;
            }
            self.vx *= 0.7;
            self.vang *= 0.5;
            let (l, r) = self.leg_heights();
            let sink = (HELIPAD_Y - l.min(r)).max(0.0);
            self.y += sink; // resolve penetration
            if lc && rc && self.vx.abs() < 0.05 && self.vang.abs() < 0.05 {
                self.landed = true;
            }
        }

        let obs = self.observe();
        let mut reward = 0.0f32;
        let shaping = self.shaping(&obs);
        if let Some(prev) = self.prev_shaping {
            reward = shaping - prev;
        }
        self.prev_shaping = Some(shaping);
        reward -= fuel_cost;

        let out_of_bounds = obs[0].abs() >= 1.0 || self.y > H || self.y < 0.0;
        let mut terminated = false;
        if self.crashed || out_of_bounds {
            terminated = true;
            reward = -100.0;
        } else if self.landed {
            terminated = true;
            reward = 100.0;
        }
        let truncated = !terminated && self.steps >= MAX_STEPS;
        StepResult { obs, reward, terminated, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freefall_crashes_with_penalty() {
        let mut env = LunarLander::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        let mut last = 0.0;
        for _ in 0..MAX_STEPS {
            let r = env.step(0, &mut rng);
            last = r.reward;
            if r.done() {
                assert!(r.terminated);
                break;
            }
        }
        assert_eq!(last, -100.0);
    }

    #[test]
    fn main_engine_slows_descent() {
        let mut e1 = LunarLander::new();
        let mut e2 = LunarLander::new();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        e1.reset(&mut r1);
        e2.reset(&mut r2);
        for _ in 0..30 {
            e1.step(0, &mut r1); // freefall
            e2.step(2, &mut r2); // main engine
        }
        assert!(e2.vy > e1.vy, "thrust must reduce downward velocity");
    }

    #[test]
    fn side_engines_rotate_opposite_ways() {
        let mut e1 = LunarLander::new();
        let mut e2 = LunarLander::new();
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        e1.reset(&mut r1);
        e2.reset(&mut r2);
        for _ in 0..10 {
            e1.step(1, &mut r1);
            e2.step(3, &mut r2);
        }
        assert!(e1.vang > 0.0 && e2.vang < 0.0);
    }

    #[test]
    fn observation_has_contact_flags() {
        let mut env = LunarLander::new();
        let obs = env.reset(&mut Rng::new(3));
        assert_eq!(obs.len(), 8);
        assert_eq!(obs[6], 0.0);
        assert_eq!(obs[7], 0.0);
    }

    #[test]
    fn gentle_descent_can_land() {
        // Proportional controller: fire main engine when falling fast,
        // side engines to level out. Should land at least sometimes.
        let mut landed = false;
        for seed in 0..10 {
            let mut env = LunarLander::new();
            let mut rng = Rng::new(seed);
            env.reset(&mut rng);
            for _ in 0..MAX_STEPS {
                let a = if env.angle > 0.1 {
                    3
                } else if env.angle < -0.1 {
                    1
                } else if env.vy < -0.6 {
                    2
                } else {
                    0
                };
                let r = env.step(a, &mut rng);
                if r.done() {
                    if env.landed {
                        landed = true;
                    }
                    break;
                }
            }
            if landed {
                break;
            }
        }
        assert!(landed, "controller never landed in 10 seeds");
    }
}
