//! CartPole-v1 dynamics (Barto, Sutton & Anderson 1983), transcribed from
//! the Gym reference implementation: Euler integration at 0.02 s, episode
//! ends when |x| > 2.4 or |θ| > 12°, +1 reward per step, 500-step limit.

use super::{Environment, StepResult};
use crate::util::Rng;

const GRAVITY: f32 = 9.8;
const MASS_CART: f32 = 1.0;
const MASS_POLE: f32 = 0.1;
const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
const LENGTH: f32 = 0.5; // half pole length
const POLE_MASS_LENGTH: f32 = MASS_POLE * LENGTH;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const THETA_THRESHOLD: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_THRESHOLD: f32 = 2.4;
const MAX_STEPS: usize = 500;

/// The cart-pole balancing task.
#[derive(Debug, Clone)]
pub struct CartPole {
    state: [f32; 4], // x, x_dot, theta, theta_dot
    steps: usize,
}

impl CartPole {
    pub fn new() -> Self {
        CartPole { state: [0.0; 4], steps: 0 }
    }

    /// Current raw state (for tests / rendering).
    pub fn state(&self) -> [f32; 4] {
        self.state
    }
}

impl Default for CartPole {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn n_actions(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "cartpole"
    }

    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        for s in self.state.iter_mut() {
            *s = rng.range_f32(-0.05, 0.05);
        }
        self.steps = 0;
        self.state.to_vec()
    }

    fn step(&mut self, action: usize, _rng: &mut Rng) -> StepResult {
        debug_assert!(action < 2);
        let [x, x_dot, theta, theta_dot] = self.state;
        let force = if action == 1 { FORCE_MAG } else { -FORCE_MAG };
        let (sin_t, cos_t) = theta.sin_cos();

        // Gym's equations (Florian 2007, "Correct equations for the
        // dynamics of the cart-pole system").
        let temp =
            (force + POLE_MASS_LENGTH * theta_dot * theta_dot * sin_t) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos_t / TOTAL_MASS;

        self.state = [
            x + TAU * x_dot,
            x_dot + TAU * x_acc,
            theta + TAU * theta_dot,
            theta_dot + TAU * theta_acc,
        ];
        self.steps += 1;

        let terminated = self.state[0].abs() > X_THRESHOLD
            || self.state[2].abs() > THETA_THRESHOLD;
        let truncated = !terminated && self.steps >= MAX_STEPS;
        StepResult {
            obs: self.state.to_vec(),
            reward: 1.0,
            terminated,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_is_near_zero() {
        let mut env = CartPole::new();
        let obs = env.reset(&mut Rng::new(0));
        assert!(obs.iter().all(|x| x.abs() <= 0.05));
    }

    #[test]
    fn falls_over_under_constant_push() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut steps = 0;
        loop {
            let r = env.step(1, &mut rng);
            steps += 1;
            if r.terminated {
                break;
            }
            assert!(steps < 200, "constant push should topple the pole");
        }
        assert!(steps < 100);
    }

    #[test]
    fn reward_is_one_per_step() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let r = env.step(0, &mut rng);
        assert_eq!(r.reward, 1.0);
    }

    #[test]
    fn truncates_at_limit_if_balanced() {
        // A crude bang-bang controller can hold the pole for 500 steps.
        let mut env = CartPole::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        let mut steps = 0;
        loop {
            let s = env.state();
            let a = if s[2] + 0.3 * s[3] > 0.0 { 1 } else { 0 };
            let r = env.step(a, &mut rng);
            steps += 1;
            if r.done() {
                assert!(r.truncated, "controller fell at step {steps}");
                break;
            }
        }
        assert_eq!(steps, MAX_STEPS);
    }
}
