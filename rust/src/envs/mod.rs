//! Reinforcement-learning environments, re-implemented from the OpenAI Gym
//! reference dynamics (DESIGN.md §4 substitution: Gym/Box2D → native Rust).
//!
//! All four classic-control tasks used by the paper's evaluation (Fig 8 /
//! Table 1) are provided with the same observation/action spaces, reward
//! functions and termination rules as the Gym versions the paper ran:
//!
//! * [`CartPole`]   — 4-dim obs, 2 actions, +1 per upright step.
//! * [`Acrobot`]    — 6-dim obs, 3 actions, −1 per step until swing-up.
//! * [`LunarLander`] — 8-dim obs, 4 actions, shaped landing reward
//!   (simplified rigid-body replacement for Box2D, same interface).
//! * [`MountainCar`] — 2-dim obs, 3 actions, −1 per step.

mod acrobot;
mod cartpole;
mod lunar_lander;
mod mountain_car;
mod pong_proxy;

pub use acrobot::Acrobot;
pub use cartpole::CartPole;
pub use lunar_lander::LunarLander;
pub use mountain_car::MountainCar;
pub use pong_proxy::PongProxy;

use crate::util::Rng;

/// Result of one environment step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepResult {
    pub obs: Vec<f32>,
    pub reward: f32,
    /// Episode ended in a terminal state (used for the TD bootstrap mask).
    pub terminated: bool,
    /// Episode hit the time limit (no bootstrap mask; Gym's `truncated`).
    pub truncated: bool,
}

impl StepResult {
    pub fn done(&self) -> bool {
        self.terminated || self.truncated
    }
}

/// A discrete-action RL environment (the Gym API surface the agent needs).
pub trait Environment: Send {
    /// Observation dimensionality.
    fn obs_dim(&self) -> usize;
    /// Number of discrete actions.
    fn n_actions(&self) -> usize;
    /// Reset to a fresh episode; returns the initial observation.
    fn reset(&mut self, rng: &mut Rng) -> Vec<f32>;
    /// Apply `action`; returns the transition result.
    fn step(&mut self, action: usize, rng: &mut Rng) -> StepResult;
    /// Environment name (matches the artifact/env-spec key).
    fn name(&self) -> &'static str;
    /// Max episode length (Gym time-limit wrapper).
    fn max_steps(&self) -> usize;
}

/// Construct an environment by name (the manifest/env-spec key).
pub fn make(name: &str) -> Option<Box<dyn Environment>> {
    match name {
        "cartpole" => Some(Box::new(CartPole::new())),
        "acrobot" => Some(Box::new(Acrobot::new())),
        "lunarlander" => Some(Box::new(LunarLander::new())),
        "mountaincar" => Some(Box::new(MountainCar::new())),
        "pongproxy" => Some(Box::new(PongProxy::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(env: &mut dyn Environment, seed: u64) {
        let mut rng = Rng::new(seed);
        let obs = env.reset(&mut rng);
        assert_eq!(obs.len(), env.obs_dim());
        let mut steps = 0;
        loop {
            let a = rng.below(env.n_actions());
            let r = env.step(a, &mut rng);
            assert_eq!(r.obs.len(), env.obs_dim());
            assert!(r.obs.iter().all(|x| x.is_finite()), "{}: {:?}", env.name(), r.obs);
            assert!(r.reward.is_finite());
            steps += 1;
            if r.done() {
                break;
            }
            assert!(steps <= env.max_steps(), "{} never terminates", env.name());
        }
        // must be resettable afterwards
        let obs2 = env.reset(&mut rng);
        assert_eq!(obs2.len(), env.obs_dim());
    }

    #[test]
    fn all_envs_step_and_terminate() {
        for name in ["cartpole", "acrobot", "lunarlander", "mountaincar"] {
            let mut env = make(name).unwrap();
            for seed in 0..3 {
                exercise(env.as_mut(), seed);
            }
        }
    }

    #[test]
    fn make_unknown_is_none() {
        assert!(make("atari-pong").is_none());
    }

    #[test]
    fn pongproxy_steps_and_scores() {
        let mut env = make("pongproxy").unwrap();
        let mut rng = Rng::new(0);
        let obs = env.reset(&mut rng);
        assert_eq!(obs.len(), 6400);
        for _ in 0..50 {
            let r = env.step(rng.below(6), &mut rng);
            assert_eq!(r.obs.len(), 6400);
            if r.done() {
                break;
            }
        }
    }

    #[test]
    fn spaces_match_manifest_specs() {
        let dims = [("cartpole", 4, 2), ("acrobot", 6, 3), ("lunarlander", 8, 4), ("mountaincar", 2, 3), ("pongproxy", 6400, 6)];
        for (name, obs, act) in dims {
            let env = make(name).unwrap();
            assert_eq!(env.obs_dim(), obs, "{name}");
            assert_eq!(env.n_actions(), act, "{name}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        for name in ["cartpole", "acrobot", "lunarlander", "mountaincar"] {
            let mut e1 = make(name).unwrap();
            let mut e2 = make(name).unwrap();
            let mut r1 = Rng::new(99);
            let mut r2 = Rng::new(99);
            assert_eq!(e1.reset(&mut r1), e2.reset(&mut r2));
            for _ in 0..50 {
                let a1 = r1.below(e1.n_actions());
                let a2 = r2.below(e2.n_actions());
                assert_eq!(a1, a2);
                let s1 = e1.step(a1, &mut r1);
                let s2 = e2.step(a2, &mut r2);
                assert_eq!(s1, s2, "{name} diverged");
                if s1.done() {
                    break;
                }
            }
        }
    }
}
