//! Pong-proxy: the Fig 4 "large network" workload (DESIGN.md §4
//! substitution for ALE Pong + CNN).
//!
//! A simple latent Pong-like game (ball + two paddles, the agent controls
//! the right paddle) whose 6400-dim observation is a fixed sparse random
//! projection of the latent state — the observation width and episode
//! structure of an 80×80 Atari difference frame, without ALE. The point
//! of the proxy is the *cost profile* (large first-layer GEMM, 6 actions,
//! long episodes), which is what the Fig 4 breakdown measures.

use super::{Environment, StepResult};
use crate::util::Rng;

/// Observation width (80x80 difference-frame equivalent).
pub const OBS_DIM: usize = 6400;
/// Atari action-set size used by the paper's Pong agent.
pub const N_ACTIONS: usize = 6;
const MAX_STEPS: usize = 1000;
/// Latent state: ball(x,y,vx,vy), paddles(y_left, y_right, vy_right).
const LATENT: usize = 7;
/// Projection sparsity: nonzeros per observation row.
const NNZ_PER_ROW: usize = 4;

/// The latent Pong-like environment with a high-dimensional observation.
pub struct PongProxy {
    s: [f32; LATENT],
    steps: usize,
    score: i32,
    /// Sparse projection: for each obs row, NNZ latent indices + weights.
    proj_idx: Vec<[u8; NNZ_PER_ROW]>,
    proj_w: Vec<[f32; NNZ_PER_ROW]>,
}

impl PongProxy {
    pub fn new() -> Self {
        // fixed projection, independent of episode RNG (part of the env
        // definition, like the pixel layout of the real game)
        let mut prng = Rng::new(0x506E_6750);
        let mut proj_idx = Vec::with_capacity(OBS_DIM);
        let mut proj_w = Vec::with_capacity(OBS_DIM);
        for _ in 0..OBS_DIM {
            let mut idx = [0u8; NNZ_PER_ROW];
            let mut w = [0f32; NNZ_PER_ROW];
            for k in 0..NNZ_PER_ROW {
                idx[k] = prng.below(LATENT) as u8;
                w[k] = prng.normal_f32(0.0, 1.0);
            }
            proj_idx.push(idx);
            proj_w.push(w);
        }
        PongProxy { s: [0.0; LATENT], steps: 0, score: 0, proj_idx, proj_w }
    }

    fn observe(&self) -> Vec<f32> {
        let mut obs = vec![0f32; OBS_DIM];
        for (i, o) in obs.iter_mut().enumerate() {
            let idx = &self.proj_idx[i];
            let w = &self.proj_w[i];
            let mut acc = 0f32;
            for k in 0..NNZ_PER_ROW {
                acc += w[k] * self.s[idx[k] as usize];
            }
            *o = acc;
        }
        obs
    }
}

impl Default for PongProxy {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for PongProxy {
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }

    fn n_actions(&self) -> usize {
        N_ACTIONS
    }

    fn name(&self) -> &'static str {
        "pongproxy"
    }

    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.s = [
            0.0,                          // ball x
            rng.range_f32(-0.3, 0.3),     // ball y
            if rng.chance(0.5) { 0.03 } else { -0.03 }, // ball vx
            rng.range_f32(-0.02, 0.02),   // ball vy
            0.0,                          // left paddle y
            0.0,                          // right paddle y
            0.0,                          // right paddle vy
        ];
        self.steps = 0;
        self.score = 0;
        self.observe()
    }

    fn step(&mut self, action: usize, rng: &mut Rng) -> StepResult {
        debug_assert!(action < N_ACTIONS);
        let [bx, by, bvx, bvy, lp, rp, _rv] = self.s;
        // Atari mapping: 0/1 noop, 2/4 up, 3/5 down
        let dv = match action {
            2 | 4 => 0.02,
            3 | 5 => -0.02,
            _ => 0.0,
        };
        let rp2 = (rp + dv).clamp(-0.4, 0.4);
        // simple opponent tracks the ball with lag
        let lp2 = (lp + 0.015 * (by - lp).signum()).clamp(-0.4, 0.4);
        let mut bx2 = bx + bvx;
        let mut by2 = by + bvy;
        let mut bvx2 = bvx;
        let mut bvy2 = bvy;
        // wall bounce
        if by2.abs() > 0.5 {
            by2 = by2.clamp(-0.5, 0.5);
            bvy2 = -bvy2;
        }
        let mut reward = 0.0f32;
        // paddle planes at x = ±0.5
        if bx2 >= 0.5 {
            if (by2 - rp2).abs() < 0.1 {
                bvx2 = -bvx2 * 1.02; // rally speeds up slightly
                bvy2 += 0.25 * (by2 - rp2) + rng.range_f32(-0.005, 0.005);
                bx2 = 0.5;
            } else {
                reward = -1.0; // missed: opponent scores
                self.score -= 1;
                bx2 = 0.0;
                by2 = rng.range_f32(-0.3, 0.3);
                bvx2 = -0.03;
                bvy2 = rng.range_f32(-0.02, 0.02);
            }
        } else if bx2 <= -0.5 {
            if (by2 - lp2).abs() < 0.1 {
                bvx2 = -bvx2 * 1.02;
                bvy2 += 0.25 * (by2 - lp2);
                bx2 = -0.5;
            } else {
                reward = 1.0; // we score
                self.score += 1;
                bx2 = 0.0;
                by2 = rng.range_f32(-0.3, 0.3);
                bvx2 = 0.03;
                bvy2 = rng.range_f32(-0.02, 0.02);
            }
        }
        self.s = [bx2, by2, bvx2, bvy2, lp2, rp2, dv];
        self.steps += 1;
        // first to ±21, as in Pong
        let terminated = self.score.abs() >= 21;
        let truncated = !terminated && self.steps >= MAX_STEPS;
        StepResult { obs: self.observe(), reward, terminated, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observation_width_matches_artifact_spec() {
        let mut env = PongProxy::new();
        let obs = env.reset(&mut Rng::new(0));
        assert_eq!(obs.len(), 6400);
        assert!(obs.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn rally_produces_rewards_eventually() {
        let mut env = PongProxy::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut saw_reward = false;
        for _ in 0..MAX_STEPS {
            let r = env.step(0, &mut rng); // noop: we will miss
            if r.reward != 0.0 {
                saw_reward = true;
                break;
            }
            if r.done() {
                break;
            }
        }
        assert!(saw_reward, "idle paddle should concede a point");
    }

    #[test]
    fn tracking_paddle_survives_longer_than_idle() {
        let run = |track: bool, seed: u64| -> i32 {
            let mut env = PongProxy::new();
            let mut rng = Rng::new(seed);
            env.reset(&mut rng);
            for _ in 0..600 {
                let a = if track {
                    if env.s[1] > env.s[5] { 2 } else { 3 }
                } else {
                    0
                };
                if env.step(a, &mut rng).done() {
                    break;
                }
            }
            env.score
        };
        let tracked: i32 = (0..3).map(|s| run(true, s)).sum();
        let idle: i32 = (0..3).map(|s| run(false, s)).sum();
        assert!(tracked > idle, "tracking {tracked} vs idle {idle}");
    }

    #[test]
    fn projection_is_deterministic_across_instances() {
        let mut a = PongProxy::new();
        let mut b = PongProxy::new();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(a.reset(&mut r1), b.reset(&mut r2));
    }
}
