//! MountainCar-v0 dynamics (Moore 1990), transcribed from Gym: position in
//! [−1.2, 0.6], velocity in [±0.07], actions {push-left, idle, push-right},
//! −1 per step, terminal at position ≥ 0.5, 200-step limit.

use super::{Environment, StepResult};
use crate::util::Rng;

const MIN_POS: f32 = -1.2;
const MAX_POS: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POS: f32 = 0.5;
const FORCE: f32 = 0.001;
const GRAVITY: f32 = 0.0025;
const MAX_STEPS: usize = 200;

/// The mountain-car task.
#[derive(Debug, Clone)]
pub struct MountainCar {
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCar {
    pub fn new() -> Self {
        MountainCar { pos: -0.5, vel: 0.0, steps: 0 }
    }
}

impl Default for MountainCar {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for MountainCar {
    fn obs_dim(&self) -> usize {
        2
    }

    fn n_actions(&self) -> usize {
        3
    }

    fn name(&self) -> &'static str {
        "mountaincar"
    }

    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = rng.range_f32(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        vec![self.pos, self.vel]
    }

    fn step(&mut self, action: usize, _rng: &mut Rng) -> StepResult {
        debug_assert!(action < 3);
        self.vel += (action as f32 - 1.0) * FORCE
            + (3.0 * self.pos).cos() * (-GRAVITY);
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos += self.vel;
        self.pos = self.pos.clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0; // inelastic wall
        }
        self.steps += 1;

        let terminated = self.pos >= GOAL_POS;
        let truncated = !terminated && self.steps >= MAX_STEPS;
        StepResult {
            obs: vec![self.pos, self.vel],
            reward: -1.0,
            terminated,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_never_escapes_valley() {
        let mut env = MountainCar::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        for _ in 0..MAX_STEPS {
            let r = env.step(1, &mut rng);
            assert!(!r.terminated);
            if r.done() {
                return;
            }
        }
        panic!("should truncate");
    }

    #[test]
    fn bang_bang_solves_it() {
        // Push in the direction of motion: classic solution.
        let mut env = MountainCar::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut solved = false;
        for _ in 0..MAX_STEPS {
            let a = if env.vel >= 0.0 { 2 } else { 0 };
            if env.step(a, &mut rng).terminated {
                solved = true;
                break;
            }
        }
        assert!(solved);
    }

    #[test]
    fn velocity_clamped() {
        let mut env = MountainCar::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        for _ in 0..100 {
            let r = env.step(2, &mut rng);
            assert!(r.obs[1].abs() <= MAX_SPEED + f32::EPSILON);
            if r.done() {
                break;
            }
        }
    }
}
