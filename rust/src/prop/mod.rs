//! Mini property-based testing framework (in-repo proptest substitute;
//! the crate registry is offline — DESIGN.md §4).
//!
//! Provides seeded case generation, configurable case counts
//! (`AMPER_PROP_CASES`), and greedy input shrinking on failure for the
//! common generator shapes the invariant tests need.
//!
//! ```no_run
//! // (no_run: 64 shrink-capable cases are pointless work in a doctest;
//! // the example is compile-checked only)
//! use amper::prop::{property, Gen};
//! property("sorted after sort", |g| {
//!     let mut v = g.vec_f32(0..200, 0.0, 1.0);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     v.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use crate::util::Rng;

/// Per-case input generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Trace of raw draws (reserved for replay/debug tooling).
    #[allow(dead_code)]
    trace: Vec<u64>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// usize in [range.start, range.end).
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.end > range.start);
        range.start + self.rng.below(range.end - range.start)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vec<f32> with length drawn from `len` and values in [lo, hi).
    pub fn vec_f32(&mut self, len: std::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vec<u32> with length from `len` and full-range values.
    pub fn vec_u32(&mut self, len: std::ops::Range<usize>) -> Vec<u32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.next_u32()).collect()
    }

    /// Access the underlying RNG for bespoke draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Number of cases per property (`AMPER_PROP_CASES`, default 64).
pub fn case_count() -> usize {
    std::env::var("AMPER_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `case_count()` seeded cases; panics with the failing
/// seed on the first counterexample so the case can be replayed by
/// constructing `Gen` with that seed.
pub fn property(name: &str, prop: impl Fn(&mut Gen) -> bool) {
    let base = 0x5EED_0000u64;
    for case in 0..case_count() as u64 {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 re-run with Gen seed to reproduce"
            );
        }
    }
}

/// Like [`property`] but the closure returns `Result` with a diagnostic.
pub fn property_res(
    name: &str,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    let base = 0x5EED_0000u64;
    for case in 0..case_count() as u64 {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_property_passes() {
        property("reverse twice is identity", |g| {
            let v = g.vec_u32(0..50);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            v == w
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        property("always false", |_| false);
    }

    #[test]
    fn generators_respect_bounds() {
        property("bounds", |g| {
            let x = g.usize_in(3..10);
            let f = g.f32_in(-1.0, 1.0);
            (3..10).contains(&x) && (-1.0..1.0).contains(&f)
        });
    }
}
