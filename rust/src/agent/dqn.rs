//! The DQN training loop driving the PJRT engine and a pluggable replay
//! memory — the workload of Fig 4 (profiling), Fig 8 (learning curves)
//! and Table 1 (test scores).

use crate::config::TrainConfig;
use crate::ensure;
use crate::envs::{self, Environment};
use crate::metrics::ReturnTracker;
use crate::profiling::{Phase, PhaseProfile};
use crate::replay::{Experience, ExperienceBatch, ReplayMemory, SampledBatch};
use crate::runtime::{ActScratch, Engine, TrainBatch, TrainScratch, TrainState};
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Everything a finished run reports.
pub struct TrainReport {
    /// Per-episode training returns (Fig 8 curves).
    pub returns: ReturnTracker,
    /// Loss every train step (sampled every `loss_stride`).
    pub losses: Vec<f32>,
    /// Phase latency breakdown (Fig 4).
    pub profile: PhaseProfile,
    /// Mean greedy return over the configured test episodes (Table 1).
    pub test_score: f64,
    /// Env steps executed.
    pub steps: u64,
    /// Modeled AM-device time (hw-backed replay only).
    pub modeled_replay_ns: Option<f64>,
}

/// The agent: engine + state + env + replay.
pub struct DqnAgent {
    engine: Engine,
    state: TrainState,
    env: Box<dyn Environment>,
    replay: Box<dyn ReplayMemory>,
    config: TrainConfig,
    rng: Rng,
    batch_scratch: TrainBatch,
    /// Sampled indices/weights scratch reused across train steps (the
    /// batch-first loop is allocation-free after warmup).
    sampled_scratch: SampledBatch,
    /// Engine activation scratch reused across train steps.
    train_scratch: TrainScratch,
    /// Inference scratch reused across act calls (no per-action
    /// activation or output allocation).
    act_scratch: ActScratch,
    global_step: u64,
}

impl DqnAgent {
    /// Build an agent from a config (loads artifacts, makes env + replay).
    pub fn new(mut config: TrainConfig) -> Result<DqnAgent> {
        let mut engine = Engine::load(
            std::path::Path::new(&config.artifacts_dir),
            &config.env,
        )?;
        // size the kernel worker pool from the config (0 = machine
        // default; 1 = sequential). Bit-identical either way.
        engine.set_threads(config.engine_threads);
        // the train graph is lowered for a fixed batch; the artifact wins
        if config.batch != engine.spec().batch {
            config.batch = engine.spec().batch;
        }
        let env = envs::make(&config.env)
            .with_context(|| format!("unknown env '{}'", config.env))?;
        ensure!(
            env.obs_dim() == engine.spec().obs_dim,
            "env/artifact obs_dim mismatch"
        );
        // replay configured with the experiment's PER/AMPER params; the
        // AMPER CSP chunk-sort shares the engine's worker pool
        let mut replay = Self::configured_replay(&config);
        replay.set_thread_pool(std::sync::Arc::clone(engine.pool()));
        let state = TrainState::init(engine.spec(), config.seed)?;
        let batch_scratch =
            TrainBatch::zeros(engine.spec().batch, engine.spec().obs_dim);
        let rng = Rng::new(config.seed.wrapping_mul(0x9E3779B9).wrapping_add(1));
        Ok(DqnAgent {
            engine,
            state,
            env,
            replay,
            config,
            rng,
            batch_scratch,
            sampled_scratch: SampledBatch::default(),
            train_scratch: TrainScratch::default(),
            act_scratch: ActScratch::default(),
            global_step: 0,
        })
    }

    fn configured_replay(config: &TrainConfig) -> Box<dyn ReplayMemory> {
        use crate::replay::{registry, NStepReplay};
        let d = registry::find(config.replay.name()).unwrap_or_else(|| {
            panic!("replay technique '{}' is not registered", config.replay.name())
        });
        // `hw_replay` routes through the simulated accelerator when the
        // technique has a hardware build; software-only techniques fall
        // back to the normal build (same behavior the old match had for
        // uniform/PER with the flag set)
        let base: Box<dyn ReplayMemory> = match (config.hw_replay, d.hw_build) {
            (true, Some(hw)) => {
                hw(config.er_size, &config.replay_params, config.seed)
            }
            _ => (d.build)(config.er_size, &config.replay_params),
        };
        if config.nstep > 1 {
            Box::new(NStepReplay::new(base, config.nstep, 0.99))
        } else {
            base
        }
    }

    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    pub fn replay(&self) -> &dyn ReplayMemory {
        self.replay.as_ref()
    }

    /// Mutable access to the replay memory (the interplay study draws
    /// post-training samples to measure the sampling distribution).
    pub fn replay_mut(&mut self) -> &mut dyn ReplayMemory {
        self.replay.as_mut()
    }

    /// Current exploration rate (linear decay).
    pub fn epsilon(&self) -> f32 {
        let c = &self.config;
        if self.global_step >= c.eps_decay_steps {
            return c.eps_end;
        }
        let frac = self.global_step as f32 / c.eps_decay_steps as f32;
        c.eps_start + (c.eps_end - c.eps_start) * frac
    }

    /// Fill the replay memory with `n` random-policy transitions without
    /// training (used by the Fig 4 profiler so ER-size cells are profiled
    /// at capacity, and available for offline warm starts).
    pub fn prefill(&mut self, n: usize) {
        // batch-first ingest: accumulate transitions into a flat batch
        // and store them with chunked ring memcpys instead of per-step
        // Experience allocations
        const CHUNK: usize = 1024;
        let mut env_rng = self.rng.fork(0xF111);
        let mut obs = self.env.reset(&mut env_rng);
        let mut pending =
            ExperienceBatch::with_capacity(self.env.obs_dim(), CHUNK.min(n));
        let mut slots = Vec::new();
        let mut remaining = n;
        while remaining > 0 {
            let action = self.rng.below(self.env.n_actions());
            let step = self.env.step(action, &mut env_rng);
            pending.push_parts(
                &obs,
                action as u32,
                step.reward,
                &step.obs,
                step.terminated,
            );
            remaining -= 1;
            if pending.len() >= CHUNK || remaining == 0 {
                slots.clear();
                self.replay.push_batch(&pending, &mut self.rng, &mut slots);
                pending.clear();
            }
            obs = if step.done() {
                self.env.reset(&mut env_rng)
            } else {
                step.obs
            };
        }
        // spread priorities so prioritized samplers see realistic data
        let len = self.replay.len();
        let idx: Vec<usize> = (0..len).collect();
        let tds: Vec<f32> = (0..len).map(|_| self.rng.f32()).collect();
        self.replay.update_priorities_batch(&idx, &tds);
    }

    /// Run the configured number of env steps; returns the full report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let steps = self.config.steps;
        self.run_steps(steps)
    }

    /// Run `steps` env steps (callable repeatedly for curriculum tests).
    pub fn run_steps(&mut self, steps: u64) -> Result<TrainReport> {
        let mut profile = PhaseProfile::new();
        let mut returns = ReturnTracker::new();
        let mut losses = Vec::new();
        let mut env_rng = self.rng.fork(0xE);
        let mut obs = self.env.reset(&mut env_rng);

        for _ in 0..steps {
            self.global_step += 1;
            // ---- action phase (network inference or explore) ----
            let eps = self.epsilon();
            let action = if self.rng.chance(eps as f64) {
                self.rng.below(self.env.n_actions())
            } else {
                let t = crate::util::Timer::start();
                let a =
                    self.engine.act(&self.state, &obs, &mut self.act_scratch)?;
                profile.add(Phase::Action, t.ns());
                a
            };

            // ---- env dynamics (excluded from the paper's breakdown) ----
            let t = crate::util::Timer::start();
            let step = self.env.step(action, &mut env_rng);
            profile.add(Phase::Env, t.ns());
            returns.push_reward(step.reward as f64);

            // ---- store phase ----
            let exp = Experience {
                obs: obs.clone(),
                action: action as u32,
                reward: step.reward,
                // bootstrap mask uses `terminated` only (not time limits)
                done: step.terminated,
                next_obs: step.obs.clone(),
            };
            let t = crate::util::Timer::start();
            self.replay.push(exp, &mut self.rng);
            profile.add(Phase::Store, t.ns());

            obs = if step.done() {
                let score = returns.end_episode(self.global_step);
                crate::debug!(
                    "step {} episode {} return {:.1} eps {:.2}",
                    self.global_step,
                    returns.n_episodes(),
                    score,
                    eps
                );
                self.env.reset(&mut env_rng)
            } else {
                step.obs
            };

            // ---- learn ----
            if self.global_step >= self.config.warmup
                && self.global_step % self.config.train_every == 0
                && self.replay.len() >= self.config.batch
            {
                // ER operation: sample (timed; priority update timed below
                // into the same phase, matching the paper's accounting).
                // Batch-first path: sample_into reuses the index/weight
                // scratch, the gather stages straight into the flat
                // TrainBatch columns, and the TD feedback goes through
                // the single-pass batched update.
                let t = crate::util::Timer::start();
                self.replay.sample_into(
                    self.config.batch,
                    &mut self.rng,
                    &mut self.sampled_scratch,
                );
                let sample_ns = t.ns();

                self.gather_sampled()?;

                let t = crate::util::Timer::start();
                let out = self.engine.train_step_scratch(
                    &mut self.state,
                    self.batch_scratch.view(),
                    &mut self.train_scratch,
                )?;
                profile.add(Phase::Train, t.ns());

                let t = crate::util::Timer::start();
                self.replay
                    .update_priorities_batch(&self.sampled_scratch.indices, &out.td);
                profile.add(Phase::ErOp, sample_ns + t.ns());

                if losses.len() < 100_000 {
                    losses.push(out.loss);
                }
                // hand the TD buffer back — the next step refills it in
                // place instead of allocating
                self.train_scratch.recycle(out);
            }

            if self.global_step % self.config.target_sync == 0 {
                self.state.sync_target()?;
            }
        }

        let test_score = self.test(self.config.test_episodes)?;
        Ok(TrainReport {
            returns,
            losses,
            profile,
            test_score,
            steps,
            modeled_replay_ns: self.replay.modeled_device_ns(),
        })
    }

    /// Stage the sampled transitions into the flat engine batch. Index
    /// validation happens inside [`ExperienceRing::gather`]
    /// (release builds included) and surfaces here as an error.
    ///
    /// [`ExperienceRing::gather`]: crate::replay::ExperienceRing::gather
    fn gather_sampled(&mut self) -> Result<()> {
        let ring = self.replay.ring();
        ring.gather(
            &self.sampled_scratch.indices,
            &mut self.batch_scratch.obs,
            &mut self.batch_scratch.actions,
            &mut self.batch_scratch.rewards,
            &mut self.batch_scratch.next_obs,
            &mut self.batch_scratch.dones,
        )?;
        self.batch_scratch
            .is_weights
            .copy_from_slice(&self.sampled_scratch.is_weights);
        Ok(())
    }

    /// Greedy evaluation: mean return over `episodes` (paper: "the test
    /// score is the average return of 10 episodes").
    pub fn test(&mut self, episodes: usize) -> Result<f64> {
        if episodes == 0 {
            return Ok(0.0);
        }
        let mut env_rng = self.rng.fork(0x7E57);
        let mut total = 0.0;
        for _ in 0..episodes {
            let mut obs = self.env.reset(&mut env_rng);
            let mut ep = 0.0;
            loop {
                let a =
                    self.engine.act(&self.state, &obs, &mut self.act_scratch)?;
                let step = self.env.step(a, &mut env_rng);
                ep += step.reward as f64;
                if step.done() {
                    break;
                }
                obs = step.obs;
            }
            total += ep;
        }
        Ok(total / episodes as f64)
    }
}
