//! The online DQN agent (paper Fig 1): ε-greedy action network, target
//! network with periodic sync, ER memory, and the per-step loop
//! store → sample → train → update-priorities, instrumented per phase.

pub mod dqn;

pub use dqn::{DqnAgent, TrainReport};
