//! Bit-accurate functional simulation + analytic latency model of the
//! AMPER in-memory-computing accelerator (paper §3.4, Fig 6).
//!
//! Components, mirroring Fig 6a:
//! * [`tcam`] — ternary CAM arrays (64×64) with exact-match and
//!   best-match sensing;
//! * [`urng`] — the 32-bit LFSR uniform random number generator;
//! * [`query_gen`] — kNN and frNN (prefix-mask) query generators at the
//!   bit level;
//! * [`csb`] — the candidate-set buffer;
//! * [`latency`] — Table 2's synthesized component delays and the
//!   composition rules (DESIGN.md §3: circuit → analytic event model);
//! * [`accelerator`] — the full device: stores quantized priorities,
//!   executes Algorithm 1 sample/update flows, and reports per-operation
//!   latency by counting the events the real hardware would execute;
//! * [`gpu_model`] — the paper's published PER-on-GPU reference series
//!   (Fig 9a comparison baseline).

pub mod accelerator;
pub mod csb;
pub mod gpu_model;
pub mod latency;
pub mod query_gen;
pub mod tcam;
pub mod urng;

pub use accelerator::{AmperAccelerator, SampleOutcome};
pub use latency::{LatencyModel, LatencyReport};
pub use tcam::{TcamArray, TcamBank};
pub use urng::Lfsr32;
