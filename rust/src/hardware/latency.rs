//! The analytic latency model: Table 2's circuit-level component delays
//! (45 nm CMOS synthesis + CACTI for the CSB) and the composition rules
//! that turn functional-simulation event counts into end-to-end latency
//! (DESIGN.md §3 Hardware-Adaptation).
//!
//! All delays in nanoseconds.

/// Component delays (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    /// TCAM exact-match search (Ni et al. [14] sensing).
    pub tcam_search_exact_ns: f64,
    /// TCAM best-match search (Dutta et al. [20] WTA sensing).
    pub tcam_search_best_ns: f64,
    /// TCAM row write.
    pub tcam_write_ns: f64,
    /// Candidate-set-buffer read (CACTI, 0.03 MB).
    pub csb_read_ns: f64,
    /// Candidate-set-buffer write.
    pub csb_write_ns: f64,
    /// URNG 32-bit word generation (synthesized LFSR).
    pub urng_ns: f64,
    /// Query generator, kNN variant (multiplier).
    pub qg_knn_ns: f64,
    /// Query generator, frNN variant (multiplier + mask + OR).
    pub qg_frnn_ns: f64,
}

impl Default for LatencyModel {
    /// Table 2 values.
    fn default() -> Self {
        LatencyModel {
            tcam_search_exact_ns: 0.58,
            tcam_search_best_ns: 1.0,
            tcam_write_ns: 2.0,
            csb_read_ns: 0.78,
            csb_write_ns: 0.78,
            urng_ns: 1.71,
            qg_knn_ns: 3.57,
            qg_frnn_ns: 2.02,
        }
    }
}

/// Event counts gathered by one accelerator operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EventCounts {
    pub urng_draws: u64,
    pub qg_knn_ops: u64,
    pub qg_frnn_ops: u64,
    /// Bank-parallel exact searches (all arrays count as one event).
    pub exact_searches: u64,
    /// Bank-parallel best-match searches.
    pub best_searches: u64,
    pub tcam_writes: u64,
    pub csb_writes: u64,
    pub csb_reads: u64,
}

impl EventCounts {
    /// Total latency under `model`.
    ///
    /// Composition (paper §3.4 dataflow, Fig 6a):
    /// * TCAM arrays evaluate a query in parallel → one search = one
    ///   search delay regardless of array count;
    /// * candidate collection serializes through the CSB write port;
    /// * the batch draw serializes URNG + CSB read per element;
    /// * priority updates go straight to the TCAM write ports (§3.4.3) —
    ///   independent rows in different arrays write concurrently, so
    ///   writes are charged per *conflicting* row (caller decides; the
    ///   default accounting charges them serially, a conservative bound).
    pub fn latency_ns(&self, model: &LatencyModel) -> f64 {
        self.urng_draws as f64 * model.urng_ns
            + self.qg_knn_ops as f64 * model.qg_knn_ns
            + self.qg_frnn_ops as f64 * model.qg_frnn_ns
            + self.exact_searches as f64 * model.tcam_search_exact_ns
            + self.best_searches as f64 * model.tcam_search_best_ns
            + self.tcam_writes as f64 * model.tcam_write_ns
            + self.csb_writes as f64 * model.csb_write_ns
            + self.csb_reads as f64 * model.csb_read_ns
    }

    pub fn add(&mut self, other: &EventCounts) {
        self.urng_draws += other.urng_draws;
        self.qg_knn_ops += other.qg_knn_ops;
        self.qg_frnn_ops += other.qg_frnn_ops;
        self.exact_searches += other.exact_searches;
        self.best_searches += other.best_searches;
        self.tcam_writes += other.tcam_writes;
        self.csb_writes += other.csb_writes;
        self.csb_reads += other.csb_reads;
    }
}

/// A latency report for one operation: events + derived total.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyReport {
    pub events: EventCounts,
    pub total_ns: f64,
}

impl LatencyReport {
    pub fn from_events(events: EventCounts, model: &LatencyModel) -> Self {
        LatencyReport { events, total_ns: events.latency_ns(model) }
    }
}

/// Pretty-print the Table 2 component rows (bench `table2_components`).
pub fn table2_rows(model: &LatencyModel) -> Vec<(String, f64)> {
    vec![
        ("TCAM search (exact)".into(), model.tcam_search_exact_ns),
        ("TCAM search (best)".into(), model.tcam_search_best_ns),
        ("TCAM write".into(), model.tcam_write_ns),
        ("CSB read".into(), model.csb_read_ns),
        ("CSB write".into(), model.csb_write_ns),
        ("URNG".into(), model.urng_ns),
        ("QG (kNN)".into(), model.qg_knn_ns),
        ("QG (frNN)".into(), model.qg_frnn_ns),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_defaults() {
        let m = LatencyModel::default();
        assert_eq!(m.tcam_search_exact_ns, 0.58);
        assert_eq!(m.tcam_search_best_ns, 1.0);
        assert_eq!(m.tcam_write_ns, 2.0);
        assert_eq!(m.csb_read_ns, 0.78);
        assert_eq!(m.urng_ns, 1.71);
        assert_eq!(m.qg_knn_ns, 3.57);
        assert_eq!(m.qg_frnn_ns, 2.02);
    }

    #[test]
    fn latency_composes_linearly() {
        let m = LatencyModel::default();
        let e = EventCounts {
            urng_draws: 2,
            exact_searches: 1,
            csb_writes: 10,
            csb_reads: 4,
            ..Default::default()
        };
        let want = 2.0 * 1.71 + 0.58 + 10.0 * 0.78 + 4.0 * 0.78;
        assert!((e.latency_ns(&m) - want).abs() < 1e-9);
    }

    #[test]
    fn best_match_sensing_costs_more() {
        // the paper's 1.7x sensing overhead claim
        let m = LatencyModel::default();
        let ratio = m.tcam_search_best_ns / m.tcam_search_exact_ns;
        assert!((ratio - 1.724).abs() < 0.01);
    }

    #[test]
    fn add_accumulates() {
        let mut a = EventCounts { urng_draws: 1, ..Default::default() };
        a.add(&EventCounts { urng_draws: 2, csb_writes: 3, ..Default::default() });
        assert_eq!(a.urng_draws, 3);
        assert_eq!(a.csb_writes, 3);
    }
}
