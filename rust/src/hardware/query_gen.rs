//! Query generators (paper Fig 6b1/b2), modeled at the bit level.
//!
//! * **kNN QG** (Fig 6b1): a Q-bit multiplier computes the subset size
//!   `N_i = λ·V(g_i)·C(g_i)`; the query word is `V(g_i)` itself, reissued
//!   `N_i` times to the best-match TCAMs.
//! * **frNN QG** (Fig 6b2): a Q-bit multiplier computes
//!   `Δ_i = λ′/m · V(g_i)`; the mask generator locates the leftmost '1'
//!   of `Δ_i` (position `p`) and ORs don't-cares into bits `p..0` of the
//!   query — three gate stages, no iteration.
//!
//! Arithmetic is Q16.16 fixed point end to end, matching what the TCAM
//! rows store ([`crate::replay::amper::quant`]).

use crate::replay::amper::quant;

/// Fixed-point multiply: (Q16.16 × Q16.16) >> 16 → Q16.16, saturating.
#[inline]
pub fn qmul(a: u32, b: u32) -> u32 {
    let wide = (a as u64 * b as u64) >> quant::FRAC_BITS;
    wide.min(u32::MAX as u64) as u32
}

/// kNN query generator: `N_i = round(λ · V(g_i) · C(g_i))` (Eq. 1).
/// `lambda_q` and `v_q` are Q16.16; `count` is an integer. Returns the
/// integer subset size.
#[inline]
pub fn knn_subset_size(lambda_q: u32, v_q: u32, count: u32) -> u32 {
    // λ·V in Q16.16, then times count with rounding at the radix point
    let lv = qmul(lambda_q, v_q) as u64;
    let prod = lv * count as u64;
    let rounded = (prod + (1 << (quant::FRAC_BITS - 1))) >> quant::FRAC_BITS;
    rounded.min(u32::MAX as u64) as u32
}

/// frNN radius: `Δ_i = λ′/m · V(g_i)` (Eq. 4), Q16.16 in, Q16.16 out.
/// `lambda_prime_over_m_q` is the precomputed λ′/m constant.
#[inline]
pub fn frnn_delta(lambda_prime_over_m_q: u32, v_q: u32) -> u32 {
    qmul(lambda_prime_over_m_q, v_q)
}

/// The frNN mask generator + OR stage (Fig 6b2): produce the ternary
/// query `(word, care)` for representative `v_q` and radius `delta_q`.
/// Delegates to the algorithm-level implementation so hardware and
/// software are bit-identical by construction.
#[inline]
pub fn frnn_query(v_q: u32, delta_q: u32) -> (u32, u32) {
    let care = crate::replay::amper::frnn::care_mask_for_delta(delta_q);
    (v_q & care, care)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmul_matches_float() {
        for (a, b) in [(1.5f32, 2.0f32), (0.25, 0.5), (100.0, 0.01), (3.75, 3.75)] {
            let got = quant::dequantize(qmul(quant::quantize(a), quant::quantize(b)));
            assert!((got - a * b).abs() < 1e-3, "{a}*{b}: {got}");
        }
    }

    #[test]
    fn qmul_saturates() {
        assert_eq!(qmul(u32::MAX, u32::MAX), u32::MAX);
    }

    #[test]
    fn knn_size_matches_eq1() {
        // λ=0.15, V=0.7, C=1000 → N = round(105) = 105
        let n = knn_subset_size(quant::quantize(0.15), quant::quantize(0.7), 1000);
        assert_eq!(n, 105);
        // λ=0.05, V=0.5, C=10 → round(0.25) = 0
        let n = knn_subset_size(quant::quantize(0.05), quant::quantize(0.5), 10);
        assert_eq!(n, 0);
    }

    #[test]
    fn frnn_delta_matches_eq4() {
        // λ'=3, m=20 → λ'/m = 0.15; V=0.8 → Δ = 0.12
        let d = frnn_delta(quant::quantize(0.15), quant::quantize(0.8));
        assert!((quant::dequantize(d) - 0.12).abs() < 1e-3);
    }

    #[test]
    fn frnn_query_covers_v() {
        let v_q = quant::quantize(0.63);
        let (word, care) = frnn_query(v_q, quant::quantize(0.05));
        assert_eq!(v_q & care, word);
        // v itself must match its own query
        assert_eq!((v_q ^ word) & care, 0);
    }

    #[test]
    fn zero_delta_is_exact_query() {
        let v_q = quant::quantize(0.5);
        let (word, care) = frnn_query(v_q, 0);
        assert_eq!(care, u32::MAX);
        assert_eq!(word, v_q);
    }
}
