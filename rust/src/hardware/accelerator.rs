//! The assembled AMPER accelerator (paper Fig 6a): TCAM bank + URNG +
//! query generator + candidate-set buffer, executing Algorithm 1's
//! sample and update flows.
//!
//! The simulation is *functional* — it computes exactly which slots a
//! real device would select, on the same Q16.16 words — and *event
//! timed*: every operation increments the event counters of
//! [`LatencyModel`]-priced components, so `report.total_ns` is the
//! latency the paper's Fig 9 reports, derived from Table 2.

use super::csb::CandidateSetBuffer;
use super::latency::{EventCounts, LatencyModel, LatencyReport};
use super::query_gen;
use super::tcam::TcamBank;
use super::urng::Lfsr32;
use crate::replay::amper::{quant, Variant};

/// Result of one sampling operation.
#[derive(Debug, Clone)]
pub struct SampleOutcome {
    /// Sampled slot ids (length = requested batch).
    pub indices: Vec<usize>,
    /// Size of the CSP that was staged in the CSB.
    pub csp_len: usize,
    /// Event counts + total latency.
    pub report: LatencyReport,
}

/// Accelerator configuration.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    /// Group count m.
    pub m: usize,
    /// λ (kNN subset scaling), Q16.16 at runtime.
    pub lambda: f32,
    /// λ′ (frNN radius scaling).
    pub lambda_prime: f32,
    /// CSB capacity (entries).
    pub csb_capacity: usize,
}

impl Default for AccelConfig {
    fn default() -> Self {
        // matched to AmperParams::default(): kNN CSP ≈ λ/2, frNN ≈ 0.75λ′
        AccelConfig {
            m: 20,
            lambda: 0.3,
            lambda_prime: 0.2,
            csb_capacity: CandidateSetBuffer::PAPER_CAPACITY,
        }
    }
}

/// The AMPER in-memory-computing device.
#[derive(Debug)]
pub struct AmperAccelerator {
    bank: TcamBank,
    csb: CandidateSetBuffer,
    urng: Lfsr32,
    model: LatencyModel,
    config: AccelConfig,
    /// Cached maximum stored priority (functional bookkeeping; the
    /// device tracks it with a comparator on the write path).
    vmax_q: u32,
    /// Set when an update may have lowered the max (rescan needed).
    vmax_dirty: bool,
    occupied: usize,
}

impl AmperAccelerator {
    pub fn new(slots: usize, config: AccelConfig, seed: u32) -> Self {
        AmperAccelerator {
            bank: TcamBank::new(slots),
            csb: CandidateSetBuffer::new(config.csb_capacity),
            urng: Lfsr32::new(seed),
            model: LatencyModel::default(),
            config,
            vmax_q: 0,
            vmax_dirty: false,
            occupied: 0,
        }
    }

    pub fn config(&self) -> &AccelConfig {
        &self.config
    }

    pub fn model(&self) -> &LatencyModel {
        &self.model
    }

    pub fn bank(&self) -> &TcamBank {
        &self.bank
    }

    pub fn occupied(&self) -> usize {
        self.occupied
    }

    /// Store (or overwrite) the priority of `slot`. One TCAM row write
    /// (§3.4.3: "we write the new priority value in AM directly").
    pub fn write_priority(&mut self, slot: usize, priority: f32) -> LatencyReport {
        let q = quant::quantize(priority);
        if !self.bank.is_valid(slot) {
            self.occupied += 1;
        } else if self.bank.value(slot) == self.vmax_q && q < self.vmax_q {
            self.vmax_dirty = true;
        }
        self.bank.write(slot, q);
        if q > self.vmax_q {
            self.vmax_q = q;
        }
        let events = EventCounts { tcam_writes: 1, ..Default::default() };
        LatencyReport::from_events(events, &self.model)
    }

    /// Batched priority update after training: one write per slot,
    /// charged serially (conservative; rows in distinct arrays could
    /// overlap).
    pub fn update_priorities(&mut self, slots: &[usize], priorities: &[f32]) -> LatencyReport {
        debug_assert_eq!(slots.len(), priorities.len());
        let mut events = EventCounts::default();
        for (&s, &p) in slots.iter().zip(priorities) {
            let r = self.write_priority(s, p);
            events.add(&r.events);
        }
        LatencyReport::from_events(events, &self.model)
    }

    fn refresh_vmax(&mut self) {
        if !self.vmax_dirty {
            return;
        }
        let mut vmax = 0u32;
        for s in 0..self.bank.slots() {
            if self.bank.is_valid(s) {
                vmax = vmax.max(self.bank.value(s));
            }
        }
        self.vmax_q = vmax;
        self.vmax_dirty = false;
    }

    /// Per-group occupancy counts, computed in one pass over the bank —
    /// the per-group counters the kNN variant needs (§3.3 notes this
    /// extra circuitry; the real counters update on the write path, so
    /// no latency is charged at sample time). §Perf: one O(slots) pass
    /// per sample instead of one per group.
    fn group_counts(&self) -> Vec<u32> {
        let m = self.config.m;
        let mut counts = vec![0u32; m];
        if self.vmax_q == 0 {
            return counts;
        }
        for s in 0..self.bank.slots() {
            if !self.bank.is_valid(s) {
                continue;
            }
            let v = self.bank.value(s) as u64;
            // group i covers [vmax*i/m, vmax*(i+1)/m); top value -> last
            let g = ((v * m as u64) / self.vmax_q as u64).min(m as u64 - 1);
            counts[g as usize] += 1;
        }
        counts
    }

    /// Ascending (value, slot) index over valid slots — the functional
    /// shortcut for repeated best-match search (§Perf): N_i successive
    /// winner-masked best-match searches from query v return exactly the
    /// N_i stored values nearest to v, ties to the lower slot, which a
    /// two-pointer walk over this index yields in O(log n + N_i).
    fn sorted_index(&self) -> Vec<(u32, usize)> {
        let mut idx: Vec<(u32, usize)> = (0..self.bank.slots())
            .filter(|&s| self.bank.is_valid(s))
            .map(|s| (self.bank.value(s), s))
            .collect();
        idx.sort_unstable();
        idx
    }

    /// Draw the per-group representatives V(g_i) with the URNG
    /// (Algorithm 1 line 3). Exposed for bit-level cross-validation.
    pub fn draw_representatives(&mut self, events: &mut EventCounts) -> Vec<u32> {
        self.refresh_vmax();
        let m = self.config.m;
        let mut reps = Vec::with_capacity(m);
        for i in 0..m {
            let lo = (self.vmax_q as u64 * i as u64 / m as u64) as u32;
            let hi = (self.vmax_q as u64 * (i + 1) as u64 / m as u64) as u32;
            events.urng_draws += 1;
            reps.push(if hi > lo { self.urng.range_q(lo, hi) } else { lo });
        }
        reps
    }

    /// Build the CSP for explicit representatives (bit-level testing and
    /// the sample flow). Returns event counts incurred.
    pub fn build_csp(&mut self, variant: Variant, reps_q: &[u32]) -> EventCounts {
        self.refresh_vmax();
        let mut events = EventCounts::default();
        self.csb.reset();
        if self.vmax_q == 0 {
            // degenerate all-zero priorities: no groups (matches the
            // software implementation; the sampler falls back to uniform)
            return events;
        }
        let m = self.config.m;
        debug_assert_eq!(reps_q.len(), m);
        let lambda_q = quant::quantize(self.config.lambda);
        let lpm_q = quant::quantize(self.config.lambda_prime / m as f32);

        // kNN state built lazily (one pass each, only for the kNN variant)
        let (counts, sorted) = match variant {
            Variant::Knn => (self.group_counts(), self.sorted_index()),
            Variant::Frnn => (Vec::new(), Vec::new()),
        };

        for (i, &v_q) in reps_q.iter().enumerate() {
            if self.csb.len() >= self.csb.capacity() {
                break;
            }
            match variant {
                Variant::Knn => {
                    // QG computes N_i from λ, V, C(g_i) (Fig 6b1)
                    events.qg_knn_ops += 1;
                    let count = counts[i];
                    if count == 0 {
                        continue;
                    }
                    let n_i = query_gen::knn_subset_size(lambda_q, v_q, count)
                        .max(1)
                        .min(self.occupied as u32);
                    // Functionally: N_i successive winner-masked
                    // best-match searches (§3.4.1) return the N_i stored
                    // values nearest to V(g_i) (the paper's multi-bit-CAM
                    // NN sensing [19,21]), ties to the lower row — i.e. a
                    // two-pointer walk of the sorted index. Each winner
                    // is charged one best-match search + one CSB write.
                    let pivot = sorted.partition_point(|&(val, _)| val < v_q);
                    let mut lo = pivot as isize - 1;
                    let mut hi = pivot;
                    for _ in 0..n_i {
                        events.best_searches += 1;
                        let take_lo = if lo < 0 {
                            false
                        } else if hi >= sorted.len() {
                            true
                        } else {
                            v_q - sorted[lo as usize].0 <= sorted[hi].0 - v_q
                        };
                        let slot = if take_lo {
                            let s = sorted[lo as usize].1;
                            lo -= 1;
                            s
                        } else if hi < sorted.len() {
                            let s = sorted[hi].1;
                            hi += 1;
                            s
                        } else {
                            break;
                        };
                        events.csb_writes += 1;
                        if !self.csb.push(slot as u32) {
                            break;
                        }
                    }
                }
                Variant::Frnn => {
                    // QG computes Δ_i and the prefix mask (Fig 6b2)
                    events.qg_frnn_ops += 1;
                    let delta_q = query_gen::frnn_delta(lpm_q, v_q);
                    let (word, care) = query_gen::frnn_query(v_q, delta_q);
                    // one bank-parallel exact-match search (§3.4.2)
                    events.exact_searches += 1;
                    let budget = self.csb.capacity() - self.csb.len();
                    let mut hits = Vec::new();
                    self.bank.search_exact(word, care, budget, &mut hits);
                    for slot in hits {
                        events.csb_writes += 1;
                        if !self.csb.push(slot as u32) {
                            break;
                        }
                    }
                }
            }
        }
        events
    }

    /// Full sampling operation (Algorithm 1): representatives → CSP →
    /// uniform batch draw from the CSB.
    pub fn sample(&mut self, batch: usize, variant: Variant) -> SampleOutcome {
        assert!(self.occupied > 0, "cannot sample an empty accelerator");
        let mut events = EventCounts::default();
        let reps = self.draw_representatives(&mut events);
        let csp_events = self.build_csp(variant, &reps);
        events.add(&csp_events);

        let mut indices = Vec::with_capacity(batch);
        if self.csb.is_empty() {
            // degenerate fallback: uniform over occupied slots
            for _ in 0..batch {
                events.urng_draws += 1;
                let mut slot = self.urng.below(self.bank.slots() as u32) as usize;
                while !self.bank.is_valid(slot) {
                    slot = (slot + 1) % self.bank.slots();
                }
                indices.push(slot);
            }
        } else {
            for _ in 0..batch {
                events.urng_draws += 1;
                let i = self.urng.below(self.csb.len() as u32) as usize;
                events.csb_reads += 1;
                indices.push(self.csb.read(i) as usize);
            }
        }
        SampleOutcome {
            indices,
            csp_len: self.csb.len(),
            report: LatencyReport::from_events(events, &self.model),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn filled(n: usize, seed: u64) -> (AmperAccelerator, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut acc = AmperAccelerator::new(n, AccelConfig::default(), 0xBEEF);
        let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        for (i, &p) in pri.iter().enumerate() {
            acc.write_priority(i, p);
        }
        (acc, pri)
    }

    #[test]
    fn write_costs_one_tcam_write() {
        let mut acc = AmperAccelerator::new(64, AccelConfig::default(), 1);
        let r = acc.write_priority(0, 0.5);
        assert_eq!(r.events.tcam_writes, 1);
        assert!((r.total_ns - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sample_returns_batch_and_positive_latency() {
        let (mut acc, _) = filled(1024, 3);
        for variant in [Variant::Knn, Variant::Frnn] {
            let out = acc.sample(64, variant);
            assert_eq!(out.indices.len(), 64);
            assert!(out.indices.iter().all(|&i| i < 1024));
            assert!(out.report.total_ns > 0.0);
            assert!(out.csp_len > 0, "{variant:?} built an empty CSP");
        }
    }

    #[test]
    fn frnn_uses_single_exact_search_per_group() {
        let (mut acc, _) = filled(4096, 5);
        let out = acc.sample(64, Variant::Frnn);
        assert_eq!(out.report.events.exact_searches, 20); // m groups
        assert_eq!(out.report.events.qg_frnn_ops, 20);
        assert_eq!(out.report.events.best_searches, 0);
    }

    #[test]
    fn knn_search_count_equals_csp_size() {
        let (mut acc, _) = filled(4096, 7);
        let out = acc.sample(64, Variant::Knn);
        assert_eq!(out.report.events.qg_knn_ops, 20);
        assert_eq!(out.report.events.exact_searches, 0);
        // one best-match search per selected candidate (when none break early)
        assert!(out.report.events.best_searches >= out.csp_len as u64);
    }

    #[test]
    fn frnn_faster_than_knn_paper_claim() {
        // Fig 9a: AMPER-fr ≈ 2× faster than AMPER-k at matched CSP sizes.
        let (mut acc, _) = filled(8192, 11);
        let k = acc.sample(64, Variant::Knn).report.total_ns;
        let fr = acc.sample(64, Variant::Frnn).report.total_ns;
        assert!(fr < k, "fr {fr} !< k {k}");
    }

    #[test]
    fn frnn_selection_matches_software_bit_for_bit() {
        // The accelerator's CSP for given reps must equal the software
        // frNN selection on the same quantized values (DESIGN.md §7).
        let (mut acc, pri) = filled(2048, 13);
        let n = pri.len();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let mut events = EventCounts::default();
        let reps = acc.draw_representatives(&mut events);
        acc.build_csp(Variant::Frnn, &reps);
        let mut hw: Vec<usize> =
            acc.csb.as_slice().iter().map(|&s| s as usize).collect();
        hw.sort_unstable();

        // software: same reps, same Δ/mask math
        let m = acc.config.m;
        let lpm_q = quant::quantize(acc.config.lambda_prime / m as f32);
        let mut sw = Vec::new();
        for &v_q in &reps {
            let delta_q = query_gen::frnn_delta(lpm_q, v_q);
            let (word, care) = query_gen::frnn_query(v_q, delta_q);
            for i in 0..n {
                if (pri_q[i] ^ word) & care == 0 {
                    sw.push(i);
                }
            }
        }
        sw.sort_unstable();
        sw.dedup();
        hw.dedup();
        assert_eq!(hw, sw);
    }

    #[test]
    fn update_lowering_the_max_rescans() {
        let mut acc = AmperAccelerator::new(64, AccelConfig::default(), 2);
        acc.write_priority(0, 1.0);
        acc.write_priority(1, 0.3);
        acc.write_priority(0, 0.1); // old max overwritten
        acc.refresh_vmax();
        assert_eq!(acc.vmax_q, quant::quantize(0.3));
    }

    #[test]
    fn empty_csp_falls_back_to_uniform() {
        // all priorities zero → vmax 0 → no groups → CSB empty
        for variant in [Variant::Knn, Variant::Frnn] {
            let mut acc = AmperAccelerator::new(64, AccelConfig::default(), 3);
            for i in 0..64 {
                acc.write_priority(i, 0.0);
            }
            let out = acc.sample(16, variant);
            assert_eq!(out.indices.len(), 16);
            assert_eq!(out.csp_len, 0, "{variant:?}");
            assert!(out.indices.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn latency_scales_with_csp_not_memory_size() {
        // Fig 9b/c: latency tracks CSP size; memory size only matters via
        // the CSP. Same config, 4x the slots, similar latency.
        let (mut small, _) = filled(2048, 17);
        let (mut big, _) = filled(8192, 17);
        let ls = small.sample(64, Variant::Frnn).report;
        let lb = big.sample(64, Variant::Frnn).report;
        let per_entry = |r: &LatencyReport, csp: usize| {
            (r.total_ns) / csp.max(1) as f64
        };
        let a = per_entry(&ls, small.csb.len());
        let b = per_entry(&lb, big.csb.len());
        assert!((a - b).abs() / a < 0.5, "per-entry ns diverged: {a} vs {b}");
    }
}
