//! The accelerator's URNG: a 32-bit linear feedback shift register
//! (paper §4.2.1: "The URNG is implemented with the 32-bit linear
//! feedback shift register").
//!
//! Fibonacci LFSR with the maximal-length taps (32, 22, 2, 1): period
//! 2³²−1, never emits 0 from a non-zero seed.

/// 32-bit maximal-length Fibonacci LFSR.
#[derive(Debug, Clone)]
pub struct Lfsr32 {
    state: u32,
}

impl Lfsr32 {
    /// Seed must be non-zero (an all-zero LFSR is stuck); zero is mapped
    /// to a fixed non-zero constant.
    pub fn new(seed: u32) -> Self {
        Lfsr32 { state: if seed == 0 { 0xACE1_u32 } else { seed } }
    }

    /// Advance one bit: feedback = x^32 + x^22 + x^2 + x^1 + 1 (taps at
    /// bit indices 31, 21, 1, 0 of the state register).
    #[inline]
    fn step_bit(&mut self) -> u32 {
        let s = self.state;
        let fb = ((s >> 31) ^ (s >> 21) ^ (s >> 1) ^ s) & 1;
        self.state = (s << 1) | fb;
        fb
    }

    /// Produce the next 32-bit word (32 shifts — one URNG "operation" in
    /// the latency model, which reports the synthesized word latency).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        // shifting 32 times fully refreshes the register
        for _ in 0..31 {
            self.step_bit();
        }
        self.step_bit();
        self.state
    }

    /// Uniform value in `[0, n)` by rejection-free modulo (hardware uses
    /// a simple modulo; the bias at 32 bits is negligible for the CSP/
    /// group ranges involved).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        self.next_u32() % n
    }

    /// Uniform fixed-point value in `[lo, hi)` (Q16.16 group-range draw
    /// for `V(g_i)`).
    #[inline]
    pub fn range_q(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn state(&self) -> u32 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_zero_and_deterministic() {
        let mut a = Lfsr32::new(1);
        let mut b = Lfsr32::new(1);
        for _ in 0..1000 {
            let x = a.next_u32();
            assert_eq!(x, b.next_u32());
            assert_ne!(x, 0);
        }
    }

    #[test]
    fn zero_seed_fixed_up() {
        let mut r = Lfsr32::new(0);
        assert_ne!(r.next_u32(), 0);
    }

    #[test]
    fn long_period_no_short_cycle() {
        let mut r = Lfsr32::new(0xDEADBEEF);
        let start = r.state();
        for _ in 0..10_000 {
            r.next_u32();
            assert_ne!(r.state(), start, "cycled early");
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Lfsr32::new(12345);
        let mut buckets = [0u32; 16];
        for _ in 0..16_000 {
            buckets[(r.next_u32() >> 28) as usize] += 1;
        }
        for &b in &buckets {
            assert!(b > 700 && b < 1300, "{buckets:?}");
        }
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Lfsr32::new(9);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        assert!(r.range_q(100, 200) >= 100);
        assert!(r.range_q(100, 200) < 200);
    }
}
