//! Ternary CAM arrays: the in-memory search fabric (paper §2.3, Fig 3).
//!
//! Each array is 64 rows × 64 columns of ternary cells. A row stores one
//! INT-32 priority (32 value cells; the remaining columns are spare, as
//! in the paper's sizing: "Each TCAM array is 64 rows × 64 columns, where
//! each row stores a priority entry"). Cells hold {0, 1, x}; encoded here
//! as a value word + care mask.
//!
//! Two sensing schemes (Fig 3b/c):
//! * **exact match** — matchline = NOR of cell mismatches (fast, simple
//!   sense amp). Used by AMPER-fr's prefix queries.
//! * **best match** — winner-take-all over mismatch counts (slower,
//!   1.0 ns vs 0.58 ns per Table 2). Used by AMPER-k's repeated
//!   nearest-neighbor searches.

/// Rows per array (the paper's array geometry).
pub const ROWS_PER_ARRAY: usize = 64;
/// Value width in ternary cells.
pub const WORD_BITS: usize = 32;

/// One 64×64 TCAM array storing up to 64 ternary words.
#[derive(Debug, Clone)]
pub struct TcamArray {
    values: [u32; ROWS_PER_ARRAY],
    care: [u32; ROWS_PER_ARRAY],
    valid: u64, // occupancy bitmap
}

impl TcamArray {
    pub fn new() -> Self {
        TcamArray { values: [0; ROWS_PER_ARRAY], care: [0; ROWS_PER_ARRAY], valid: 0 }
    }

    /// Write a fully-specified word into `row` (the priority update path,
    /// §3.4.3 — one TCAM write, no tree traversal).
    pub fn write(&mut self, row: usize, value: u32) {
        self.write_ternary(row, value, u32::MAX);
    }

    /// Write a ternary word (care=0 bits are stored 'x').
    pub fn write_ternary(&mut self, row: usize, value: u32, care: u32) {
        debug_assert!(row < ROWS_PER_ARRAY);
        self.values[row] = value & care;
        self.care[row] = care;
        self.valid |= 1 << row;
    }

    /// Invalidate a row (eviction).
    pub fn clear(&mut self, row: usize) {
        debug_assert!(row < ROWS_PER_ARRAY);
        self.valid &= !(1 << row);
    }

    pub fn is_valid(&self, row: usize) -> bool {
        self.valid >> row & 1 == 1
    }

    pub fn value(&self, row: usize) -> u32 {
        self.values[row]
    }

    /// Exact-match search: bitmap of rows whose every mutually-cared cell
    /// agrees with the query (Fig 3b). One array-parallel operation.
    pub fn search_exact(&self, query: u32, query_care: u32) -> u64 {
        let mut hits = 0u64;
        for row in 0..ROWS_PER_ARRAY {
            if self.valid >> row & 1 == 0 {
                continue;
            }
            let both = self.care[row] & query_care;
            if (self.values[row] ^ query) & both == 0 {
                hits |= 1 << row;
            }
        }
        hits
    }

    /// Best-match search (Fig 3c): the valid row with the fewest
    /// mismatching cells, excluding rows in `disabled`. Returns
    /// `(row, mismatch_count)`; `None` if no candidate row. Ties resolve
    /// to the lowest row index (matchline arbitration).
    pub fn search_best(&self, query: u32, query_care: u32, disabled: u64) -> Option<(usize, u32)> {
        let mut best: Option<(usize, u32)> = None;
        for row in 0..ROWS_PER_ARRAY {
            if self.valid >> row & 1 == 0 || disabled >> row & 1 == 1 {
                continue;
            }
            let both = self.care[row] & query_care;
            let mis = ((self.values[row] ^ query) & both).count_ones();
            match best {
                Some((_, b)) if mis >= b => {}
                _ => best = Some((row, mis)),
            }
        }
        best
    }
}

impl Default for TcamArray {
    fn default() -> Self {
        Self::new()
    }
}

/// A bank of TCAM arrays searched in parallel (Fig 6a: "Multiple TCAM
/// arrays work in parallel"). Row addressing is flat: slot `s` lives in
/// array `s / 64`, row `s % 64`.
#[derive(Debug, Clone)]
pub struct TcamBank {
    arrays: Vec<TcamArray>,
    slots: usize,
}

impl TcamBank {
    /// Bank sized for `slots` priorities (e.g. 128 arrays for 8192, as in
    /// the paper's example).
    pub fn new(slots: usize) -> Self {
        let n_arrays = slots.div_ceil(ROWS_PER_ARRAY);
        TcamBank { arrays: vec![TcamArray::new(); n_arrays], slots }
    }

    pub fn n_arrays(&self) -> usize {
        self.arrays.len()
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn write(&mut self, slot: usize, value: u32) {
        debug_assert!(slot < self.slots);
        self.arrays[slot / ROWS_PER_ARRAY].write(slot % ROWS_PER_ARRAY, value);
    }

    pub fn clear(&mut self, slot: usize) {
        self.arrays[slot / ROWS_PER_ARRAY].clear(slot % ROWS_PER_ARRAY);
    }

    pub fn value(&self, slot: usize) -> u32 {
        self.arrays[slot / ROWS_PER_ARRAY].value(slot % ROWS_PER_ARRAY)
    }

    pub fn is_valid(&self, slot: usize) -> bool {
        self.arrays[slot / ROWS_PER_ARRAY].is_valid(slot % ROWS_PER_ARRAY)
    }

    /// Bank-wide exact-match: appends matching slot ids to `out`, up to
    /// `budget`. All arrays evaluate in one parallel step; collection
    /// order is array-major (priority encoder order).
    pub fn search_exact(&self, query: u32, query_care: u32, budget: usize, out: &mut Vec<usize>) {
        let mut taken = 0usize;
        for (ai, arr) in self.arrays.iter().enumerate() {
            let mut hits = arr.search_exact(query, query_care);
            while hits != 0 && taken < budget {
                let row = hits.trailing_zeros() as usize;
                hits &= hits - 1;
                let slot = ai * ROWS_PER_ARRAY + row;
                if slot < self.slots {
                    out.push(slot);
                    taken += 1;
                }
            }
            if taken >= budget {
                return;
            }
        }
    }

    /// Bank-wide best match with per-slot disable mask. Each array
    /// reports its local winner; a global winner-take-all picks the row
    /// with the fewest mismatches (lowest slot wins ties).
    pub fn search_best(&self, query: u32, query_care: u32, disabled: &[u64]) -> Option<(usize, u32)> {
        debug_assert_eq!(disabled.len(), self.arrays.len());
        let mut best: Option<(usize, u32)> = None;
        for (ai, arr) in self.arrays.iter().enumerate() {
            if let Some((row, mis)) = arr.search_best(query, query_care, disabled[ai]) {
                let slot = ai * ROWS_PER_ARRAY + row;
                if slot >= self.slots {
                    continue;
                }
                match best {
                    Some((_, b)) if mis >= b => {}
                    _ => best = Some((slot, mis)),
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_finds_equal_rows() {
        let mut arr = TcamArray::new();
        arr.write(3, 0xABCD);
        arr.write(7, 0xABCD);
        arr.write(9, 0x1234);
        let hits = arr.search_exact(0xABCD, u32::MAX);
        assert_eq!(hits, (1 << 3) | (1 << 7));
    }

    #[test]
    fn invalid_rows_never_match() {
        let mut arr = TcamArray::new();
        arr.write(0, 0);
        arr.clear(0);
        assert_eq!(arr.search_exact(0, u32::MAX), 0);
        assert_eq!(arr.search_best(0, u32::MAX, 0), None);
    }

    #[test]
    fn query_dont_care_widens_match() {
        let mut arr = TcamArray::new();
        arr.write(0, 0b1000);
        arr.write(1, 0b1011);
        arr.write(2, 0b0111);
        // low 2 bits don't-care: matches 0b10xx
        let hits = arr.search_exact(0b1000, !0b0011);
        assert_eq!(hits, 0b011);
    }

    #[test]
    fn stored_dont_care_matches_any_query_bit() {
        let mut arr = TcamArray::new();
        arr.write_ternary(5, 0b1010, !0b0001); // lsb is 'x'
        assert_ne!(arr.search_exact(0b1011, u32::MAX), 0);
        assert_ne!(arr.search_exact(0b1010, u32::MAX), 0);
        assert_eq!(arr.search_exact(0b1000, u32::MAX), 0);
    }

    #[test]
    fn best_match_returns_min_hamming() {
        let mut arr = TcamArray::new();
        arr.write(0, 0b0000);
        arr.write(1, 0b0110);
        arr.write(2, 0b0111);
        let (row, mis) = arr.search_best(0b0111, u32::MAX, 0).unwrap();
        assert_eq!((row, mis), (2, 0));
        // disable the exact hit: next best is row 1 (1 mismatch)
        let (row, mis) = arr.search_best(0b0111, u32::MAX, 1 << 2).unwrap();
        assert_eq!((row, mis), (1, 1));
    }

    #[test]
    fn bank_addressing_flat() {
        let mut bank = TcamBank::new(8192);
        assert_eq!(bank.n_arrays(), 128); // the paper's 8192-entry example
        bank.write(8191, 42);
        assert!(bank.is_valid(8191));
        assert_eq!(bank.value(8191), 42);
        let mut out = Vec::new();
        bank.search_exact(42, u32::MAX, usize::MAX, &mut out);
        assert_eq!(out, vec![8191]);
    }

    #[test]
    fn bank_best_match_global_winner() {
        let mut bank = TcamBank::new(256);
        bank.write(10, 0b1111_0000);
        bank.write(100, 0b1111_0001);
        bank.write(200, 0b1111_0011);
        let disabled = vec![0u64; bank.n_arrays()];
        let (slot, mis) = bank.search_best(0b1111_0001, u32::MAX, &disabled).unwrap();
        assert_eq!((slot, mis), (100, 0));
        let mut dis = disabled.clone();
        dis[100 / 64] |= 1 << (100 % 64);
        let (slot, mis) = bank.search_best(0b1111_0001, u32::MAX, &dis).unwrap();
        assert_eq!(mis, 1);
        assert_eq!(slot, 10); // tie with 200 broken toward lower slot
    }

    #[test]
    fn bank_budget_truncates() {
        let mut bank = TcamBank::new(512);
        for i in 0..512 {
            bank.write(i, 7);
        }
        let mut out = Vec::new();
        bank.search_exact(7, u32::MAX, 100, &mut out);
        assert_eq!(out.len(), 100);
    }
}
