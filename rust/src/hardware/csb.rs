//! Candidate-set buffer (CSB): the SRAM staging buffer between the TCAM
//! searches and the batch draw (paper Fig 6a; latency from CACTI,
//! Table 2: 0.78 ns read / 0.78 ns write, 8000-entry capacity).
//!
//! Functionally a bounded append buffer of slot ids; the latency model
//! charges one write per appended candidate (the Fig 9c "latency grows
//! linearly with CSP size — dominated by candidate set buffer
//! throughput" effect) and one read per drawn batch element.

/// Bounded candidate-set buffer.
#[derive(Debug, Clone)]
pub struct CandidateSetBuffer {
    entries: Vec<u32>,
    capacity: usize,
    /// Lifetime write counter (latency accounting).
    writes: u64,
    /// Lifetime read counter.
    reads: u64,
}

impl CandidateSetBuffer {
    /// The paper's CSB holds 8000 entries.
    pub const PAPER_CAPACITY: usize = 8000;

    pub fn new(capacity: usize) -> Self {
        CandidateSetBuffer {
            // pre-size up to a sane bound; "unbounded" study configs pass
            // usize::MAX as the logical capacity
            entries: Vec::with_capacity(capacity.min(1 << 16)),
            capacity,
            writes: 0,
            reads: 0,
        }
    }

    /// Clear for a new sampling operation (pointer reset; free).
    pub fn reset(&mut self) {
        self.entries.clear();
    }

    /// Append a candidate; returns false (dropped) when full.
    #[inline]
    pub fn push(&mut self, slot: u32) -> bool {
        if self.entries.len() >= self.capacity {
            return false;
        }
        self.entries.push(slot);
        self.writes += 1;
        true
    }

    /// Read entry `i` (the batch-draw path).
    #[inline]
    pub fn read(&mut self, i: usize) -> u32 {
        self.reads += 1;
        self.entries[i]
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    pub fn reads(&self) -> u64 {
        self.reads
    }

    pub fn as_slice(&self) -> &[u32] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_read_counts() {
        let mut b = CandidateSetBuffer::new(4);
        assert!(b.push(10));
        assert!(b.push(20));
        assert_eq!(b.read(1), 20);
        assert_eq!(b.writes(), 2);
        assert_eq!(b.reads(), 1);
    }

    #[test]
    fn drops_when_full() {
        let mut b = CandidateSetBuffer::new(2);
        assert!(b.push(1));
        assert!(b.push(2));
        assert!(!b.push(3));
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn reset_keeps_counters() {
        let mut b = CandidateSetBuffer::new(2);
        b.push(1);
        b.reset();
        assert!(b.is_empty());
        assert_eq!(b.writes(), 1, "lifetime counters survive reset");
    }
}
