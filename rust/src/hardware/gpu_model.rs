//! The Fig 9a comparison baseline: PER per-batch sampling+update latency
//! on the paper's GPU testbed (Intel i5-8600K + GTX 1080, batch 64).
//!
//! The paper reports speedup *ranges* rather than raw GPU numbers
//! (AMPER-k 55×–170×, AMPER-fr 118×–270× across ER sizes 5000–20000).
//! This module reconstructs the implied GPU latency series from those
//! bands and the accelerator's modeled latencies (DESIGN.md §4
//! substitution), and is reported side-by-side with *measured* latencies
//! of this crate's own sum-tree PER on the host CPU so the comparison
//! always includes a live software baseline.

/// ER memory sizes of Fig 9a.
pub const FIG9A_SIZES: [usize; 3] = [5_000, 10_000, 20_000];

/// Reconstructed GPU PER per-batch latency (ns) for the Fig 9a sizes.
/// Chosen so the modeled accelerator latencies at the paper's operating
/// point (m=20, CSP ratio 0.15, batch 64) land inside the published
/// speedup bands.
pub fn gpu_per_latency_ns(er_size: usize) -> f64 {
    // piecewise-linear in log(size) through the reconstructed anchors
    let anchors: [(f64, f64); 3] = [
        (5_000.0, 95_000.0),   // 95 µs
        (10_000.0, 290_000.0), // 290 µs
        (20_000.0, 820_000.0), // 820 µs
    ];
    let x = er_size as f64;
    if x <= anchors[0].0 {
        return anchors[0].1 * x / anchors[0].0;
    }
    for w in anchors.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        if x <= x1 {
            let t = (x.ln() - x0.ln()) / (x1.ln() - x0.ln());
            return y0 * (y1 / y0).powf(t);
        }
    }
    // extrapolate on the last segment's log-log slope
    let (x0, y0) = anchors[1];
    let (x1, y1) = anchors[2];
    let slope = (y1 / y0).ln() / (x1 / x0).ln();
    y1 * (x / x1).powf(slope)
}

/// The paper's published speedup bands (for EXPERIMENTS.md comparison).
pub const PAPER_SPEEDUP_K: (f64, f64) = (55.0, 170.0);
pub const PAPER_SPEEDUP_FR: (f64, f64) = (118.0, 270.0);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_size() {
        let mut prev = 0.0;
        for s in [1000, 5000, 10_000, 20_000, 40_000] {
            let l = gpu_per_latency_ns(s);
            assert!(l > prev, "size {s}");
            prev = l;
        }
    }

    #[test]
    fn anchors_exact() {
        assert!((gpu_per_latency_ns(5000) - 95_000.0).abs() < 1.0);
        assert!((gpu_per_latency_ns(10_000) - 290_000.0).abs() < 1.0);
        assert!((gpu_per_latency_ns(20_000) - 820_000.0).abs() < 1.0);
    }

    #[test]
    fn speedups_land_in_paper_bands() {
        use super::super::accelerator::{AccelConfig, AmperAccelerator};
        use crate::replay::amper::Variant;
        use crate::util::Rng;

        for &size in &FIG9A_SIZES {
            let mut rng = Rng::new(size as u64);
            // λ' tuned per size is not needed: CSP ratio is set by config
            let mut acc = AmperAccelerator::new(size, AccelConfig::default(), 7);
            for i in 0..size {
                acc.write_priority(i, rng.f32());
            }
            let gpu = gpu_per_latency_ns(size);
            let k = acc.sample(64, Variant::Knn).report.total_ns;
            let fr = acc.sample(64, Variant::Frnn).report.total_ns;
            let sk = gpu / k;
            let sfr = gpu / fr;
            assert!(
                sk > 30.0 && sk < 400.0,
                "size {size}: k speedup {sk:.0} wildly out of band"
            );
            assert!(sfr > sk, "fr must beat k (size {size})");
        }
    }
}
