//! A small, dependency-free worker pool for the engine's hot kernels
//! (std-only, like everything else in the crate — the build is offline).
//!
//! [`ThreadPool::run`] is a blocking parallel-for: the caller hands over
//! `tasks` independent chunk indices and a `Fn(usize)` that executes one
//! of them; workers and the caller race through the index space via one
//! atomic counter, and `run` returns only after every chunk finished.
//! Kernels built on it (`dense`, the backward passes, Adam, the CSP key
//! sort) give each chunk a **disjoint output range** and keep the
//! per-element accumulation order identical to the scalar loop, so the
//! results are bit-identical at any worker count — determinism comes
//! from the work decomposition, not from scheduling.
//!
//! A pool with 1 thread spawns no workers and `run` degenerates to the
//! plain sequential loop — `engine_threads = 1` is *literally* the
//! single-threaded code path, not an emulation of it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Resolve a thread-count knob: 0 = one thread per available core
/// (`std::thread::available_parallelism`), n = exactly n.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Thread count for engines built without explicit config: the
/// `AMPER_ENGINE_THREADS` env override (`0` = all cores), default 1 —
/// the exact pre-pool code path. `tier1.sh` runs the test suite under
/// `AMPER_ENGINE_THREADS=0` as a second pass so the deterministic
/// parallel kernels are exercised on every push.
pub fn threads_from_env() -> usize {
    match std::env::var("AMPER_ENGINE_THREADS") {
        Ok(s) => resolve_threads(s.trim().parse().unwrap_or(1)),
        Err(_) => 1,
    }
}

/// The type-erased job: a borrowed `Fn(usize)` promoted to a raw pointer
/// for the duration of one `run` call. Workers only dereference it for
/// chunk indices they won the claim on, and `run` does not return until
/// every claimed chunk completed — the pointee therefore outlives every
/// dereference.
#[derive(Clone, Copy)]
struct RawJob(*const (dyn Fn(usize) + Sync));
unsafe impl Send for RawJob {}

struct JobState {
    /// Bumped per dispatch so a worker never re-enters a job it has
    /// already seen (condvar wakeups can be spurious or late).
    epoch: u64,
    job: Option<RawJob>,
    tasks: usize,
    /// Workers currently inside the claim loop. `run` waits for this to
    /// reach 0 before returning: a worker's final (empty) claim attempt
    /// must not race the *next* dispatch's counter reset.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers wait here for the next job (or shutdown).
    work: Condvar,
    /// The caller waits here for `finished == tasks`.
    done: Condvar,
    /// Next unclaimed chunk index.
    next: AtomicUsize,
    /// Completed chunk count for the current job.
    finished: AtomicUsize,
}

/// Persistent worker pool. `new(threads)` is the total parallelism of a
/// `run` call — the caller participates, so `threads - 1` OS threads are
/// spawned and `threads <= 1` spawns none.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes concurrent `run` calls (the learner and shard workers
    /// may share one pool); plain Mutex — dispatches are short.
    dispatch: Mutex<()>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads).finish()
    }
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                tasks: 0,
                active: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
            finished: AtomicUsize::new(0),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("amper-engine-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn engine worker")
            })
            .collect();
        ThreadPool { shared, workers, threads, dispatch: Mutex::new(()) }
    }

    /// A process-wide single-threaded pool: `run` on it is the plain
    /// sequential loop. Engine-free callers (the actor-side policy
    /// snapshot) use it instead of carrying a pool of their own.
    pub fn inline() -> &'static ThreadPool {
        static INLINE: OnceLock<ThreadPool> = OnceLock::new();
        INLINE.get_or_init(|| ThreadPool::new(1))
    }

    /// Total parallelism of a `run` call (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Blocking parallel-for: execute `f(0..tasks)` across the pool and
    /// the calling thread; returns once all `tasks` chunks completed.
    /// `f` must not panic (a panicking chunk would strand the caller)
    /// and must not call back into the same pool (the dispatch lock is
    /// held for the whole call).
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if self.workers.is_empty() || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let _serial = self.dispatch.lock().unwrap();
        // Erase the borrow's lifetime for the hand-off to the workers;
        // see `RawJob` for why no dereference can outlive this frame.
        let job = RawJob(unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(f)
        });
        self.shared.next.store(0, Ordering::Relaxed);
        self.shared.finished.store(0, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.tasks = tasks;
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        // the caller is a full participant — a 1-chunk-per-worker
        // dispatch never leaves it idle-waiting
        loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            f(i);
            self.shared.finished.fetch_add(1, Ordering::AcqRel);
        }
        // wait for all chunks AND for every participating worker to have
        // left the claim loop — only then is resetting `next`/`finished`
        // for the next dispatch safe
        let mut st = self.shared.state.lock().unwrap();
        while self.shared.finished.load(Ordering::Acquire) < tasks || st.active > 0
        {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen_epoch = 0u64;
    loop {
        let (job, tasks) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.job.is_some() && st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            st.active += 1;
            (st.job.unwrap(), st.tasks)
        };
        loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            // safe to dereference: having claimed chunk i < tasks, the
            // caller cannot observe finished == tasks (and return) until
            // this worker bumps `finished` below
            let f = unsafe { &*job.0 };
            f(i);
            shared.finished.fetch_add(1, Ordering::AcqRel);
        }
        // deregister; the last worker out wakes the caller (which also
        // rechecks the predicate itself before ever sleeping, so a job
        // finished entirely by the caller needs no notification)
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Send+Sync wrapper for a raw pointer into a buffer the pool's chunks
/// write through **provably disjoint** ranges (tile rows of `dense`
/// outputs, k-blocks of dW, per-tensor Adam updates, sort chunks).
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn resolve_threads_zero_is_machine_default() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(17, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn every_chunk_runs_exactly_once() {
        let pool = ThreadPool::new(4);
        for tasks in [1usize, 2, 3, 7, 64, 257] {
            let counts: Vec<AtomicUsize> =
                (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "chunk {i} of {tasks}");
            }
        }
    }

    #[test]
    fn disjoint_writes_land_for_every_chunk() {
        // the SendPtr pattern every kernel uses: chunk i owns slot i
        let pool = ThreadPool::new(3);
        let mut out = vec![0u64; 100];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.run(out.len(), &|i| unsafe {
            *ptr.0.add(i) = (i as u64) * 3 + 1;
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * 3 + 1);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_dispatches() {
        // a train step dispatches ~15 jobs; make sure the epoch/condvar
        // protocol survives thousands of back-to-back runs
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for round in 0..2000u64 {
            pool.run(8, &|i| {
                total.fetch_add(round + i as u64, Ordering::Relaxed);
            });
        }
        let expect: u64 = (0..2000u64).map(|r| 8 * r + 28).sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn concurrent_callers_serialize_safely() {
        // learner + shard workers may share one pool: concurrent run()
        // calls must not corrupt each other's chunk spaces
        let pool = Arc::new(ThreadPool::new(4));
        let sum = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let sum = Arc::clone(&sum);
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        pool.run(16, &|i| {
                            sum.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 200 * 120);
    }

    #[test]
    fn env_default_is_single_threaded() {
        // without AMPER_ENGINE_THREADS the default engine pool must be
        // the exact scalar path (tests rely on it for bit-identity)
        if std::env::var("AMPER_ENGINE_THREADS").is_err() {
            assert_eq!(threads_from_env(), 1);
        } else {
            assert!(threads_from_env() >= 1);
        }
    }
}
