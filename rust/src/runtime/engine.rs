//! The execution engine: one compiled PJRT executable pair (train + act)
//! per environment, plus the host-side training state (parameters, Adam
//! moments, step counter) kept as literals between calls.
//!
//! Flat I/O layout (must mirror `python/compile/model.py`):
//! ```text
//! train in : w0 b0 w1 b1 w2 b2 | tw0..tb2 | m0..m5 | v0..v5 | t
//!            | obs actions rewards next_obs dones is_weights
//! train out: w0'..b2' | m0'..m5' | v0'..v5' | t' | td | loss
//! act   in : w0 b0 w1 b1 w2 b2 | obs
//! act   out: actions(int32) | qvals
//! ```

use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::{EnvArtifacts, Manifest};
use crate::util::Rng;

/// Host-side training state: the 19 state literals round-tripped through
/// every train step.
pub struct TrainState {
    /// Online parameters w0,b0,w1,b1,w2,b2.
    pub params: Vec<xla::Literal>,
    /// Target-network parameters (same layout).
    pub target: Vec<xla::Literal>,
    /// Adam first moments.
    pub m: Vec<xla::Literal>,
    /// Adam second moments.
    pub v: Vec<xla::Literal>,
    /// Step counter (f32 scalar).
    pub t: xla::Literal,
}

impl TrainState {
    /// He-initialized parameters, zero moments (mirrors
    /// `model.init_params`).
    pub fn init(spec: &EnvArtifacts, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut params = Vec::with_capacity(6);
        for shape in spec.param_shapes() {
            let n: usize = shape.iter().product();
            let lit = if shape.len() == 2 {
                let scale = (2.0 / shape[0] as f64).sqrt() as f32;
                let data: Vec<f32> =
                    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect();
                xla::Literal::vec1(&data)
                    .reshape(&[shape[0] as i64, shape[1] as i64])?
            } else {
                xla::Literal::vec1(&vec![0f32; n])
            };
            params.push(lit);
        }
        let clone_zeros = |shapes: &[Vec<usize>]| -> Result<Vec<xla::Literal>> {
            shapes
                .iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    let lit = xla::Literal::vec1(&vec![0f32; n]);
                    Ok(if s.len() == 2 {
                        lit.reshape(&[s[0] as i64, s[1] as i64])?
                    } else {
                        lit
                    })
                })
                .collect()
        };
        let shapes = spec.param_shapes();
        let target = clone_literals(&params)?;
        Ok(TrainState {
            params,
            target,
            m: clone_zeros(&shapes)?,
            v: clone_zeros(&shapes)?,
            t: xla::Literal::scalar(0f32),
        })
    }

    /// Copy online params into the target network (the periodic sync).
    pub fn sync_target(&mut self) -> Result<()> {
        self.target = clone_literals(&self.params)?;
        Ok(())
    }
}

fn clone_literals(xs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    // Literal has no Clone; round-trip through raw f32 data.
    xs.iter()
        .map(|l| {
            let shape = l.array_shape()?;
            let data = l.to_vec::<f32>()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            Ok(xla::Literal::vec1(&data).reshape(&dims)?)
        })
        .collect()
}

/// One training batch in host memory (flat, row-major).
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub dones: Vec<f32>,
    pub is_weights: Vec<f32>,
}

impl TrainBatch {
    pub fn zeros(batch: usize, obs_dim: usize) -> TrainBatch {
        TrainBatch {
            obs: vec![0.0; batch * obs_dim],
            actions: vec![0; batch],
            rewards: vec![0.0; batch],
            next_obs: vec![0.0; batch * obs_dim],
            dones: vec![0.0; batch],
            is_weights: vec![1.0; batch],
        }
    }
}

/// Result of one train step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// TD errors per batch element (the new priorities' inputs).
    pub td: Vec<f32>,
    /// Scalar loss.
    pub loss: f32,
}

/// Compiled executables + spec for one environment.
pub struct Engine {
    spec: EnvArtifacts,
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    act_exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Load and compile the artifacts for `env` from `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, env: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(anyhow::Error::msg)
            .context("loading manifest")?;
        let spec = manifest.env(env).map_err(anyhow::Error::msg)?.clone();
        let client = xla::PjRtClient::cpu()?;
        let train_exe = compile(&client, &spec.train_artifact)?;
        let act_exe = compile(&client, &spec.act_artifact)?;
        Ok(Engine { spec, client, train_exe, act_exe })
    }

    pub fn spec(&self) -> &EnvArtifacts {
        &self.spec
    }

    /// Host→device upload.
    ///
    /// NOTE: all execution goes through `execute_b` (device buffers the
    /// Rust side owns and drops). The crate's literal-accepting `execute`
    /// leaks its internally created input buffers (`buffer.release()`
    /// with no matching delete in xla_rs.cc) — ~300 KB per train step,
    /// which OOM-killed long suites before this was switched
    /// (EXPERIMENTS.md §Perf).
    fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Upload a flat f32 slice directly (skips the Literal staging copy).
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute one fused train step (fwd + bwd + Adam). Updates `state`
    /// in place; returns TD errors and loss.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        batch: &TrainBatch,
    ) -> Result<StepOutput> {
        let b = self.spec.batch;
        let d = self.spec.obs_dim;
        anyhow::ensure!(batch.obs.len() == b * d, "batch obs size");

        // assemble the 31 flat inputs as device buffers (see `upload`)
        let mut inputs: Vec<xla::PjRtBuffer> = Vec::with_capacity(31);
        for lit in state
            .params
            .iter()
            .chain(state.target.iter())
            .chain(state.m.iter())
            .chain(state.v.iter())
        {
            inputs.push(self.upload(lit)?);
        }
        inputs.push(self.upload(&state.t)?);
        inputs.push(self.upload_f32(&batch.obs, &[b, d])?);
        inputs.push(self.upload_i32(&batch.actions, &[b])?);
        inputs.push(self.upload_f32(&batch.rewards, &[b])?);
        inputs.push(self.upload_f32(&batch.next_obs, &[b, d])?);
        inputs.push(self.upload_f32(&batch.dones, &[b])?);
        inputs.push(self.upload_f32(&batch.is_weights, &[b])?);

        let result = self.train_exe.execute_b::<xla::PjRtBuffer>(&inputs)?;
        let out = result[0][0].to_literal_sync()?;
        let mut parts = out.to_tuple()?;
        anyhow::ensure!(parts.len() == 21, "expected 21 outputs, got {}", parts.len());

        // unpack in reverse to pop cheaply
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0];
        let td = parts.pop().unwrap().to_vec::<f32>()?;
        let t = parts.pop().unwrap();
        let v: Vec<xla::Literal> = parts.drain(12..18).collect();
        let m: Vec<xla::Literal> = parts.drain(6..12).collect();
        let params: Vec<xla::Literal> = parts.drain(0..6).collect();
        state.params = params;
        state.m = m;
        state.v = v;
        state.t = t;
        Ok(StepOutput { td, loss })
    }

    /// Greedy action for a single observation. Returns (action, q-values).
    pub fn act(&self, state: &TrainState, obs: &[f32]) -> Result<(usize, Vec<f32>)> {
        let d = self.spec.obs_dim;
        anyhow::ensure!(obs.len() == d, "obs dim");
        let mut inputs: Vec<xla::PjRtBuffer> = Vec::with_capacity(7);
        for lit in state.params.iter() {
            inputs.push(self.upload(lit)?);
        }
        inputs.push(self.upload_f32(obs, &[1, d])?);
        let result = self.act_exe.execute_b::<xla::PjRtBuffer>(&inputs)?;
        let out = result[0][0].to_literal_sync()?;
        let (a, q) = out.to_tuple2()?;
        let action = a.to_vec::<i32>()?[0] as usize;
        let qvals = q.to_vec::<f32>()?;
        Ok((action, qvals))
    }
}

fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .with_context(|| format!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client
        .compile(&comp)
        .with_context(|| format!("compiling {path:?}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_loads_and_steps_cartpole() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir, "cartpole").unwrap();
        let spec = engine.spec().clone();
        let mut state = TrainState::init(&spec, 0).unwrap();
        let mut batch = TrainBatch::zeros(spec.batch, spec.obs_dim);
        let mut rng = Rng::new(1);
        for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
            *x = rng.normal_f32(0.0, 1.0);
        }
        for a in batch.actions.iter_mut() {
            *a = rng.below(spec.n_actions) as i32;
        }
        for r in batch.rewards.iter_mut() {
            *r = rng.f32();
        }
        let out = engine.train_step(&mut state, &batch).unwrap();
        assert_eq!(out.td.len(), spec.batch);
        assert!(out.loss.is_finite());
        assert!(out.td.iter().all(|x| x.is_finite()));
        // t advanced
        assert_eq!(state.t.to_vec::<f32>().unwrap()[0], 1.0);

        // act path
        let obs = vec![0.1f32; spec.obs_dim];
        let (action, q) = engine.act(&state, &obs).unwrap();
        assert!(action < spec.n_actions);
        assert_eq!(q.len(), spec.n_actions);
    }

    #[test]
    fn repeated_steps_reduce_loss_on_fixed_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir, "cartpole").unwrap();
        let spec = engine.spec().clone();
        let mut state = TrainState::init(&spec, 7).unwrap();
        let mut batch = TrainBatch::zeros(spec.batch, spec.obs_dim);
        let mut rng = Rng::new(3);
        for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
            *x = rng.normal_f32(0.0, 0.5);
        }
        for (i, a) in batch.actions.iter_mut().enumerate() {
            *a = (i % spec.n_actions) as i32;
        }
        for r in batch.rewards.iter_mut() {
            *r = rng.f32();
        }
        for dn in batch.dones.iter_mut() {
            *dn = 1.0; // pure regression to rewards
        }
        let first = engine.train_step(&mut state, &batch).unwrap().loss;
        let mut last = first;
        for _ in 0..30 {
            last = engine.train_step(&mut state, &batch).unwrap().loss;
        }
        assert!(
            last < first * 0.5,
            "loss did not descend: {first} -> {last}"
        );
    }

    #[test]
    fn target_sync_copies_params() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir, "cartpole").unwrap();
        let spec = engine.spec().clone();
        let mut state = TrainState::init(&spec, 2).unwrap();
        let batch = {
            let mut b = TrainBatch::zeros(spec.batch, spec.obs_dim);
            let mut rng = Rng::new(5);
            // non-zero observations so the weight gradients are non-zero
            b.obs.iter_mut().for_each(|x| *x = rng.normal_f32(0.0, 1.0));
            b.rewards.iter_mut().for_each(|r| *r = 1.0);
            b.dones.iter_mut().for_each(|d| *d = 1.0);
            b
        };
        engine.train_step(&mut state, &batch).unwrap();
        // params changed; target still initial
        let p0 = state.params[0].to_vec::<f32>().unwrap();
        let t0 = state.target[0].to_vec::<f32>().unwrap();
        assert_ne!(p0, t0);
        state.sync_target().unwrap();
        let t1 = state.target[0].to_vec::<f32>().unwrap();
        assert_eq!(p0, t1);
    }
}
