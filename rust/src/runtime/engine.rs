//! The execution engine: one native train/act implementation per
//! environment, plus the host-side training state (parameters, Adam
//! moments, step counter) kept between calls.
//!
//! The math mirrors `python/compile/model.py` operation-for-operation —
//! 3-layer ReLU MLP, (double-)DQN TD target, importance-weighted Huber
//! loss (δ = 1), bias-corrected Adam — so learning curves are comparable
//! with the JAX/Pallas L2/L1 stack. The PJRT/xla execution path was
//! removed from the default build (the crate registry is offline;
//! DESIGN.md §4): `artifacts/manifest.json` still drives the network
//! spec when present, and the lowered HLO artifacts remain the contract
//! for a vendored PJRT backend.
//!
//! Flat parameter layout (must mirror `python/compile/model.py`):
//! ```text
//! params: w0 b0 w1 b1 w2 b2   (w row-major [in, out])
//! ```

use std::path::Path;
use std::sync::Arc;

use super::manifest::{EnvArtifacts, Manifest};
use super::threadpool::{threads_from_env, SendPtr, ThreadPool};
use crate::ensure;
use crate::replay::GatheredBatch;
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Adam hyper-parameters (model.py: ADAM_B1, ADAM_B2, ADAM_EPS).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
/// Huber loss transition point (model.py passes delta=1.0).
const HUBER_DELTA: f32 = 1.0;

/// Host-side training state: 6 online params, 6 target params, Adam
/// moments and the step counter (the 19 state "literals" of the PJRT
/// layout, held as flat f32 buffers).
pub struct TrainState {
    /// Online parameters w0,b0,w1,b1,w2,b2 (w row-major [in, out]).
    pub params: Vec<Vec<f32>>,
    /// Target-network parameters (same layout).
    pub target: Vec<Vec<f32>>,
    /// Adam first moments.
    pub m: Vec<Vec<f32>>,
    /// Adam second moments.
    pub v: Vec<Vec<f32>>,
    /// Step counter.
    pub t: f32,
}

impl TrainState {
    /// He-initialized parameters, zero moments (mirrors
    /// `model.init_params`).
    pub fn init(spec: &EnvArtifacts, seed: u64) -> Result<TrainState> {
        let mut rng = Rng::new(seed);
        let mut params = Vec::with_capacity(6);
        for shape in spec.param_shapes() {
            let n: usize = shape.iter().product();
            let data = if shape.len() == 2 {
                let scale = (2.0 / shape[0] as f64).sqrt() as f32;
                (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
            } else {
                vec![0f32; n]
            };
            params.push(data);
        }
        let zeros: Vec<Vec<f32>> = spec
            .param_shapes()
            .iter()
            .map(|s| vec![0f32; s.iter().product()])
            .collect();
        Ok(TrainState {
            target: params.clone(),
            params,
            m: zeros.clone(),
            v: zeros,
            t: 0.0,
        })
    }

    /// Copy online params into the target network (the periodic sync).
    pub fn sync_target(&mut self) -> Result<()> {
        self.target = self.params.clone();
        Ok(())
    }

    /// Export a frozen copy of the online parameters — the payload of an
    /// actor-facing policy snapshot
    /// ([`crate::coordinator::PolicySnapshot`]). One flat memcpy per
    /// tensor, no graph state, no Adam moments.
    pub fn snapshot_params(&self) -> Vec<Vec<f32>> {
        self.params.clone()
    }
}

/// One training batch in host memory (flat, row-major).
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub dones: Vec<f32>,
    pub is_weights: Vec<f32>,
}

impl TrainBatch {
    pub fn zeros(batch: usize, obs_dim: usize) -> TrainBatch {
        TrainBatch {
            obs: vec![0.0; batch * obs_dim],
            actions: vec![0; batch],
            rewards: vec![0.0; batch],
            next_obs: vec![0.0; batch * obs_dim],
            dones: vec![0.0; batch],
            is_weights: vec![1.0; batch],
        }
    }

    /// Borrowed view over the columns (zero-copy engine input).
    pub fn view(&self) -> TrainBatchRef<'_> {
        TrainBatchRef {
            obs: &self.obs,
            actions: &self.actions,
            rewards: &self.rewards,
            next_obs: &self.next_obs,
            dones: &self.dones,
            is_weights: &self.is_weights,
        }
    }
}

/// A borrowed training batch (flat, row-major): the view the engine
/// actually consumes, so any flat columnar source — an owned
/// [`TrainBatch`], a replay-service `GatheredBatch`, a slice of a larger
/// staging buffer — trains **without an intermediate per-row repack**.
#[derive(Debug, Clone, Copy)]
pub struct TrainBatchRef<'a> {
    pub obs: &'a [f32],
    pub actions: &'a [i32],
    pub rewards: &'a [f32],
    pub next_obs: &'a [f32],
    pub dones: &'a [f32],
    pub is_weights: &'a [f32],
}

/// A gathered replay-service reply trains directly: the reply buffer's
/// columns *are* the engine input (the zero-copy contract of the reply
/// pool — lend, fill, view, recycle).
impl<'a> From<&'a GatheredBatch> for TrainBatchRef<'a> {
    fn from(g: &'a GatheredBatch) -> TrainBatchRef<'a> {
        TrainBatchRef {
            obs: &g.obs,
            actions: &g.actions,
            rewards: &g.rewards,
            next_obs: &g.next_obs,
            dones: &g.dones,
            is_weights: &g.is_weights,
        }
    }
}

/// Reusable forward/backward scratch for [`Engine::train_step_scratch`]:
/// activation buffers, the output-gradient buffer, the six gradient
/// tensors, the backprop hidden-gradient buffers and the TD-error buffer
/// all survive across steps, so a hot training loop allocates **nothing**
/// per step once warm (pair [`Self::recycle`] with the returned
/// [`StepOutput`] to hand the TD buffer back).
#[derive(Default)]
pub struct TrainScratch {
    on: Activations,
    next: Activations,
    tgt: Activations,
    dq: Vec<f32>,
    /// TD-error buffer, moved into [`StepOutput::td`] each step and
    /// returned via [`Self::recycle`].
    td: Vec<f32>,
    /// Gradient tensors in param order (w0,b0,w1,b1,w2,b2).
    grads: Vec<Vec<f32>>,
    dh1: Vec<f32>,
    dh2: Vec<f32>,
}

impl TrainScratch {
    /// Return a consumed [`StepOutput`]'s TD buffer to the scratch: the
    /// next `train_step_scratch` call refills it in place instead of
    /// allocating. Hot loops call this once the TD errors have been fed
    /// back to the replay memory; one-shot callers just drop the output.
    pub fn recycle(&mut self, out: StepOutput) {
        self.td = out.td;
    }
}

/// Result of one train step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// TD errors per batch element (the new priorities' inputs).
    pub td: Vec<f32>,
    /// Scalar loss.
    pub loss: f32,
}

/// Rows processed together per weight pass in [`dense`]: a tile's output
/// block (`ROW_TILE x dout`) stays hot while each weight row is read once
/// per tile instead of once per input row.
const ROW_TILE: usize = 8;

/// `y = x @ w (+ bias) (then ReLU)` — x is (rows, din) row-major, w is
/// (din, dout) row-major. Rows are processed in tiles of [`ROW_TILE`]
/// with the k-loop outside the tile, so a batched call streams each
/// weight row once per tile instead of once per row (the batched-act /
/// train-step bandwidth win). Tiles write **disjoint output rows**, so
/// they dispatch across the worker pool with no store-side
/// synchronization; per output element the accumulation order over k is
/// unchanged — a tiled call is bit-identical to row-at-a-time at any
/// worker count (pinned by `batch_equivalence`).
#[allow(clippy::too_many_arguments)]
fn dense(
    x: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    bias: &[f32],
    relu: bool,
    out: &mut Vec<f32>,
    pool: &ThreadPool,
) {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(bias.len(), dout);
    out.clear();
    out.resize(rows * dout, 0.0);
    let tiles = rows.div_ceil(ROW_TILE);
    if pool.threads() <= 1 || tiles <= 1 {
        for t in 0..tiles {
            let r0 = t * ROW_TILE;
            let rt = (rows - r0).min(ROW_TILE);
            let tile = &mut out[r0 * dout..(r0 + rt) * dout];
            dense_tile(x, r0, rt, din, dout, w, bias, relu, tile);
        }
        return;
    }
    let out_ptr = SendPtr(out.as_mut_ptr());
    pool.run(tiles, &|t| {
        let r0 = t * ROW_TILE;
        let rt = (rows - r0).min(ROW_TILE);
        // tile t exclusively owns output rows r0..r0+rt
        let tile = unsafe {
            std::slice::from_raw_parts_mut(out_ptr.0.add(r0 * dout), rt * dout)
        };
        dense_tile(x, r0, rt, din, dout, w, bias, relu, tile);
    });
}

/// One [`ROW_TILE`] block of [`dense`]: `tile` is the output rows
/// `r0..r0+rt`. Identical arithmetic whether tiles run sequentially or
/// across the pool.
#[allow(clippy::too_many_arguments)]
fn dense_tile(
    x: &[f32],
    r0: usize,
    rt: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    bias: &[f32],
    relu: bool,
    tile: &mut [f32],
) {
    debug_assert_eq!(tile.len(), rt * dout);
    for orow in tile.chunks_exact_mut(dout) {
        orow.copy_from_slice(bias);
    }
    for k in 0..din {
        let wrow = &w[k * dout..(k + 1) * dout];
        for (r, orow) in tile.chunks_exact_mut(dout).enumerate() {
            let xv = x[(r0 + r) * din + k];
            if xv == 0.0 {
                continue; // ReLU outputs are sparse; skip dead units
            }
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    if relu {
        for o in tile.iter_mut() {
            if *o < 0.0 {
                *o = 0.0;
            }
        }
    }
}

/// Forward activations of the 3-layer MLP for one input matrix.
#[derive(Default)]
struct Activations {
    h1: Vec<f32>,
    h2: Vec<f32>,
    q: Vec<f32>,
}

fn forward(
    params: &[Vec<f32>],
    dims: &[usize],
    x: &[f32],
    rows: usize,
    a: &mut Activations,
    pool: &ThreadPool,
) {
    dense(x, rows, dims[0], dims[1], &params[0], &params[1], true, &mut a.h1, pool);
    dense(&a.h1, rows, dims[1], dims[2], &params[2], &params[3], true, &mut a.h2, pool);
    dense(&a.h2, rows, dims[2], dims[3], &params[4], &params[5], false, &mut a.q, pool);
}

/// Reusable inference scratch for [`Engine::act_batch`] (and the
/// scalar [`Engine::act`], which is its 1-row case): activation buffers
/// plus the per-row action output survive across ticks, so actor-side
/// inference allocates nothing at steady state.
#[derive(Default)]
pub struct ActScratch {
    acts: Activations,
    actions: Vec<u32>,
}

impl ActScratch {
    /// Greedy actions from the most recent `act_batch` call.
    pub fn actions(&self) -> &[u32] {
        &self.actions
    }

    /// Q-values from the most recent `act_batch` call, flat row-major
    /// (`rows x n_actions`).
    pub fn q(&self) -> &[f32] {
        &self.acts.q
    }
}

/// Batched greedy actions against explicit parameters + network dims:
/// one [`forward`] over all rows, first-occurrence [`argmax`] per row,
/// everything written into `scratch`. This is the spec-free core shared
/// by [`Engine::act_batch`] and the actor-side policy snapshot
/// ([`crate::coordinator::PolicySnapshot::greedy_actions`]), which must
/// run without an engine in scope.
pub(crate) fn act_batch_dims<'s>(
    params: &[Vec<f32>],
    dims: &[usize],
    obs: &[f32],
    rows: usize,
    scratch: &'s mut ActScratch,
    pool: Option<&ThreadPool>,
) -> Result<&'s [u32]> {
    ensure!(dims.len() == 4, "act: dims must be the 3-layer MLP shape");
    ensure!(params.len() == 6, "act: params must be w0,b0,w1,b1,w2,b2");
    ensure!(obs.len() == rows * dims[0], "act: obs rows x dim mismatch");
    let pool = pool.unwrap_or_else(ThreadPool::inline);
    forward(params, dims, obs, rows, &mut scratch.acts, pool);
    let n = dims[3];
    scratch.actions.clear();
    scratch
        .actions
        .extend((0..rows).map(|r| argmax(&scratch.acts.q[r * n..(r + 1) * n]) as u32));
    Ok(&scratch.actions)
}

/// First-occurrence argmax over a row (jnp.argmax tie-breaking).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// The native execution engine for one environment spec.
pub struct Engine {
    spec: EnvArtifacts,
    /// Worker pool the hot kernels (dense fwd/bwd tiles, Adam tensors)
    /// dispatch on. Defaults to [`threads_from_env`]
    /// (`AMPER_ENGINE_THREADS`, absent → 1 = today's sequential path);
    /// serve installs a shared pool sized by the `engine_threads` config
    /// key. `Arc` so replay shards / multiple engines can share workers.
    pool: Arc<ThreadPool>,
}

impl Engine {
    /// Load the spec for `env`: from `<artifacts_dir>/manifest.json` when
    /// present (the AOT contract produced by `python/compile/aot.py`),
    /// otherwise from the built-in environment table — the native engine
    /// needs only the spec, not the lowered HLO.
    pub fn load(artifacts_dir: &Path, env: &str) -> Result<Engine> {
        let spec = if artifacts_dir.join("manifest.json").exists() {
            let manifest =
                Manifest::load(artifacts_dir).context("loading manifest")?;
            manifest.env(env)?.clone()
        } else {
            EnvArtifacts::builtin(env).with_context(|| {
                format!("unknown env '{env}' (no artifacts dir, no builtin spec)")
            })?
        };
        Ok(Engine {
            spec,
            pool: Arc::new(ThreadPool::new(threads_from_env())),
        })
    }

    /// Build an engine directly from a spec (tests, custom workloads).
    pub fn from_spec(spec: EnvArtifacts) -> Engine {
        Engine {
            spec,
            pool: Arc::new(ThreadPool::new(threads_from_env())),
        }
    }

    pub fn spec(&self) -> &EnvArtifacts {
        &self.spec
    }

    /// Resize the worker pool to `threads` (0 = `available_parallelism`,
    /// 1 = fully sequential kernels). No-op when the count is unchanged.
    pub fn set_threads(&mut self, threads: usize) {
        let resolved = super::threadpool::resolve_threads(threads);
        if resolved != self.pool.threads() {
            self.pool = Arc::new(ThreadPool::new(resolved));
        }
    }

    /// Install a shared worker pool (serve builds one pool and hands it
    /// to the engine *and* the shard-local replay builds).
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = pool;
    }

    /// Worker count the kernels currently dispatch across.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The engine's worker pool, for sharing with other subsystems.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Execute one fused train step (fwd + bwd + Adam). Updates `state`
    /// in place; returns TD errors and loss.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        batch: &TrainBatch,
    ) -> Result<StepOutput> {
        self.train_step_view(state, batch.view())
    }

    /// [`Self::train_step`] over a borrowed columnar view — the zero-copy
    /// entry point for gathered replay-service batches.
    pub fn train_step_view(
        &self,
        state: &mut TrainState,
        batch: TrainBatchRef<'_>,
    ) -> Result<StepOutput> {
        let mut scratch = TrainScratch::default();
        self.train_step_scratch(state, batch, &mut scratch)
    }

    /// [`Self::train_step_view`] with caller-owned [`TrainScratch`]: the
    /// activation and output-gradient buffers are reused across steps, so
    /// hot training loops stop allocating per step. Identical math and
    /// output to the scratch-free entry points.
    pub fn train_step_scratch(
        &self,
        state: &mut TrainState,
        batch: TrainBatchRef<'_>,
        scratch: &mut TrainScratch,
    ) -> Result<StepOutput> {
        let b = self.spec.batch;
        let d = self.spec.obs_dim;
        let dims = &self.spec.dims;
        let n_actions = dims[3];
        ensure!(batch.obs.len() == b * d, "batch obs size");
        ensure!(batch.actions.len() == b, "batch actions size");
        ensure!(batch.next_obs.len() == b * d, "batch next_obs size");
        ensure!(batch.rewards.len() == b, "batch rewards size");
        ensure!(batch.dones.len() == b, "batch dones size");
        ensure!(batch.is_weights.len() == b, "batch is_weights size");

        // ---- forward passes ------------------------------------------------
        let pool = &*self.pool;
        let on = &mut scratch.on; // online net on obs
        forward(&state.params, dims, batch.obs, b, on, pool);
        // online net on next_obs: only the double-DQN argmax reads it
        let next = &mut scratch.next;
        if self.spec.double_dqn {
            forward(&state.params, dims, batch.next_obs, b, next, pool);
        }
        let tgt = &mut scratch.tgt; // target net on next_obs
        forward(&state.target, dims, batch.next_obs, b, tgt, pool);

        // ---- TD target + Huber loss (td.py: _td_kernel) --------------------
        let gamma = self.spec.gamma;
        let td = &mut scratch.td;
        td.clear();
        td.resize(b, 0.0);
        let mut loss = 0.0f64;
        for i in 0..b {
            let a = batch.actions[i] as usize;
            ensure!(a < n_actions, "action {a} out of range");
            let q_sa = on.q[i * n_actions + a];
            let trow = &tgt.q[i * n_actions..(i + 1) * n_actions];
            let tmax = if self.spec.double_dqn {
                // Double DQN: argmax from the online net, value from target.
                let nrow = &next.q[i * n_actions..(i + 1) * n_actions];
                trow[argmax(nrow)]
            } else {
                trow[argmax(trow)]
            };
            let target = batch.rewards[i] + gamma * (1.0 - batch.dones[i]) * tmax;
            let e = target - q_sa;
            td[i] = e;
            let abs = e.abs();
            let huber = if abs <= HUBER_DELTA {
                0.5 * e * e
            } else {
                HUBER_DELTA * (abs - 0.5 * HUBER_DELTA)
            };
            loss += (batch.is_weights[i] * huber) as f64;
        }
        let loss = (loss / b as f64) as f32;

        // ---- backward (model.py: _td_bwd + _dense_bwd) ---------------------
        // d loss / d q_sa = -(1/B) * w * clip(td, ±δ); zero elsewhere.
        let dq = &mut scratch.dq;
        dq.clear();
        dq.resize(b * n_actions, 0.0);
        let inv_b = 1.0 / b as f32;
        for i in 0..b {
            let a = batch.actions[i] as usize;
            let clipped = td[i].clamp(-HUBER_DELTA, HUBER_DELTA);
            dq[i * n_actions + a] = -inv_b * batch.is_weights[i] * clipped;
        }
        // backprop through the online net on obs only (tmax carries
        // stop_gradient in model.py; the next_obs online pass feeds the
        // non-differentiable argmax).
        backward(
            &state.params,
            dims,
            batch.obs,
            b,
            on,
            dq,
            &mut scratch.grads,
            &mut scratch.dh1,
            &mut scratch.dh2,
            pool,
        );

        // ---- bias-corrected Adam (model.py: make_train_step) ---------------
        state.t += 1.0;
        let t_new = state.t;
        let b1t = ADAM_B1.powf(t_new);
        let b2t = ADAM_B2.powf(t_new);
        let lr = self.spec.lr;
        // One task per parameter tensor: each updates a disjoint
        // (p, m, v, g) quadruple, and the per-element recurrence inside a
        // tensor stays the sequential order — bit-identical at any
        // worker count.
        let grads = &scratch.grads;
        let p_ptr = SendPtr(state.params.as_mut_ptr());
        let m_ptr = SendPtr(state.m.as_mut_ptr());
        let v_ptr = SendPtr(state.v.as_mut_ptr());
        pool.run(6, &|ti| {
            let (p, m, v) = unsafe {
                (
                    &mut *p_ptr.0.add(ti),
                    &mut *m_ptr.0.add(ti),
                    &mut *v_ptr.0.add(ti),
                )
            };
            adam_tensor(p, &grads[ti], m, v, lr, b1t, b2t);
        });
        Ok(StepOutput {
            td: std::mem::take(&mut scratch.td),
            loss,
        })
    }

    /// Batched greedy actions for `rows` observations (flat row-major):
    /// **one** forward pass over all rows, first-occurrence argmax per
    /// row, scratch reused across ticks — zero per-call allocations once
    /// the scratch is warm. Takes explicit `params` so it serves both
    /// the live [`TrainState`] and a frozen policy-snapshot export;
    /// bit-identical to `rows` scalar [`Self::act`] calls (pinned by
    /// `batch_equivalence`).
    pub fn act_batch<'s>(
        &self,
        params: &[Vec<f32>],
        obs: &[f32],
        rows: usize,
        scratch: &'s mut ActScratch,
    ) -> Result<&'s [u32]> {
        act_batch_dims(params, &self.spec.dims, obs, rows, scratch, Some(&self.pool))
    }

    /// Greedy action for a single observation — the 1-row case of
    /// [`Self::act_batch`], sharing its scratch so the scalar hot loop
    /// (the agent's action phase) stops allocating an activation set and
    /// output `Vec` per call. Q-values stay readable via
    /// [`ActScratch::q`].
    pub fn act(
        &self,
        state: &TrainState,
        obs: &[f32],
        scratch: &mut ActScratch,
    ) -> Result<usize> {
        let actions = self.act_batch(&state.params, obs, 1, scratch)?;
        Ok(actions[0] as usize)
    }
}

/// Input-feature chunk width for the parallel dW pass: each task owns
/// `K_TILE` rows of dW (a disjoint stripe) and walks every batch row in
/// order, so the per-element accumulation sequence is exactly the
/// sequential one.
const K_TILE: usize = 16;

/// Backward pass of the 3-layer MLP: given d loss / d q (`dq`), write
/// gradients in param order w0,b0,w1,b1,w2,b2 into `grads` (sized and
/// zeroed here; `dh1`/`dh2` are the hidden-gradient scratch buffers).
/// Every parallel pass partitions **disjoint outputs** — dW by K_TILE
/// stripes, da by ROW_TILE row blocks — and keeps each element's
/// accumulation order identical to the sequential code, so the result is
/// bit-identical at any worker count (pinned by `batch_equivalence`).
#[allow(clippy::too_many_arguments)]
fn backward(
    params: &[Vec<f32>],
    dims: &[usize],
    x: &[f32],
    rows: usize,
    acts: &Activations,
    dq: &[f32],
    grads: &mut Vec<Vec<f32>>,
    dh1: &mut Vec<f32>,
    dh2: &mut Vec<f32>,
    pool: &ThreadPool,
) {
    let (d0, d1, d2, d3) = (dims[0], dims[1], dims[2], dims[3]);
    let sizes = [d0 * d1, d1, d1 * d2, d2, d2 * d3, d3];
    grads.resize(6, Vec::new());
    for (g, n) in grads.iter_mut().zip(sizes) {
        g.clear();
        g.resize(n, 0.0);
    }
    dh2.clear();
    dh2.resize(rows * d2, 0.0);
    dh1.clear();
    dh1.resize(rows * d1, 0.0);
    let (g01, rest) = grads.split_at_mut(2);
    let (g23, g45) = rest.split_at_mut(2);
    let (dw0, db0) = match g01 {
        [a, b] => (a, b),
        _ => unreachable!(),
    };
    let (dw1, db1) = match g23 {
        [a, b] => (a, b),
        _ => unreachable!(),
    };
    let (dw2, db2) = match g45 {
        [a, b] => (a, b),
        _ => unreachable!(),
    };
    // layer 2 (linear head): dW2 = h2^T dq, db2 = Σ dq, dh2 = dq W2^T
    layer_backward(&acts.h2, dq, &params[4], rows, d2, d3, dw2, db2, Some(dh2), pool);
    relu_mask(&acts.h2, dh2);
    // layer 1: dW1 = h1^T dh2, db1 = Σ dh2, dh1 = dh2 W1^T
    layer_backward(&acts.h1, dh2, &params[2], rows, d1, d2, dw1, db1, Some(dh1), pool);
    relu_mask(&acts.h1, dh1);
    // layer 0: dW0 = x^T dh1, db0 = Σ dh1 (no input gradient needed)
    layer_backward(x, dh1, &params[0], rows, d0, d1, dw0, db0, None, pool);
}

/// Shared per-layer backward: inputs `a` (rows × din), upstream gradient
/// `g` (rows × dout), weights `w` (din × dout). Accumulates dW (din ×
/// dout), db (dout) and, when requested, da (rows × din). Three passes
/// with disjoint outputs: db sequentially on the caller (tiny), dW
/// across [`K_TILE`] stripes of input features, da across [`ROW_TILE`]
/// row blocks.
#[allow(clippy::too_many_arguments)]
fn layer_backward(
    a: &[f32],
    g: &[f32],
    w: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    da: Option<&mut [f32]>,
    pool: &ThreadPool,
) {
    // db = Σ_r g[r]: dout elements, cheaper than a dispatch.
    for grow in g.chunks_exact(dout) {
        for (o, &gv) in db.iter_mut().zip(grow) {
            *o += gv;
        }
    }
    // dW: task t owns rows k0..k1 of dW and scans all batch rows in
    // order — same accumulation sequence as the sequential loop.
    let ktiles = din.div_ceil(K_TILE);
    if pool.threads() <= 1 || ktiles <= 1 {
        dw_ktile(a, g, rows, din, dout, 0, din, dw);
    } else {
        let dw_ptr = SendPtr(dw.as_mut_ptr());
        pool.run(ktiles, &|t| {
            let k0 = t * K_TILE;
            let k1 = (k0 + K_TILE).min(din);
            let dwt = unsafe {
                std::slice::from_raw_parts_mut(
                    dw_ptr.0.add(k0 * dout),
                    (k1 - k0) * dout,
                )
            };
            dw_ktile(a, g, rows, din, dout, k0, k1, dwt);
        });
    }
    // da: row r's gradient is a set of independent dot products — tile
    // over rows like the forward pass.
    if let Some(da) = da {
        let tiles = rows.div_ceil(ROW_TILE);
        if pool.threads() <= 1 || tiles <= 1 {
            da_rows(g, w, 0, rows, din, dout, da);
        } else {
            let da_ptr = SendPtr(da.as_mut_ptr());
            pool.run(tiles, &|t| {
                let r0 = t * ROW_TILE;
                let r1 = (r0 + ROW_TILE).min(rows);
                let dat = unsafe {
                    std::slice::from_raw_parts_mut(
                        da_ptr.0.add(r0 * din),
                        (r1 - r0) * din,
                    )
                };
                da_rows(g, w, r0, r1, din, dout, dat);
            });
        }
    }
}

/// dW stripe `k0..k1`: `dwt` is `dw[k0*dout..k1*dout]`. Scans every
/// batch row in order, so each dW element sees the same accumulation
/// sequence as the full sequential pass.
#[allow(clippy::too_many_arguments)]
fn dw_ktile(
    a: &[f32],
    g: &[f32],
    rows: usize,
    din: usize,
    dout: usize,
    k0: usize,
    k1: usize,
    dwt: &mut [f32],
) {
    debug_assert_eq!(dwt.len(), (k1 - k0) * dout);
    for r in 0..rows {
        let arow = &a[r * din + k0..r * din + k1];
        let grow = &g[r * dout..(r + 1) * dout];
        for (k, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let wg = &mut dwt[k * dout..(k + 1) * dout];
                for (o, &gv) in wg.iter_mut().zip(grow) {
                    *o += av * gv;
                }
            }
        }
    }
}

/// da rows `r0..r1`: `dat` is `da[r0*din..r1*din]`; each element is an
/// independent dot product `w[k,:] · g[r,:]`.
fn da_rows(
    g: &[f32],
    w: &[f32],
    r0: usize,
    r1: usize,
    din: usize,
    dout: usize,
    dat: &mut [f32],
) {
    debug_assert_eq!(dat.len(), (r1 - r0) * din);
    for (r, darow) in (r0..r1).zip(dat.chunks_exact_mut(din)) {
        let grow = &g[r * dout..(r + 1) * dout];
        for (k, dv) in darow.iter_mut().enumerate() {
            let wrow = &w[k * dout..(k + 1) * dout];
            let mut acc = 0.0f32;
            for (&wv, &gv) in wrow.iter().zip(grow) {
                acc += wv * gv;
            }
            *dv = acc;
        }
    }
}

/// Bias-corrected Adam over one parameter tensor (disjoint per tensor —
/// the unit of the parallel update pass).
fn adam_tensor(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    b1t: f32,
    b2t: f32,
) {
    for i in 0..p.len() {
        let gi = g[i];
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
        let mhat = m[i] / (1.0 - b1t);
        let vhat = v[i] / (1.0 - b2t);
        p[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// Zero the gradient where the forward ReLU output was clamped.
fn relu_mask(y: &[f32], dy: &mut [f32]) {
    for (d, &v) in dy.iter_mut().zip(y) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> EnvArtifacts {
        let mut spec = EnvArtifacts::builtin("cartpole").unwrap();
        spec.hidden = 16;
        spec.batch = 8;
        spec.dims = vec![spec.obs_dim, 16, 16, spec.n_actions];
        spec
    }

    fn random_batch(spec: &EnvArtifacts, seed: u64) -> TrainBatch {
        let mut rng = Rng::new(seed);
        let mut batch = TrainBatch::zeros(spec.batch, spec.obs_dim);
        for x in batch.obs.iter_mut().chain(batch.next_obs.iter_mut()) {
            *x = rng.normal_f32(0.0, 1.0);
        }
        for a in batch.actions.iter_mut() {
            *a = rng.below(spec.n_actions) as i32;
        }
        for r in batch.rewards.iter_mut() {
            *r = rng.f32();
        }
        batch
    }

    #[test]
    fn engine_loads_builtin_and_steps_cartpole() {
        let engine =
            Engine::load(Path::new("definitely-not-a-dir"), "cartpole").unwrap();
        let spec = engine.spec().clone();
        assert_eq!(spec.dims, vec![4, 128, 128, 2]);
        let mut state = TrainState::init(&spec, 0).unwrap();
        let batch = random_batch(&spec, 1);
        let out = engine.train_step(&mut state, &batch).unwrap();
        assert_eq!(out.td.len(), spec.batch);
        assert!(out.loss.is_finite());
        assert!(out.td.iter().all(|x| x.is_finite()));
        assert_eq!(state.t, 1.0);

        // act path
        let obs = vec![0.1f32; spec.obs_dim];
        let mut scratch = ActScratch::default();
        let action = engine.act(&state, &obs, &mut scratch).unwrap();
        assert!(action < spec.n_actions);
        assert_eq!(scratch.q().len(), spec.n_actions);
        assert_eq!(scratch.actions(), &[action as u32]);
    }

    #[test]
    fn batched_act_is_bit_identical_to_scalar_act() {
        // one forward over N rows == N one-row forwards: the row tiling
        // in `dense` must not change any per-element accumulation order
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let state = TrainState::init(&spec, 17).unwrap();
        let d = spec.obs_dim;
        let rows = 3 * ROW_TILE + 1; // cover full tiles and a ragged tail
        let mut rng = Rng::new(99);
        let obs: Vec<f32> =
            (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut batched = ActScratch::default();
        let actions = engine
            .act_batch(&state.params, &obs, rows, &mut batched)
            .unwrap()
            .to_vec();
        let q = batched.q().to_vec();
        let mut scalar = ActScratch::default();
        for r in 0..rows {
            let row = &obs[r * d..(r + 1) * d];
            let a = engine.act(&state, row, &mut scalar).unwrap();
            assert_eq!(actions[r], a as u32, "row {r}");
            let nq = spec.n_actions;
            for (j, (&bq, &sq)) in q[r * nq..(r + 1) * nq]
                .iter()
                .zip(scalar.q())
                .enumerate()
            {
                assert_eq!(bq.to_bits(), sq.to_bits(), "row {r} q[{j}]");
            }
        }
    }

    #[test]
    fn act_batch_rejects_bad_shapes() {
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let state = TrainState::init(&spec, 1).unwrap();
        let mut s = ActScratch::default();
        let obs = vec![0.0; spec.obs_dim * 2];
        assert!(engine.act_batch(&state.params, &obs, 3, &mut s).is_err());
        assert!(engine.act(&state, &obs, &mut s).is_err());
    }

    #[test]
    fn view_and_owned_batch_train_identically() {
        // the borrowed view is the same computation as the owned batch —
        // gathered service replies must not need a repack
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let batch = random_batch(&spec, 21);
        let mut s1 = TrainState::init(&spec, 5).unwrap();
        let mut s2 = TrainState::init(&spec, 5).unwrap();
        let o1 = engine.train_step(&mut s1, &batch).unwrap();
        let o2 = engine
            .train_step_view(
                &mut s2,
                TrainBatchRef {
                    obs: &batch.obs,
                    actions: &batch.actions,
                    rewards: &batch.rewards,
                    next_obs: &batch.next_obs,
                    dones: &batch.dones,
                    is_weights: &batch.is_weights,
                },
            )
            .unwrap();
        assert_eq!(o1.td, o2.td);
        assert_eq!(o1.loss, o2.loss);
        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn reused_scratch_trains_identically_across_steps() {
        // a single TrainScratch carried across steps (the pipelined
        // learner's usage) must match fresh per-step allocations exactly
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let mut s1 = TrainState::init(&spec, 3).unwrap();
        let mut s2 = TrainState::init(&spec, 3).unwrap();
        let mut scratch = TrainScratch::default();
        for seed in 0..5u64 {
            let batch = random_batch(&spec, 100 + seed);
            let o1 = engine.train_step_view(&mut s1, batch.view()).unwrap();
            let o2 = engine
                .train_step_scratch(&mut s2, batch.view(), &mut scratch)
                .unwrap();
            assert_eq!(o1.td, o2.td, "seed {seed}");
            assert_eq!(o1.loss, o2.loss, "seed {seed}");
        }
        assert_eq!(s1.params, s2.params);
        assert_eq!(s1.m, s2.m);
    }

    #[test]
    fn gathered_batch_views_as_train_batch() {
        let mut g = GatheredBatch::default();
        g.reset(8, 4);
        g.rewards[3] = 1.5;
        let v: TrainBatchRef<'_> = (&g).into();
        assert_eq!(v.obs.len(), 32);
        assert_eq!(v.rewards[3], 1.5);
        assert_eq!(v.is_weights.len(), 8);
    }

    #[test]
    fn unknown_env_without_artifacts_errors() {
        assert!(Engine::load(Path::new("nope"), "atari-pong").is_err());
    }

    #[test]
    fn repeated_steps_reduce_loss_on_fixed_batch() {
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let mut state = TrainState::init(&spec, 7).unwrap();
        let mut batch = random_batch(&spec, 3);
        for dn in batch.dones.iter_mut() {
            *dn = 1.0; // pure regression to rewards
        }
        let first = engine.train_step(&mut state, &batch).unwrap().loss;
        let mut last = first;
        for _ in 0..60 {
            last = engine.train_step(&mut state, &batch).unwrap().loss;
        }
        assert!(last < first * 0.5, "loss did not descend: {first} -> {last}");
    }

    #[test]
    fn target_sync_copies_params() {
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let mut state = TrainState::init(&spec, 2).unwrap();
        let mut batch = random_batch(&spec, 5);
        for dn in batch.dones.iter_mut() {
            *dn = 1.0;
        }
        for r in batch.rewards.iter_mut() {
            *r = 1.0;
        }
        engine.train_step(&mut state, &batch).unwrap();
        assert_ne!(state.params[0], state.target[0]);
        state.sync_target().unwrap();
        assert_eq!(state.params[0], state.target[0]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Check dW0/dW2/db1 entries against central differences of the
        // scalar loss — the native backward must match the math it claims.
        // done=1 everywhere: the TD target reduces to the reward, so the
        // loss is smooth in the online params (no argmax flips that would
        // poison the finite-difference estimate); the full backward path
        // through all three layers is still exercised.
        let spec = tiny_spec();
        let mut batch = random_batch(&spec, 11);
        for dn in batch.dones.iter_mut() {
            *dn = 1.0;
        }

        // loss with frozen state (no Adam update): recompute via a clone
        let pool = ThreadPool::inline();
        let loss_of = |params: &Vec<Vec<f32>>, target: &Vec<Vec<f32>>| -> f32 {
            let mut on = Activations::default();
            forward(params, &spec.dims, &batch.obs, spec.batch, &mut on, pool);
            let mut next = Activations::default();
            forward(params, &spec.dims, &batch.next_obs, spec.batch, &mut next, pool);
            let mut tgt = Activations::default();
            forward(target, &spec.dims, &batch.next_obs, spec.batch, &mut tgt, pool);
            let na = spec.dims[3];
            let mut loss = 0.0f64;
            for i in 0..spec.batch {
                let a = batch.actions[i] as usize;
                let q_sa = on.q[i * na + a];
                let trow = &tgt.q[i * na..(i + 1) * na];
                let nrow = &next.q[i * na..(i + 1) * na];
                let tmax = trow[argmax(nrow)];
                let target_v =
                    batch.rewards[i] + spec.gamma * (1.0 - batch.dones[i]) * tmax;
                let e = target_v - q_sa;
                let abs = e.abs();
                let huber = if abs <= HUBER_DELTA {
                    0.5 * e * e
                } else {
                    HUBER_DELTA * (abs - 0.5 * HUBER_DELTA)
                };
                loss += (batch.is_weights[i] * huber) as f64;
            }
            (loss / spec.batch as f64) as f32
        };

        let state = TrainState::init(&spec, 13).unwrap();
        // analytic grads (recompute the backward exactly as train_step does)
        let mut on = Activations::default();
        forward(&state.params, &spec.dims, &batch.obs, spec.batch, &mut on, pool);
        let mut next = Activations::default();
        forward(&state.params, &spec.dims, &batch.next_obs, spec.batch, &mut next, pool);
        let mut tgt = Activations::default();
        forward(&state.target, &spec.dims, &batch.next_obs, spec.batch, &mut tgt, pool);
        let na = spec.dims[3];
        let mut dq = vec![0.0f32; spec.batch * na];
        for i in 0..spec.batch {
            let a = batch.actions[i] as usize;
            let q_sa = on.q[i * na + a];
            let trow = &tgt.q[i * na..(i + 1) * na];
            let nrow = &next.q[i * na..(i + 1) * na];
            let tmax = trow[argmax(nrow)];
            let tv = batch.rewards[i] + spec.gamma * (1.0 - batch.dones[i]) * tmax;
            let e = tv - q_sa;
            dq[i * na + a] = -(1.0 / spec.batch as f32)
                * batch.is_weights[i]
                * e.clamp(-HUBER_DELTA, HUBER_DELTA);
        }
        let mut grads = Vec::new();
        let mut dh1 = Vec::new();
        let mut dh2 = Vec::new();
        backward(
            &state.params,
            &spec.dims,
            &batch.obs,
            spec.batch,
            &on,
            &dq,
            &mut grads,
            &mut dh1,
            &mut dh2,
            pool,
        );

        let eps = 1e-3f32;
        // probe a few entries in every parameter tensor
        for (pi, stride) in [(0usize, 7usize), (2, 13), (4, 3), (1, 5), (3, 4), (5, 1)] {
            for idx in (0..state.params[pi].len()).step_by(stride.max(1)).take(6) {
                let mut plus = state.params.clone();
                plus[pi][idx] += eps;
                let mut minus = state.params.clone();
                minus[pi][idx] -= eps;
                let fd = (loss_of(&plus, &state.target)
                    - loss_of(&minus, &state.target))
                    / (2.0 * eps);
                let an = grads[pi][idx];
                assert!(
                    (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                    "param {pi} idx {idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn recycled_td_buffer_is_not_reallocated() {
        // hot-loop contract: recycle() hands the TD buffer back, and the
        // next step refills it in place — same allocation every step
        let spec = tiny_spec();
        let engine = Engine::from_spec(spec.clone());
        let mut state = TrainState::init(&spec, 9).unwrap();
        let mut scratch = TrainScratch::default();
        let batch = random_batch(&spec, 31);
        let out = engine
            .train_step_scratch(&mut state, batch.view(), &mut scratch)
            .unwrap();
        let ptr = out.td.as_ptr();
        let cap = out.td.capacity();
        scratch.recycle(out);
        for seed in 0..4u64 {
            let batch = random_batch(&spec, 200 + seed);
            let out = engine
                .train_step_scratch(&mut state, batch.view(), &mut scratch)
                .unwrap();
            assert_eq!(out.td.as_ptr(), ptr, "td buffer moved on step {seed}");
            assert_eq!(out.td.capacity(), cap, "td buffer regrew on step {seed}");
            scratch.recycle(out);
        }
    }

    #[test]
    fn multi_threaded_train_step_is_bit_identical() {
        // the whole point of the disjoint-output decomposition: params,
        // TD errors and loss match the sequential path bit for bit
        let spec = tiny_spec();
        let mut engines = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut e = Engine::from_spec(spec.clone());
            e.set_threads(threads);
            engines.push(e);
        }
        let mut states: Vec<TrainState> = (0..engines.len())
            .map(|_| TrainState::init(&spec, 41).unwrap())
            .collect();
        let mut scratches: Vec<TrainScratch> =
            (0..engines.len()).map(|_| TrainScratch::default()).collect();
        for seed in 0..6u64 {
            let batch = random_batch(&spec, 300 + seed);
            let mut outs = Vec::new();
            for ((e, st), sc) in
                engines.iter().zip(states.iter_mut()).zip(scratches.iter_mut())
            {
                outs.push(e.train_step_scratch(st, batch.view(), sc).unwrap());
            }
            for o in &outs[1..] {
                assert_eq!(o.loss.to_bits(), outs[0].loss.to_bits(), "seed {seed}");
                let a: Vec<u32> = o.td.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = outs[0].td.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "seed {seed}");
            }
            for (sc, o) in scratches.iter_mut().zip(outs) {
                sc.recycle(o);
            }
        }
        for st in &states[1..] {
            for (t, (a, b)) in st.params.iter().zip(&states[0].params).enumerate() {
                let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb, "param tensor {t}");
            }
        }
    }
}
