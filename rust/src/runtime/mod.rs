//! The PJRT runtime: loads the AOT-lowered HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Flow (see /opt/xla-example/load_hlo and DESIGN.md §2):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos).
//!
//! Python never runs here; the binary is self-contained once
//! `make artifacts` has produced `artifacts/`.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, TrainBatch, TrainState};
pub use manifest::{EnvArtifacts, Manifest};
