//! The execution runtime: the native train/act engine plus the
//! `artifacts/manifest.json` contract shared with `python/compile/aot.py`.
//!
//! [`Engine`] computes the same graph the JAX/Pallas stack lowers to HLO
//! (3-layer MLP, double-DQN TD target, IS-weighted Huber, Adam), entirely
//! in Rust — the build is offline, so the PJRT/xla execution path was
//! replaced by this native implementation; the manifest (when present)
//! still supplies per-env network dims/batch, and the lowered HLO
//! artifacts remain the interchange contract for a vendored PJRT backend.
//!
//! Python never runs here; the binary is self-contained.

pub mod engine;
pub mod manifest;
pub mod threadpool;

pub use engine::{ActScratch, Engine, TrainBatch, TrainBatchRef, TrainScratch, TrainState};
pub use manifest::{EnvArtifacts, Manifest};
pub use threadpool::{resolve_threads, ThreadPool};
