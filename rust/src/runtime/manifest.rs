//! `artifacts/manifest.json` — the contract between `aot.py` (producer)
//! and the Rust runtime (consumer): per-environment network dims, batch
//! size, artifact filenames and flat input layouts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Artifact descriptors for one environment.
#[derive(Debug, Clone)]
pub struct EnvArtifacts {
    pub name: String,
    pub obs_dim: usize,
    pub n_actions: usize,
    pub hidden: usize,
    pub batch: usize,
    pub gamma: f32,
    pub lr: f32,
    pub double_dqn: bool,
    /// Layer dims [obs, h, h, actions].
    pub dims: Vec<usize>,
    pub train_artifact: PathBuf,
    pub act_artifact: PathBuf,
}

impl EnvArtifacts {
    /// Built-in spec table mirroring `python/compile/model.py::ENV_SPECS`
    /// — used by the native engine when no `artifacts/` directory has
    /// been produced (the manifest always wins when present).
    pub fn builtin(name: &str) -> Option<EnvArtifacts> {
        let (obs_dim, n_actions, hidden, batch) = match name {
            "cartpole" => (4, 2, 128, 64),
            "acrobot" => (6, 3, 128, 64),
            "lunarlander" => (8, 4, 128, 64),
            "mountaincar" => (2, 3, 128, 64),
            "pongproxy" => (6400, 6, 512, 32),
            _ => return None,
        };
        Some(EnvArtifacts {
            name: name.to_string(),
            obs_dim,
            n_actions,
            hidden,
            batch,
            gamma: 0.99,
            lr: 1e-3,
            double_dqn: true,
            dims: vec![obs_dim, hidden, hidden, n_actions],
            train_artifact: PathBuf::from(format!("{name}_train.hlo.txt")),
            act_artifact: PathBuf::from(format!("{name}_act.hlo.txt")),
        })
    }

    /// Shapes of the 6 parameter arrays (w0,b0,w1,b1,w2,b2).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let d = &self.dims;
        let mut out = Vec::with_capacity(6);
        for i in 0..3 {
            out.push(vec![d[i], d[i + 1]]);
            out.push(vec![d[i + 1]]);
        }
        out
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub envs: BTreeMap<String, EnvArtifacts>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&src, dir)
    }

    /// Parse manifest JSON with artifact paths rooted at `dir`.
    pub fn parse(src: &str, dir: &Path) -> Result<Manifest, String> {
        let j = Json::parse(src).map_err(|e| e.to_string())?;
        let envs_j = j
            .get("envs")
            .and_then(Json::as_obj)
            .ok_or("manifest: missing 'envs'")?;
        let mut envs = BTreeMap::new();
        for (name, e) in envs_j {
            let usz = |k: &str| -> Result<usize, String> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("manifest env {name}: missing {k}"))
            };
            let f = |k: &str| -> Result<f32, String> {
                e.get(k)
                    .and_then(Json::as_f64)
                    .map(|x| x as f32)
                    .ok_or_else(|| format!("manifest env {name}: missing {k}"))
            };
            let s = |k: &str| -> Result<String, String> {
                e.get(k)
                    .and_then(Json::as_str)
                    .map(String::from)
                    .ok_or_else(|| format!("manifest env {name}: missing {k}"))
            };
            let dims = e
                .get("dims")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("manifest env {name}: missing dims"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect::<Vec<_>>();
            if dims.len() != 4 || dims.iter().any(|&d| d == 0) {
                return Err(format!("manifest env {name}: bad dims {dims:?}"));
            }
            envs.insert(
                name.clone(),
                EnvArtifacts {
                    name: name.clone(),
                    obs_dim: usz("obs_dim")?,
                    n_actions: usz("n_actions")?,
                    hidden: usz("hidden")?,
                    batch: usz("batch")?,
                    gamma: f("gamma")?,
                    lr: f("lr")?,
                    double_dqn: e
                        .get("double_dqn")
                        .and_then(Json::as_bool)
                        .unwrap_or(true),
                    dims,
                    train_artifact: dir.join(s("train_artifact")?),
                    act_artifact: dir.join(s("act_artifact")?),
                },
            );
        }
        Ok(Manifest { envs, dir: dir.to_path_buf() })
    }

    pub fn env(&self, name: &str) -> Result<&EnvArtifacts, String> {
        self.envs
            .get(name)
            .ok_or_else(|| format!("env '{name}' not in manifest (have: {:?})",
                self.envs.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "envs": {
            "cartpole": {
                "obs_dim": 4, "n_actions": 2, "hidden": 128, "batch": 64,
                "gamma": 0.99, "lr": 0.001, "double_dqn": true,
                "dims": [4, 128, 128, 2],
                "train_artifact": "cartpole_train.hlo.txt",
                "act_artifact": "cartpole_act.hlo.txt",
                "train_inputs": [], "act_inputs": []
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        let e = m.env("cartpole").unwrap();
        assert_eq!(e.obs_dim, 4);
        assert_eq!(e.dims, vec![4, 128, 128, 2]);
        assert_eq!(e.batch, 64);
        assert!((e.gamma - 0.99).abs() < 1e-6);
        assert_eq!(
            e.train_artifact,
            PathBuf::from("/art/cartpole_train.hlo.txt")
        );
        assert!(m.env("nope").is_err());
    }

    #[test]
    fn param_shapes_and_count() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let e = m.env("cartpole").unwrap();
        assert_eq!(
            e.param_shapes(),
            vec![
                vec![4, 128],
                vec![128],
                vec![128, 128],
                vec![128],
                vec![128, 2],
                vec![2]
            ]
        );
        assert_eq!(e.param_count(), 4 * 128 + 128 + 128 * 128 + 128 + 128 * 2 + 2);
    }

    #[test]
    fn builtin_specs_cover_all_envs() {
        for (name, obs, act) in [
            ("cartpole", 4, 2),
            ("acrobot", 6, 3),
            ("lunarlander", 8, 4),
            ("mountaincar", 2, 3),
            ("pongproxy", 6400, 6),
        ] {
            let s = EnvArtifacts::builtin(name).unwrap();
            assert_eq!(s.obs_dim, obs, "{name}");
            assert_eq!(s.n_actions, act, "{name}");
            assert_eq!(s.dims, vec![obs, s.hidden, s.hidden, act]);
        }
        assert!(EnvArtifacts::builtin("atari-pong").is_none());
    }

    #[test]
    fn real_repo_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["cartpole", "acrobot", "lunarlander"] {
            let e = m.env(name).unwrap();
            assert!(e.train_artifact.exists(), "{:?}", e.train_artifact);
            assert!(e.act_artifact.exists());
        }
    }
}
