//! Named experiment presets: the paper's evaluation settings (Fig 8 /
//! Table 1 rows) exposed as `--preset` keys.

use super::TrainConfig;
use crate::replay::ReplayKind;

/// Resolve a preset by name. Table 1 rows are `<env>-<size>`; replay kind
/// defaults to PER and is overridden by `--replay`.
pub fn preset(name: &str) -> Option<TrainConfig> {
    let mut c = TrainConfig::default();
    match name {
        // Table 1 / Fig 8c: CartPole, ER 2000
        "cartpole-2000" => {
            c.env = "cartpole".into();
            c.er_size = 2000;
            c.steps = 20_000;
        }
        // Fig 8d: CartPole, ER 5000
        "cartpole-5000" => {
            c.env = "cartpole".into();
            c.er_size = 5000;
            c.steps = 30_000;
        }
        // Fig 8e: Acrobot, ER 10000
        "acrobot-10000" => {
            c.env = "acrobot".into();
            c.er_size = 10_000;
            c.steps = 50_000;
            c.eps_decay_steps = 10_000;
        }
        // Fig 8f: LunarLander, ER 20000
        "lunarlander-20000" => {
            c.env = "lunarlander".into();
            c.er_size = 20_000;
            c.steps = 80_000;
            c.eps_decay_steps = 20_000;
            c.target_sync = 1000;
        }
        // small smoke preset for CI / quickstart
        "smoke" => {
            c.env = "cartpole".into();
            c.er_size = 500;
            c.steps = 1_500;
            c.warmup = 200;
            c.eps_decay_steps = 800;
            c.target_sync = 200;
        }
        "mountaincar-10000" => {
            c.env = "mountaincar".into();
            c.er_size = 10_000;
            c.steps = 40_000;
            c.eps_decay_steps = 15_000;
        }
        // serving profile for `amper serve`: production-sized memory,
        // sharded replay service (paper-faithful one port per bank, N
        // banks), adaptive actor ingest (flush grows 8 → 128 under
        // queue depth), double-buffered learner over a pooled zero-copy
        // reply path, actors on epoch-versioned policy snapshots
        // refreshed every 8 train steps
        "serve-sharded" => {
            c.env = "cartpole".into();
            c.replay = ReplayKind::AmperFr;
            c.er_size = 100_000;
            c.replay_shards = 4;
            c.push_batch = 32;
            c.push_batch_min = 8;
            c.push_batch_max = 128;
            c.pipeline_depth = 2;
            c.reply_pool = 8;
            c.snapshot_interval = 8;
        }
        _ => return None,
    }
    Some(c)
}

/// All preset names (CLI help).
pub const PRESET_NAMES: [&str; 7] = [
    "cartpole-2000",
    "cartpole-5000",
    "acrobot-10000",
    "lunarlander-20000",
    "mountaincar-10000",
    "smoke",
    "serve-sharded",
];

/// The Fig 8 suite: the four paper rows with all three prioritized
/// replay techniques.
pub fn fig8_suite() -> Vec<(TrainConfig, ReplayKind)> {
    let rows = ["cartpole-2000", "cartpole-5000", "acrobot-10000", "lunarlander-20000"];
    let kinds = [ReplayKind::Per, ReplayKind::AmperK, ReplayKind::AmperFr];
    let mut out = Vec::new();
    for row in rows {
        for kind in kinds {
            let mut c = preset(row).unwrap();
            c.replay = kind;
            out.push((c, kind));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for name in PRESET_NAMES {
            let c = preset(name).unwrap();
            assert!(!c.env.is_empty());
            assert!(c.er_size > 0);
            assert!(c.push_batch >= 1);
        }
        assert!(preset("bogus").is_none());
        assert_eq!(preset("serve-sharded").unwrap().push_batch, 32);
        assert_eq!(preset("serve-sharded").unwrap().pipeline_depth, 2);
        assert_eq!(preset("serve-sharded").unwrap().snapshot_interval, 8);
    }

    #[test]
    fn serve_preset_enables_adaptive_flush() {
        let p = preset("serve-sharded").unwrap().flush_policy();
        assert_eq!((p.min(), p.max()), (8, 128));
        assert!(!p.is_fixed());
    }

    #[test]
    fn table1_sizes_match_paper() {
        assert_eq!(preset("cartpole-2000").unwrap().er_size, 2000);
        assert_eq!(preset("cartpole-5000").unwrap().er_size, 5000);
        assert_eq!(preset("acrobot-10000").unwrap().er_size, 10_000);
        assert_eq!(preset("lunarlander-20000").unwrap().er_size, 20_000);
    }

    #[test]
    fn fig8_suite_is_4x3() {
        assert_eq!(fig8_suite().len(), 12);
    }
}
