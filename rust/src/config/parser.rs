//! TOML-subset parser: `key = value` lines, `[section]` headers (flattened
//! to `section.key`), `#` comments, quoted/unquoted scalars. Covers what
//! experiment configs need without an external crate.

use std::collections::BTreeMap;
use std::fmt;

/// Flat `section.key -> value` map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConfigMap {
    map: BTreeMap<String, String>,
}

/// Line-addressed parse error.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl ConfigMap {
    pub fn parse(src: &str) -> Result<ConfigMap, ParseError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| ParseError {
                        line: i + 1,
                        msg: "unterminated section header".into(),
                    })?
                    .trim();
                if name.is_empty() {
                    return Err(ParseError { line: i + 1, msg: "empty section".into() });
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ParseError {
                line: i + 1,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = k.trim();
            if key.is_empty() {
                return Err(ParseError { line: i + 1, msg: "empty key".into() });
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            map.insert(full_key, unquote(v.trim()).to_string());
        }
        Ok(ConfigMap { map })
    }

    pub fn load(path: &str) -> Result<ConfigMap, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        Self::parse(&src).map_err(|e| format!("{path}: {e}"))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    pub fn insert(&mut self, key: &str, val: &str) {
        self.map.insert(key.to_string(), val.to_string());
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let m = ConfigMap::parse(
            "a = 1\n[amper]\nm = 20\nlambda = 0.15\n[per]\nalpha = \"0.6\"\n",
        )
        .unwrap();
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("amper.m"), Some("20"));
        assert_eq!(m.get("amper.lambda"), Some("0.15"));
        assert_eq!(m.get("per.alpha"), Some("0.6"));
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = ConfigMap::parse("# top\n\nx = 5 # trailing\ny = \"a#b\"\n").unwrap();
        assert_eq!(m.get("x"), Some("5"));
        assert_eq!(m.get("y"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = ConfigMap::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = ConfigMap::parse("[oops\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn later_keys_override() {
        let m = ConfigMap::parse("x = 1\nx = 2\n").unwrap();
        assert_eq!(m.get("x"), Some("2"));
    }
}
