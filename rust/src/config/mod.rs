//! Experiment configuration: a TOML-subset parser (offline build — no
//! serde), typed config structs, and the preset table the CLI exposes.

pub mod parser;
pub mod presets;

pub use parser::{ConfigMap, ParseError};
pub use presets::preset;

use crate::replay::{registry, ReplayKind, ReplayParams};

/// Full experiment configuration for one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Environment key: cartpole | acrobot | lunarlander | mountaincar.
    pub env: String,
    /// Replay technique.
    pub replay: ReplayKind,
    /// ER memory capacity (paper: 2000-20000 per env).
    pub er_size: usize,
    /// Total environment steps.
    pub steps: u64,
    /// Training batch size (paper: 64).
    pub batch: usize,
    /// Steps between target-network syncs.
    pub target_sync: u64,
    /// Env steps before learning starts.
    pub warmup: u64,
    /// Train every `train_every` env steps.
    pub train_every: u64,
    /// ε-greedy schedule: start, end, decay steps.
    pub eps_start: f32,
    pub eps_end: f32,
    pub eps_decay_steps: u64,
    /// RNG seed.
    pub seed: u64,
    /// Per-technique replay hyper-parameters, set through the unified
    /// `replay.<technique>.<field>` config namespace (legacy bare
    /// `per.*` / `amper.*` keys route to the same fields).
    pub replay_params: ReplayParams,
    /// Route AMPER replay ops through the simulated accelerator
    /// ([`crate::replay::HwAmperReplay`]) and account modeled device ns.
    pub hw_replay: bool,
    /// Shard count for the replay *service* deployments (`amper serve`,
    /// ingest benches): 1 = single-owner [`ReplayService`], N > 1 =
    /// [`ShardedReplayService`] with `er_size` partitioned across shards.
    ///
    /// [`ReplayService`]: crate::coordinator::ReplayService
    /// [`ShardedReplayService`]: crate::coordinator::ShardedReplayService
    pub replay_shards: usize,
    /// Actor-side ingest batch for the replay services: each env actor
    /// accumulates this many transitions into an
    /// [`ExperienceBatch`](crate::replay::ExperienceBatch) before
    /// flushing one `PushBatch` command (1 = scalar one-command-per-step
    /// ingest).
    pub push_batch: usize,
    /// Lower bound for the adaptive actor flush (`amper serve`): the
    /// [`FlushController`](crate::coordinator::FlushController) starts
    /// here and halves back toward it when the service command queue is
    /// shallow. 0 (default) inherits `push_batch`, i.e. a fixed flush.
    pub push_batch_min: usize,
    /// Upper bound for the adaptive actor flush: the controller doubles
    /// toward it while the command queue is deep. 0 (default) inherits
    /// `push_batch`. Setting `push_batch_min < push_batch_max` enables
    /// depth-aware flushing; equal bounds reproduce the fixed path
    /// bit-exactly.
    pub push_batch_max: usize,
    /// Idle gathered-reply buffers each service pool retains for reuse
    /// (`amper serve`): the learner recycles consumed `GatheredBatch`
    /// buffers and the workers gather into them, so steady-state replies
    /// allocate nothing. 0 disables pooling (every reply allocates —
    /// the pre-pool behavior, kept for baseline benchmarking).
    pub reply_pool: usize,
    /// Gather requests the serve learner keeps in flight
    /// ([`GatherPipeline`](crate::coordinator::GatherPipeline)): 1 =
    /// synchronous request → train → update; 2 = double-buffered (train
    /// batch N while batch N+1 gathers). Capped at 8 — beyond that the
    /// reply pool and priority staleness grow with no latency left to
    /// hide.
    pub pipeline_depth: usize,
    /// Worker threads for the engine's hot kernels (dense forward /
    /// backward tiles, Adam tensor updates) and the shard-local AMPER
    /// CSP sorts: 0 (default) = `available_parallelism`, 1 = fully
    /// sequential (today's code path exactly). Results are bit-identical
    /// at any setting — the kernels partition disjoint outputs and keep
    /// every per-element accumulation order unchanged.
    pub engine_threads: usize,
    /// Train steps between policy-snapshot publications (`amper serve`):
    /// the learner freezes its online params into the shared
    /// [`SnapshotSlot`](crate::coordinator::SnapshotSlot) every
    /// `snapshot_interval` steps, and the batched env actors pick the
    /// new epoch up on their next tick. Smaller = fresher actors, more
    /// parameter copies; must be ≥ 1.
    pub snapshot_interval: usize,
    /// Listen address for `amper replay-serve` (the standalone remote
    /// replay tier): `host:port` for TCP or `unix:/path` for a Unix
    /// socket.
    pub net_listen: String,
    /// Remote replay tier to connect to (`amper serve --connect`):
    /// empty = run the replay service in-process (the default
    /// single-process topology).
    pub net_connect: String,
    /// Role this process takes at the remote tier: "learner" (samples,
    /// trains, publishes snapshots) or "actor" (pushes experience,
    /// follows relayed snapshots).
    pub net_role: String,
    /// First reconnect backoff after a lost tier connection, in ms.
    /// Subsequent attempts double up to `net_reconnect_max_ms`.
    pub net_reconnect_ms: u64,
    /// Backoff cap for tier reconnect attempts, in ms.
    pub net_reconnect_max_ms: u64,
    /// Reconnect attempts before a request gives up and reports failure.
    pub net_reconnect_tries: u32,
    /// N-step returns (1 = standard one-step; Rainbow uses 3).
    pub nstep: usize,
    /// Test episodes for the final score (paper: 10).
    pub test_episodes: usize,
    /// Directory for artifacts (HLO text + manifest).
    pub artifacts_dir: String,
    /// Optional CSV output path for the learning curve.
    pub out_csv: Option<String>,
    /// Optional path: `amper serve` writes its final service report
    /// (counters, per-stage latency histograms, queue + pool state) as
    /// JSON here — the CI bench artifact and the operator's post-mortem.
    pub stats_json: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            env: "cartpole".into(),
            replay: ReplayKind::Per,
            er_size: 2000,
            steps: 20_000,
            batch: 64,
            target_sync: 500,
            warmup: 500,
            train_every: 1,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 5_000,
            seed: 0,
            replay_params: ReplayParams::default(),
            hw_replay: false,
            replay_shards: 1,
            push_batch: 1,
            push_batch_min: 0,
            push_batch_max: 0,
            reply_pool: 8,
            pipeline_depth: 2,
            engine_threads: 0,
            snapshot_interval: 16,
            net_listen: "127.0.0.1:7447".into(),
            net_connect: String::new(),
            net_role: "learner".into(),
            net_reconnect_ms: 50,
            net_reconnect_max_ms: 2000,
            net_reconnect_tries: 10,
            nstep: 1,
            test_episodes: 10,
            artifacts_dir: "artifacts".into(),
            out_csv: None,
            stats_json: None,
        }
    }
}

impl TrainConfig {
    /// Apply `key = value` overrides from a parsed config map or CLI
    /// `--set key=value` flags.
    pub fn apply(&mut self, map: &ConfigMap) -> Result<(), String> {
        for (k, v) in map.entries() {
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Set one field by key.
    pub fn set(&mut self, key: &str, val: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("invalid value '{v}' for key '{k}'");
        match key {
            "env" => self.env = val.to_string(),
            "replay" => {
                self.replay = ReplayKind::parse(val).ok_or_else(|| {
                    format!(
                        "invalid value '{val}' for key 'replay' (valid: {})",
                        ReplayKind::valid_names()
                    )
                })?
            }
            "er_size" => self.er_size = val.parse().map_err(|_| bad(key, val))?,
            "steps" => self.steps = val.parse().map_err(|_| bad(key, val))?,
            "batch" => self.batch = val.parse().map_err(|_| bad(key, val))?,
            "target_sync" => {
                self.target_sync = val.parse().map_err(|_| bad(key, val))?
            }
            "warmup" => self.warmup = val.parse().map_err(|_| bad(key, val))?,
            "train_every" => {
                self.train_every = val.parse().map_err(|_| bad(key, val))?
            }
            "eps_start" => self.eps_start = val.parse().map_err(|_| bad(key, val))?,
            "eps_end" => self.eps_end = val.parse().map_err(|_| bad(key, val))?,
            "eps_decay_steps" => {
                self.eps_decay_steps = val.parse().map_err(|_| bad(key, val))?
            }
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "hw_replay" => {
                self.hw_replay = val.parse().map_err(|_| bad(key, val))?
            }
            "replay_shards" => {
                self.replay_shards = val.parse().map_err(|_| bad(key, val))?;
                if self.replay_shards == 0
                    || self.replay_shards
                        > crate::replay::global_index::MAX_SHARDS
                {
                    return Err(bad(key, val));
                }
            }
            "push_batch" => {
                self.push_batch = val.parse().map_err(|_| bad(key, val))?;
                if self.push_batch == 0 {
                    return Err(bad(key, val));
                }
            }
            "push_batch_min" => {
                self.push_batch_min = val.parse().map_err(|_| bad(key, val))?
            }
            "push_batch_max" => {
                self.push_batch_max = val.parse().map_err(|_| bad(key, val))?
            }
            "reply_pool" => {
                self.reply_pool = val.parse().map_err(|_| bad(key, val))?
            }
            "pipeline_depth" => {
                self.pipeline_depth = val.parse().map_err(|_| bad(key, val))?;
                if self.pipeline_depth == 0 || self.pipeline_depth > 8 {
                    return Err(bad(key, val));
                }
            }
            "engine_threads" => {
                self.engine_threads = val.parse().map_err(|_| bad(key, val))?;
                // 0 = available_parallelism; a four-digit thread count is
                // a typo, not a machine
                if self.engine_threads > 1024 {
                    return Err(bad(key, val));
                }
            }
            "snapshot_interval" => {
                self.snapshot_interval = val.parse().map_err(|_| bad(key, val))?;
                if self.snapshot_interval == 0 {
                    return Err(bad(key, val));
                }
            }
            "net_listen" => {
                if val.is_empty() {
                    return Err(bad(key, val));
                }
                self.net_listen = val.to_string()
            }
            "net_connect" => self.net_connect = val.to_string(),
            "net_role" => {
                if val != "learner" && val != "actor" {
                    return Err(format!(
                        "invalid value '{val}' for key 'net_role' (valid: learner, actor)"
                    ));
                }
                self.net_role = val.to_string()
            }
            "net_reconnect_ms" => {
                self.net_reconnect_ms = val.parse().map_err(|_| bad(key, val))?;
                if self.net_reconnect_ms == 0 {
                    return Err(bad(key, val));
                }
            }
            "net_reconnect_max_ms" => {
                self.net_reconnect_max_ms =
                    val.parse().map_err(|_| bad(key, val))?;
                if self.net_reconnect_max_ms == 0 {
                    return Err(bad(key, val));
                }
            }
            "net_reconnect_tries" => {
                self.net_reconnect_tries =
                    val.parse().map_err(|_| bad(key, val))?
            }
            "nstep" => self.nstep = val.parse().map_err(|_| bad(key, val))?,
            "test_episodes" => {
                self.test_episodes = val.parse().map_err(|_| bad(key, val))?
            }
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "out_csv" => self.out_csv = Some(val.to_string()),
            "stats_json" => self.stats_json = Some(val.to_string()),
            _ => return self.set_replay_param(key, val),
        }
        Ok(())
    }

    /// Route a dotted technique-parameter key (`replay.per.alpha`, or the
    /// legacy bare `per.alpha` / `amper.m` spelling) to the owning
    /// technique's descriptor in the replay [`registry`]. Every key that
    /// is not a flat `TrainConfig` field lands here, so dynamically
    /// registered techniques get config parsing with no match-arm edits.
    fn set_replay_param(&mut self, key: &str, val: &str) -> Result<(), String> {
        let dotted = key.strip_prefix("replay.").unwrap_or(key);
        if let Some((ns, field)) = dotted.split_once('.') {
            if let Some(d) = registry::find_by_ns(ns) {
                return (d.set_param)(&mut self.replay_params, field, val);
            }
            return Err(format!(
                "unknown replay technique '{ns}' in key '{key}' (valid: {})",
                registry::valid_names()
            ));
        }
        Err(format!("unknown config key '{key}'"))
    }

    /// Resolve the actor flush policy for the replay services: a
    /// `push_batch_min`/`push_batch_max` bound of 0 inherits
    /// `push_batch`, so configs that never touch the new keys keep the
    /// fixed-flush behavior bit-exactly.
    pub fn flush_policy(&self) -> crate::coordinator::FlushPolicy {
        let min = if self.push_batch_min == 0 { self.push_batch } else { self.push_batch_min };
        let max = if self.push_batch_max == 0 { self.push_batch } else { self.push_batch_max };
        crate::coordinator::FlushPolicy::adaptive(min, max)
    }

    /// The `net_role` key as a wire [`Role`](crate::net::Role).
    pub fn net_role(&self) -> crate::net::Role {
        match self.net_role.as_str() {
            "actor" => crate::net::Role::Actor,
            _ => crate::net::Role::Learner,
        }
    }

    /// Remote-client options assembled from the `net_reconnect_*` and
    /// `reply_pool` keys.
    pub fn net_client_options(&self) -> crate::net::ClientOptions {
        use std::time::Duration;
        crate::net::ClientOptions {
            reconnect: crate::net::client::ReconnectPolicy {
                base: Duration::from_millis(self.net_reconnect_ms),
                max: Duration::from_millis(
                    self.net_reconnect_max_ms.max(self.net_reconnect_ms),
                ),
                tries: self.net_reconnect_tries,
            },
            reply_pool: self.reply_pool,
            ..crate::net::ClientOptions::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_known_keys() {
        let mut c = TrainConfig::default();
        c.set("env", "acrobot").unwrap();
        c.set("replay", "amper-fr").unwrap();
        c.set("er_size", "10000").unwrap();
        c.set("amper.m", "8").unwrap();
        c.set("per.alpha", "0.7").unwrap();
        assert_eq!(c.env, "acrobot");
        assert_eq!(c.replay, ReplayKind::AmperFr);
        assert_eq!(c.er_size, 10000);
        assert_eq!(c.replay_params.amper.m, 8);
        assert!((c.replay_params.per.alpha - 0.7).abs() < 1e-6);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = TrainConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("er_size", "abc").is_err());
    }

    #[test]
    fn replay_shards_bounds_enforced() {
        let mut c = TrainConfig::default();
        c.set("replay_shards", "8").unwrap();
        assert_eq!(c.replay_shards, 8);
        assert!(c.set("replay_shards", "0").is_err());
        assert!(c.set("replay_shards", "999999").is_err());
    }

    #[test]
    fn push_batch_bounds_enforced() {
        let mut c = TrainConfig::default();
        assert_eq!(c.push_batch, 1, "default must be scalar ingest");
        c.set("push_batch", "32").unwrap();
        assert_eq!(c.push_batch, 32);
        assert!(c.set("push_batch", "0").is_err());
        assert!(c.set("push_batch", "abc").is_err());
    }

    #[test]
    fn flush_policy_inherits_push_batch_when_bounds_unset() {
        let mut c = TrainConfig::default();
        c.set("push_batch", "32").unwrap();
        let p = c.flush_policy();
        assert_eq!((p.min(), p.max()), (32, 32), "0-bounds inherit push_batch");
        assert!(p.is_fixed());
        c.set("push_batch_min", "8").unwrap();
        c.set("push_batch_max", "128").unwrap();
        let p = c.flush_policy();
        assert_eq!((p.min(), p.max()), (8, 128));
        assert!(!p.is_fixed());
        // only one bound set: the other still inherits push_batch
        c.set("push_batch_max", "0").unwrap();
        let p = c.flush_policy();
        assert_eq!((p.min(), p.max()), (8, 32));
        assert!(c.set("push_batch_min", "abc").is_err());
    }

    #[test]
    fn stats_json_path_round_trips() {
        let mut c = TrainConfig::default();
        assert!(c.stats_json.is_none());
        c.set("stats_json", "out/stats.json").unwrap();
        assert_eq!(c.stats_json.as_deref(), Some("out/stats.json"));
    }

    #[test]
    fn reply_pool_and_pipeline_depth_bounds_enforced() {
        let mut c = TrainConfig::default();
        assert_eq!(c.pipeline_depth, 2, "default learner is double-buffered");
        assert_eq!(c.reply_pool, 8);
        c.set("pipeline_depth", "1").unwrap();
        assert_eq!(c.pipeline_depth, 1);
        assert!(c.set("pipeline_depth", "0").is_err());
        assert!(c.set("pipeline_depth", "9").is_err());
        c.set("reply_pool", "0").unwrap(); // 0 = pooling disabled, legal
        assert_eq!(c.reply_pool, 0);
        assert!(c.set("reply_pool", "x").is_err());
    }

    #[test]
    fn engine_threads_bounds_enforced() {
        let mut c = TrainConfig::default();
        assert_eq!(c.engine_threads, 0, "default must follow the machine");
        c.set("engine_threads", "4").unwrap();
        assert_eq!(c.engine_threads, 4);
        c.set("engine_threads", "0").unwrap(); // 0 = available_parallelism
        assert_eq!(c.engine_threads, 0);
        c.set("engine_threads", "1").unwrap(); // 1 = sequential
        assert_eq!(c.engine_threads, 1);
        assert!(c.set("engine_threads", "4096").is_err());
        assert!(c.set("engine_threads", "x").is_err());
    }

    #[test]
    fn snapshot_interval_bounds_enforced() {
        let mut c = TrainConfig::default();
        assert_eq!(c.snapshot_interval, 16);
        c.set("snapshot_interval", "4").unwrap();
        assert_eq!(c.snapshot_interval, 4);
        assert!(c.set("snapshot_interval", "0").is_err());
        assert!(c.set("snapshot_interval", "x").is_err());
    }

    #[test]
    fn net_keys_validate_and_round_trip() {
        let mut c = TrainConfig::default();
        assert_eq!(c.net_listen, "127.0.0.1:7447");
        assert!(c.net_connect.is_empty(), "default topology is in-process");
        c.set("net_listen", "unix:/tmp/amper.sock").unwrap();
        assert_eq!(c.net_listen, "unix:/tmp/amper.sock");
        assert!(c.set("net_listen", "").is_err());
        c.set("net_connect", "10.0.0.1:7447").unwrap();
        assert_eq!(c.net_connect, "10.0.0.1:7447");
        c.set("net_role", "actor").unwrap();
        assert_eq!(c.net_role(), crate::net::Role::Actor);
        c.set("net_role", "learner").unwrap();
        assert_eq!(c.net_role(), crate::net::Role::Learner);
        let err = c.set("net_role", "observer").unwrap_err();
        assert!(err.contains("learner") && err.contains("actor"));
    }

    #[test]
    fn net_reconnect_knobs_feed_client_options() {
        use std::time::Duration;
        let mut c = TrainConfig::default();
        c.set("net_reconnect_ms", "25").unwrap();
        c.set("net_reconnect_max_ms", "400").unwrap();
        c.set("net_reconnect_tries", "3").unwrap();
        c.set("reply_pool", "4").unwrap();
        let o = c.net_client_options();
        assert_eq!(o.reconnect.base, Duration::from_millis(25));
        assert_eq!(o.reconnect.max, Duration::from_millis(400));
        assert_eq!(o.reconnect.tries, 3);
        assert_eq!(o.reply_pool, 4);
        assert!(c.set("net_reconnect_ms", "0").is_err());
        assert!(c.set("net_reconnect_max_ms", "0").is_err());
        // a cap below the base is clamped up to the base
        c.set("net_reconnect_max_ms", "10").unwrap();
        assert_eq!(c.net_client_options().reconnect.max, Duration::from_millis(25));
    }

    #[test]
    fn replay_accepts_any_case_and_lists_names_on_error() {
        let mut c = TrainConfig::default();
        c.set("replay", "PER").unwrap();
        assert_eq!(c.replay, ReplayKind::Per);
        let err = c.set("replay", "bogus").unwrap_err();
        assert!(
            err.contains("uniform") && err.contains("amper-fr"),
            "error must list valid names: {err}"
        );
    }

    #[test]
    fn apply_from_parsed_file() {
        let map = ConfigMap::parse(
            "# comment\nenv = \"lunarlander\"\n[amper]\nm = 12\nlambda = 0.25\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply(&map).unwrap();
        assert_eq!(c.env, "lunarlander");
        assert_eq!(c.replay_params.amper.m, 12);
        assert!((c.replay_params.amper.lambda - 0.25).abs() < 1e-6);
    }

    #[test]
    fn replay_namespace_routes_every_registered_technique() {
        let mut c = TrainConfig::default();
        c.set("replay.per.alpha", "0.8").unwrap();
        c.set("replay.per.beta0", "0.5").unwrap();
        c.set("replay.amper.m", "16").unwrap();
        c.set("replay.dpsr.recycle_frac", "0.25").unwrap();
        c.set("replay.dpsr.decay", "0.5").unwrap();
        c.set("replay.dual.lt_frac", "0.4").unwrap();
        c.set("replay.pper.div_floor", "0.05").unwrap();
        assert!((c.replay_params.per.alpha - 0.8).abs() < 1e-6);
        assert!((c.replay_params.per.beta0 - 0.5).abs() < 1e-6);
        assert_eq!(c.replay_params.amper.m, 16);
        assert!((c.replay_params.dpsr.recycle_frac - 0.25).abs() < 1e-6);
        assert!((c.replay_params.dpsr.decay - 0.5).abs() < 1e-6);
        assert!((c.replay_params.dual.lt_frac - 0.4).abs() < 1e-6);
        assert!((c.replay_params.pper.div_floor - 0.05).abs() < 1e-6);
    }

    #[test]
    fn replay_namespace_defaults_round_trip() {
        // writing every default back through the namespace must be a
        // no-op: the parsed values land on the same defaults
        let d = ReplayParams::default();
        let mut c = TrainConfig::default();
        c.set("replay.per.alpha", &d.per.alpha.to_string()).unwrap();
        c.set("replay.per.beta0", &d.per.beta0.to_string()).unwrap();
        c.set("replay.per.beta_steps", &d.per.beta_steps.to_string()).unwrap();
        c.set("replay.per.eps", &d.per.eps.to_string()).unwrap();
        c.set("replay.amper.m", &d.amper.m.to_string()).unwrap();
        c.set("replay.amper.lambda", &d.amper.lambda.to_string()).unwrap();
        c.set("replay.amper.lambda_prime", &d.amper.lambda_prime.to_string())
            .unwrap();
        c.set("replay.amper.eps", &d.amper.eps.to_string()).unwrap();
        c.set("replay.amper.alpha", &d.amper.alpha.to_string()).unwrap();
        c.set("replay.amper.csp_cap", &d.amper.csp_cap.to_string()).unwrap();
        c.set("replay.dpsr.alpha", &d.dpsr.alpha.to_string()).unwrap();
        c.set("replay.dpsr.eps", &d.dpsr.eps.to_string()).unwrap();
        c.set("replay.dpsr.decay", &d.dpsr.decay.to_string()).unwrap();
        c.set("replay.dpsr.recycle_frac", &d.dpsr.recycle_frac.to_string())
            .unwrap();
        c.set(
            "replay.dpsr.recycle_candidates",
            &d.dpsr.recycle_candidates.to_string(),
        )
        .unwrap();
        c.set("replay.dual.st_frac", &d.dual.st_frac.to_string()).unwrap();
        c.set("replay.dual.lt_frac", &d.dual.lt_frac.to_string()).unwrap();
        c.set("replay.dual.promote_margin", &d.dual.promote_margin.to_string())
            .unwrap();
        c.set("replay.pper.alpha", &d.pper.alpha.to_string()).unwrap();
        c.set("replay.pper.eps", &d.pper.eps.to_string()).unwrap();
        c.set("replay.pper.ema_decay", &d.pper.ema_decay.to_string()).unwrap();
        c.set("replay.pper.div_floor", &d.pper.div_floor.to_string()).unwrap();
        let round_tripped = format!("{:?}", c.replay_params);
        assert_eq!(round_tripped, format!("{:?}", ReplayParams::default()));
    }

    #[test]
    fn unknown_replay_field_errors_name_accepted_fields() {
        let mut c = TrainConfig::default();
        let err = c.set("replay.dpsr.nope", "1").unwrap_err();
        assert!(
            err.contains("dpsr") && err.contains("recycle_frac"),
            "error must name the accepted fields: {err}"
        );
        let err = c.set("replay.per.gamma", "0.9").unwrap_err();
        assert!(err.contains("alpha") && err.contains("beta0"), "{err}");
        let err = c.set("replay.uniform.alpha", "0.9").unwrap_err();
        assert!(err.contains("no parameters"), "{err}");
        let err = c.set("replay.bogus.alpha", "0.9").unwrap_err();
        assert!(
            err.contains("unknown replay technique") && err.contains("dpsr"),
            "error must list valid techniques: {err}"
        );
    }

    #[test]
    fn replay_sections_parse_from_config_files() {
        let map = ConfigMap::parse(
            "replay = \"dpsr\"\n[replay.dpsr]\nrecycle_frac = 0.2\n\
             [replay.dual]\nst_frac = 0.6\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply(&map).unwrap();
        assert_eq!(c.replay, ReplayKind::Dpsr);
        assert!((c.replay_params.dpsr.recycle_frac - 0.2).abs() < 1e-6);
        assert!((c.replay_params.dual.st_frac - 0.6).abs() < 1e-6);
    }
}
