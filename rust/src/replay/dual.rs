//! Dual experience replay — a short-term/long-term memory split
//! (arXiv:1907.06396).
//!
//! One [`ExperienceRing`] is partitioned into a **short-term** region
//! (the first `st_cap` slots, a plain FIFO every transition enters) and
//! a **long-term** region (the remaining slots). When an episode ends,
//! its return is compared against the running mean of all finished
//! episodes: episodes that beat the mean (plus `promote_margin`) are
//! *promoted* — their transitions are copied into the long-term FIFO,
//! where only other promoted episodes can overwrite them. Sampling mixes
//! the two regions: each draw reads long-term with probability `lt_frac`
//! (when it is non-empty), short-term otherwise, so rare good episodes
//! keep getting replayed long after the short-term FIFO has evicted them.
//!
//! Priorities are uniform within each region — the technique's leverage
//! is *retention*, not per-transition weighting — so `update_priorities`
//! is a no-op and all importance weights are 1.

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;
use std::collections::VecDeque;

/// Dual-memory hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct DualParams {
    /// Fraction of capacity given to the short-term region (0, 1).
    pub st_frac: f32,
    /// Per-draw probability of sampling the long-term region once it
    /// holds promoted transitions.
    pub lt_frac: f32,
    /// Episode return must exceed the running mean by this margin to be
    /// promoted.
    pub promote_margin: f32,
}

impl Default for DualParams {
    fn default() -> Self {
        DualParams { st_frac: 0.5, lt_frac: 0.3, promote_margin: 0.0 }
    }
}

/// Short-term/long-term dual replay memory.
#[derive(Debug)]
pub struct DualReplay {
    ring: ExperienceRing,
    params: DualParams,
    /// Slots `0..st_cap` are short-term, `st_cap..capacity` long-term.
    st_cap: usize,
    lt_cap: usize,
    st_head: usize,
    st_len: usize,
    lt_head: usize,
    lt_len: usize,
    /// Short-term slots of the episode currently being recorded (in push
    /// order). Slots evicted by the short-term wrap are dropped from the
    /// front — an episode longer than the short-term region promotes only
    /// its surviving tail.
    ep_slots: VecDeque<usize>,
    /// Return accumulated by the in-flight episode.
    ep_return: f64,
    /// Running mean return over finished episodes.
    ret_mean: f64,
    ret_count: u64,
}

impl DualReplay {
    pub fn new(capacity: usize, params: DualParams) -> Self {
        assert!(
            params.st_frac > 0.0 && params.st_frac < 1.0,
            "st_frac must be in (0, 1)"
        );
        // both regions get at least one slot whenever capacity allows
        let st_cap = ((capacity as f64 * params.st_frac as f64) as usize)
            .clamp(1, capacity.saturating_sub(1).max(1));
        let lt_cap = capacity - st_cap.min(capacity);
        DualReplay {
            ring: ExperienceRing::new(capacity, 4),
            params,
            st_cap,
            lt_cap,
            st_head: 0,
            st_len: 0,
            lt_head: 0,
            lt_len: 0,
            ep_slots: VecDeque::new(),
            ep_return: 0.0,
            ret_mean: 0.0,
            ret_count: 0,
        }
    }

    /// Transitions currently in the short-term region.
    pub fn st_len(&self) -> usize {
        self.st_len
    }

    /// Promoted transitions currently in the long-term region.
    pub fn lt_len(&self) -> usize {
        self.lt_len
    }

    /// Running mean episode return (promotion threshold base).
    pub fn mean_return(&self) -> f64 {
        self.ret_mean
    }

    /// Write one transition into the short-term FIFO and run the
    /// episode-boundary promotion logic. Shared verbatim by the scalar
    /// and batched push paths (state-identical by construction).
    fn place_row(
        &mut self,
        obs: &[f32],
        action: u32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) -> usize {
        let idx = self.st_head;
        self.ring.write_at_parts(idx, obs, action, reward, next_obs, done);
        self.st_head = (self.st_head + 1) % self.st_cap;
        self.st_len = (self.st_len + 1).min(self.st_cap);
        self.ep_slots.push_back(idx);
        // slots older than one short-term lap were overwritten and no
        // longer belong to this episode
        while self.ep_slots.len() > self.st_cap {
            self.ep_slots.pop_front();
        }
        self.ep_return += reward as f64;
        if done {
            self.finish_episode();
        }
        idx
    }

    /// Episode boundary: maybe promote, then fold the return into the
    /// running mean. The first episode always promotes (there is no mean
    /// to compare against yet).
    fn finish_episode(&mut self) {
        let promote = self.ret_count == 0
            || self.ep_return
                >= self.ret_mean + self.params.promote_margin as f64;
        if promote && self.lt_cap > 0 {
            for i in 0..self.ep_slots.len() {
                let src = self.ep_slots[i];
                let dst = self.st_cap + self.lt_head;
                self.ring.copy_slot(src, dst);
                self.lt_head = (self.lt_head + 1) % self.lt_cap;
                self.lt_len = (self.lt_len + 1).min(self.lt_cap);
            }
        }
        self.ret_count += 1;
        self.ret_mean +=
            (self.ep_return - self.ret_mean) / self.ret_count as f64;
        self.ep_return = 0.0;
        self.ep_slots.clear();
    }
}

impl ReplayMemory for DualReplay {
    fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        self.place_row(&e.obs, e.action, e.reward, &e.next_obs, e.done)
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        _rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        // placement depends on per-row episode state (done flags trigger
        // promotion copies), so rows place one by one through the same
        // routine as the scalar path — but on borrowed row views, with no
        // per-row Experience allocation
        for row in 0..batch.len() {
            let r = batch.get(row);
            slots.push(self.place_row(r.obs, r.action, r.reward, r.next_obs, r.done));
        }
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let (n_st, n_lt) = (self.st_len, self.lt_len);
        assert!(n_st + n_lt > 0, "cannot sample an empty memory");
        out.indices.clear();
        for _ in 0..batch {
            // short-circuit keeps the rng stream identical whether or not
            // the long-term region exists yet
            let use_lt = n_lt > 0 && rng.chance(self.params.lt_frac as f64);
            let idx = if use_lt {
                self.st_cap + rng.below(n_lt)
            } else {
                // n_lt > 0 implies n_st > 0 (promotion only happens after
                // short-term pushes), so this never divides by zero
                rng.below(n_st)
            };
            out.indices.push(idx);
        }
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    fn update_priorities(&mut self, _indices: &[usize], _td_errors: &[f32]) {
        // retention-based technique: no per-transition priorities
    }

    fn len(&self) -> usize {
        // the ring's high-water mark: every sampled index is below it, and
        // slots in the gap between the regions are never handed out
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::Dual
    }

    fn priority_of(&self, _idx: usize) -> f32 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32, reward: f32, done: bool) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward,
            next_obs: vec![v; 4],
            done,
        }
    }

    /// Push one `len`-step episode with total return `ret`.
    fn push_episode(mem: &mut DualReplay, rng: &mut Rng, tag: f32, len: usize, ret: f32) {
        for i in 0..len {
            let r = if i == len - 1 { ret } else { 0.0 };
            mem.push(exp(tag, r, i == len - 1), rng);
        }
    }

    #[test]
    fn first_episode_promotes_and_seeds_the_mean() {
        let mut rng = Rng::new(0);
        let mut mem = DualReplay::new(20, DualParams::default());
        push_episode(&mut mem, &mut rng, 1.0, 4, 2.0);
        assert_eq!(mem.st_len(), 4);
        assert_eq!(mem.lt_len(), 4);
        assert!((mem.mean_return() - 2.0).abs() < 1e-9);
        // the promoted copies live past st_cap and hold the episode data
        assert_eq!(mem.ring().obs_of(10), &[1.0; 4]);
    }

    #[test]
    fn below_mean_episodes_are_not_promoted() {
        let mut rng = Rng::new(1);
        let mut mem = DualReplay::new(20, DualParams::default());
        push_episode(&mut mem, &mut rng, 1.0, 3, 10.0); // mean -> 10
        let lt_after_first = mem.lt_len();
        push_episode(&mut mem, &mut rng, 2.0, 3, 1.0); // below mean
        assert_eq!(mem.lt_len(), lt_after_first);
        push_episode(&mut mem, &mut rng, 3.0, 3, 50.0); // above mean
        assert_eq!(mem.lt_len(), lt_after_first + 3);
        // mean tracked all three episodes
        assert!((mem.mean_return() - (10.0 + 1.0 + 50.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn long_term_survives_short_term_wrap() {
        let mut rng = Rng::new(2);
        let mut mem = DualReplay::new(10, DualParams::default()); // st 5, lt 5
        push_episode(&mut mem, &mut rng, 7.0, 2, 5.0); // promoted
        // flood the short-term region with below-mean episodes
        for k in 0..6 {
            push_episode(&mut mem, &mut rng, 20.0 + k as f32, 2, 0.0);
        }
        assert_eq!(mem.lt_len(), 2);
        // the promoted transitions are intact in the long-term region
        assert_eq!(mem.ring().obs_of(5), &[7.0; 4]);
        assert_eq!(mem.ring().obs_of(6), &[7.0; 4]);
        // ...while the short-term copies were overwritten
        for i in 0..5 {
            assert_ne!(mem.ring().obs_of(i), &[7.0; 4]);
        }
    }

    #[test]
    fn sampling_mixes_both_regions() {
        let mut rng = Rng::new(3);
        let mut mem = DualReplay::new(
            40,
            DualParams { lt_frac: 0.5, ..Default::default() },
        );
        push_episode(&mut mem, &mut rng, 1.0, 10, 3.0); // promoted
        push_episode(&mut mem, &mut rng, 2.0, 10, 0.0); // not promoted
        let st_cap = 20;
        let (mut st, mut lt) = (0usize, 0usize);
        for _ in 0..200 {
            for &idx in &mem.sample(8, &mut rng).indices {
                assert!(idx < mem.len());
                if idx < st_cap {
                    st += 1;
                } else {
                    lt += 1;
                }
            }
        }
        let frac = lt as f64 / (st + lt) as f64;
        assert!((frac - 0.5).abs() < 0.05, "lt fraction {frac}");
    }

    #[test]
    fn empty_long_term_consumes_no_extra_rng() {
        // before any episode finishes, sampling must draw short-term only
        // and skip the lt coin flip (short-circuit)
        let mut rng = Rng::new(4);
        let mut mem = DualReplay::new(16, DualParams::default());
        for i in 0..5 {
            mem.push(exp(i as f32, 0.0, false), &mut rng);
        }
        let b = mem.sample(64, &mut rng);
        assert!(b.indices.iter().all(|&i| i < 5));
    }

    #[test]
    fn episode_longer_than_short_term_promotes_surviving_tail() {
        let mut rng = Rng::new(5);
        let mut mem = DualReplay::new(10, DualParams::default()); // st 5, lt 5
        push_episode(&mut mem, &mut rng, 1.0, 8, 4.0);
        // only the st_cap most recent transitions survive to promote
        assert_eq!(mem.lt_len(), 5);
        assert_eq!(mem.st_len(), 5);
    }
}
