//! Experience storage: a fixed-capacity ring of transitions with
//! flat, cache-friendly observation storage, plus the owned flat
//! [`ExperienceBatch`] that moves transitions through the stack in
//! batch-first form.

use crate::ensure;
use crate::util::error::Result;

/// One state transition `(s, a, r, s', done)` (paper Fig 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Experience {
    pub obs: Vec<f32>,
    pub action: u32,
    pub reward: f32,
    pub next_obs: Vec<f32>,
    pub done: bool,
}

/// A borrowed view of one row of an [`ExperienceBatch`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExperienceRef<'a> {
    pub obs: &'a [f32],
    pub action: u32,
    pub reward: f32,
    pub next_obs: &'a [f32],
    pub done: bool,
}

impl ExperienceRef<'_> {
    /// Clone the row into an owned [`Experience`] (scalar-fallback paths).
    pub fn to_experience(&self) -> Experience {
        Experience {
            obs: self.obs.to_vec(),
            action: self.action,
            reward: self.reward,
            next_obs: self.next_obs.to_vec(),
            done: self.done,
        }
    }
}

/// An owned batch of transitions in structure-of-arrays layout: `obs` and
/// `next_obs` are one flat `Vec<f32>` each (`len * obs_dim`), the scalar
/// columns one `Vec` each. This is the native unit of the replay data
/// path (paper §4: one wide parallel search per batch, not one tree walk
/// per element): actors accumulate into it, services route it, rings copy
/// it in with chunked `memcpy`s instead of per-row `Vec` allocations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExperienceBatch {
    obs_dim: usize,
    obs: Vec<f32>,
    next_obs: Vec<f32>,
    actions: Vec<u32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
}

impl ExperienceBatch {
    /// Empty batch for `obs_dim`-dimensional observations.
    pub fn new(obs_dim: usize) -> Self {
        Self::with_capacity(obs_dim, 0)
    }

    /// Empty batch with room for `rows` transitions.
    pub fn with_capacity(obs_dim: usize, rows: usize) -> Self {
        ExperienceBatch {
            obs_dim,
            obs: Vec::with_capacity(rows * obs_dim),
            next_obs: Vec::with_capacity(rows * obs_dim),
            actions: Vec::with_capacity(rows),
            rewards: Vec::with_capacity(rows),
            dones: Vec::with_capacity(rows),
        }
    }

    /// Build a batch from a slice of owned experiences (tests, adapters).
    pub fn from_experiences(exps: &[Experience]) -> Self {
        let obs_dim = exps.first().map_or(0, |e| e.obs.len());
        let mut b = Self::with_capacity(obs_dim, exps.len());
        for e in exps {
            b.push(e);
        }
        b
    }

    /// One-row batch taking ownership of the experience's buffers: the
    /// obs/next_obs `Vec`s become the SoA columns directly, so the scalar
    /// service-push convenience pays no float copies.
    pub fn from_experience(e: Experience) -> Self {
        let obs_dim = e.obs.len();
        assert_eq!(e.next_obs.len(), obs_dim, "obs dim mismatch");
        ExperienceBatch {
            obs_dim,
            obs: e.obs,
            next_obs: e.next_obs,
            actions: vec![e.action],
            rewards: vec![e.reward],
            dones: vec![e.done],
        }
    }

    /// Reassemble a batch from owned SoA columns (the wire-decode path:
    /// the frame payload is exactly these five runs). Validates the
    /// cross-column shape so a corrupt frame surfaces as an `Err` at the
    /// decode boundary instead of a panic deep in the ring.
    pub fn from_columns(
        obs_dim: usize,
        obs: Vec<f32>,
        next_obs: Vec<f32>,
        actions: Vec<u32>,
        rewards: Vec<f32>,
        dones: Vec<bool>,
    ) -> Result<Self> {
        let rows = actions.len();
        ensure!(
            obs.len() == rows * obs_dim && next_obs.len() == rows * obs_dim,
            "obs columns hold {}/{} floats, want {} rows x {} dims",
            obs.len(),
            next_obs.len(),
            rows,
            obs_dim
        );
        ensure!(
            rewards.len() == rows && dones.len() == rows,
            "scalar columns disagree: {rows} actions, {} rewards, {} dones",
            rewards.len(),
            dones.len()
        );
        Ok(ExperienceBatch { obs_dim, obs, next_obs, actions, rewards, dones })
    }

    /// Append one transition (builder-style ingest).
    pub fn push(&mut self, e: &Experience) {
        self.push_parts(&e.obs, e.action, e.reward, &e.next_obs, e.done);
    }

    /// Append one transition from its parts without an intermediate
    /// [`Experience`] (the actor hot path: no per-step heap allocation).
    pub fn push_parts(
        &mut self,
        obs: &[f32],
        action: u32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) {
        assert_eq!(obs.len(), self.obs_dim, "obs dim mismatch");
        assert_eq!(next_obs.len(), self.obs_dim);
        self.obs.extend_from_slice(obs);
        self.next_obs.extend_from_slice(next_obs);
        self.actions.push(action);
        self.rewards.push(reward);
        self.dones.push(done);
    }

    /// Append row `row` of another batch (the sharded router's one-pass
    /// split).
    pub fn push_row(&mut self, src: &ExperienceBatch, row: usize) {
        self.push_parts(
            src.obs_of(row),
            src.actions[row],
            src.rewards[row],
            src.next_obs_of(row),
            src.dones[row],
        );
    }

    /// Number of transitions held.
    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    /// Drop all rows, keeping the allocations (actor reuse across flushes).
    pub fn clear(&mut self) {
        self.obs.clear();
        self.next_obs.clear();
        self.actions.clear();
        self.rewards.clear();
        self.dones.clear();
    }

    /// Observation slice of row `row`.
    #[inline]
    pub fn obs_of(&self, row: usize) -> &[f32] {
        let o = row * self.obs_dim;
        &self.obs[o..o + self.obs_dim]
    }

    /// Next-observation slice of row `row`.
    #[inline]
    pub fn next_obs_of(&self, row: usize) -> &[f32] {
        let o = row * self.obs_dim;
        &self.next_obs[o..o + self.obs_dim]
    }

    /// Borrowed view of row `row`.
    #[inline]
    pub fn get(&self, row: usize) -> ExperienceRef<'_> {
        ExperienceRef {
            obs: self.obs_of(row),
            action: self.actions[row],
            reward: self.rewards[row],
            next_obs: self.next_obs_of(row),
            done: self.dones[row],
        }
    }

    /// Iterate over the rows as borrowed views.
    pub fn iter(&self) -> impl Iterator<Item = ExperienceRef<'_>> {
        (0..self.len()).map(move |row| self.get(row))
    }

    /// Flat observation column (`len * obs_dim`).
    pub fn obs_flat(&self) -> &[f32] {
        &self.obs
    }

    /// Flat next-observation column (`len * obs_dim`).
    pub fn next_obs_flat(&self) -> &[f32] {
        &self.next_obs
    }

    pub fn actions(&self) -> &[u32] {
        &self.actions
    }

    pub fn rewards(&self) -> &[f32] {
        &self.rewards
    }

    pub fn dones(&self) -> &[bool] {
        &self.dones
    }
}

/// A fully gathered batch (flat host buffers, ready for the engine).
///
/// This is the *reply* unit of the replay services: a worker gathers a
/// sampled batch straight into these columns and the learner trains on
/// them via a borrowed view without any repack. The buffer is designed
/// for **reuse**: [`GatheredBatch::reset`] resizes every column to the
/// exact reply shape while keeping the underlying allocations, so a
/// buffer recycled through a reply pool crosses the service with zero
/// fresh allocations on the steady-state path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GatheredBatch {
    pub indices: Vec<usize>,
    pub is_weights: Vec<f32>,
    pub obs: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub dones: Vec<f32>,
}

impl GatheredBatch {
    /// Number of gathered transitions.
    pub fn rows(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Observation dimensionality of the gathered columns (0 when empty).
    pub fn obs_dim(&self) -> usize {
        if self.indices.is_empty() {
            0
        } else {
            self.obs.len() / self.indices.len()
        }
    }

    /// Resize every column for `rows` transitions of `obs_dim`-dim
    /// observations. Keeps the existing allocations when they are large
    /// enough — the recycled-buffer hot path allocates nothing — and
    /// only zero-fills *growth* (no redundant memset of bytes the fill
    /// pass overwrites anyway). Retained elements keep their stale
    /// values: every filler (worker gather, sharded offset merge) fully
    /// overwrites the rows it keeps, which is what makes a refilled
    /// buffer bit-identical to a freshly allocated one.
    pub fn reset(&mut self, rows: usize, obs_dim: usize) {
        self.indices.resize(rows, 0);
        self.is_weights.resize(rows, 0.0);
        self.obs.resize(rows * obs_dim, 0.0);
        self.actions.resize(rows, 0);
        self.rewards.resize(rows, 0.0);
        self.next_obs.resize(rows * obs_dim, 0.0);
        self.dones.resize(rows, 0.0);
    }

    /// Shrink every column to the first `rows` transitions (capacity
    /// kept) — the sharded merge pre-sizes for the full request and
    /// truncates to what the warm shards actually served.
    pub fn truncate(&mut self, rows: usize, obs_dim: usize) {
        self.indices.truncate(rows);
        self.is_weights.truncate(rows);
        self.obs.truncate(rows * obs_dim);
        self.actions.truncate(rows);
        self.rewards.truncate(rows);
        self.next_obs.truncate(rows * obs_dim);
        self.dones.truncate(rows);
    }
}

/// Ring buffer of experiences with contiguous obs storage.
///
/// Observations for all slots live in two flat `Vec<f32>`s (`obs`,
/// `next_obs`), so batch gathering writes straight into the literal
/// buffers without per-experience pointer chasing. When full, the oldest
/// entry is overwritten (paper §4.1.2: "If the ER memory is full, it
/// discards the oldest experience").
#[derive(Debug, Clone)]
pub struct ExperienceRing {
    capacity: usize,
    obs_dim: usize,
    obs: Vec<f32>,
    next_obs: Vec<f32>,
    actions: Vec<u32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    len: usize,
    head: usize,
}

impl ExperienceRing {
    /// Create a ring for `capacity` transitions of `obs_dim`-dim states.
    pub fn new(capacity: usize, obs_dim: usize) -> Self {
        assert!(capacity > 0);
        ExperienceRing {
            capacity,
            obs_dim,
            obs: vec![0.0; capacity * obs_dim],
            next_obs: vec![0.0; capacity * obs_dim],
            actions: vec![0; capacity],
            rewards: vec![0.0; capacity],
            dones: vec![false; capacity],
            len: 0,
            head: 0,
        }
    }

    /// Lazily (re)size for the first pushed experience when `obs_dim` was
    /// unknown at construction (capacity preserved).
    pub fn ensure_dim(&mut self, obs_dim: usize) {
        if self.obs_dim != obs_dim {
            assert_eq!(self.len, 0, "cannot change obs_dim of non-empty ring");
            self.obs_dim = obs_dim;
            self.obs = vec![0.0; self.capacity * obs_dim];
            self.next_obs = vec![0.0; self.capacity * obs_dim];
        }
    }

    /// Insert, returning the slot index written (== evicted slot if full).
    pub fn push(&mut self, e: &Experience) -> usize {
        self.push_parts(&e.obs, e.action, e.reward, &e.next_obs, e.done)
    }

    /// Insert from parts (borrowed row views: no intermediate
    /// [`Experience`] allocation on the batched push paths).
    pub fn push_parts(
        &mut self,
        obs: &[f32],
        action: u32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) -> usize {
        let idx = self.head;
        self.write_at_parts(idx, obs, action, reward, next_obs, done);
        self.head = (self.head + 1) % self.capacity;
        idx
    }

    /// Overwrite slot `idx` in place **without** moving the FIFO head
    /// (DPSR state recycling: a low-priority victim is replaced while the
    /// ring order of everything else is untouched). Slots at or past the
    /// current length count as written afterwards.
    pub fn write_at(&mut self, idx: usize, e: &Experience) {
        self.write_at_parts(idx, &e.obs, e.action, e.reward, &e.next_obs, e.done);
    }

    /// Part-wise form of [`Self::write_at`].
    pub fn write_at_parts(
        &mut self,
        idx: usize,
        obs: &[f32],
        action: u32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
    ) {
        assert!(idx < self.capacity, "slot {idx} out of capacity");
        assert_eq!(obs.len(), self.obs_dim, "obs dim mismatch");
        assert_eq!(next_obs.len(), self.obs_dim);
        let o = idx * self.obs_dim;
        self.obs[o..o + self.obs_dim].copy_from_slice(obs);
        self.next_obs[o..o + self.obs_dim].copy_from_slice(next_obs);
        self.actions[idx] = action;
        self.rewards[idx] = reward;
        self.dones[idx] = done;
        self.len = self.len.max(idx + 1);
    }

    /// Copy slot `src` over slot `dst` (dual-memory promotion: an episode
    /// is replicated from the short-term region into the long-term one).
    pub fn copy_slot(&mut self, src: usize, dst: usize) {
        assert!(src < self.capacity && dst < self.capacity);
        if src == dst {
            self.len = self.len.max(dst + 1);
            return;
        }
        let d = self.obs_dim;
        self.obs.copy_within(src * d..(src + 1) * d, dst * d);
        self.next_obs.copy_within(src * d..(src + 1) * d, dst * d);
        self.actions[dst] = self.actions[src];
        self.rewards[dst] = self.rewards[src];
        self.dones[dst] = self.dones[src];
        self.len = self.len.max(dst + 1);
    }

    /// Insert a whole batch, appending the written slot indices (in push
    /// order) to `slots`. State-identical to pushing each row in order,
    /// but the SoA columns copy in chunked `memcpy`s — at most one split
    /// per capacity wrap — instead of five writes per row.
    pub fn push_batch(&mut self, b: &ExperienceBatch, slots: &mut Vec<usize>) {
        let k = b.len();
        if k == 0 {
            return;
        }
        assert_eq!(b.obs_dim(), self.obs_dim, "obs dim mismatch");
        let d = self.obs_dim;
        let mut row = 0;
        while row < k {
            let chunk = (self.capacity - self.head).min(k - row);
            let dst = self.head * d;
            let src = row * d;
            self.obs[dst..dst + chunk * d]
                .copy_from_slice(&b.obs_flat()[src..src + chunk * d]);
            self.next_obs[dst..dst + chunk * d]
                .copy_from_slice(&b.next_obs_flat()[src..src + chunk * d]);
            self.actions[self.head..self.head + chunk]
                .copy_from_slice(&b.actions()[row..row + chunk]);
            self.rewards[self.head..self.head + chunk]
                .copy_from_slice(&b.rewards()[row..row + chunk]);
            self.dones[self.head..self.head + chunk]
                .copy_from_slice(&b.dones()[row..row + chunk]);
            slots.extend(self.head..self.head + chunk);
            self.head = (self.head + chunk) % self.capacity;
            self.len = (self.len + chunk).min(self.capacity);
            row += chunk;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    /// Observation slice of slot `idx`.
    #[inline]
    pub fn obs_of(&self, idx: usize) -> &[f32] {
        let o = idx * self.obs_dim;
        &self.obs[o..o + self.obs_dim]
    }

    /// Next-observation slice of slot `idx`.
    #[inline]
    pub fn next_obs_of(&self, idx: usize) -> &[f32] {
        let o = idx * self.obs_dim;
        &self.next_obs[o..o + self.obs_dim]
    }

    #[inline]
    pub fn action_of(&self, idx: usize) -> u32 {
        self.actions[idx]
    }

    #[inline]
    pub fn reward_of(&self, idx: usize) -> f32 {
        self.rewards[idx]
    }

    #[inline]
    pub fn done_of(&self, idx: usize) -> bool {
        self.dones[idx]
    }

    /// Gather a batch into flat buffers (one memcpy per row) — the literal
    /// staging used by the runtime hot path.
    ///
    /// Every index is validated against `len` in release builds too: a
    /// corrupt index must surface as a proper error at the service
    /// boundary, not silently read stale slot data.
    pub fn gather(
        &self,
        indices: &[usize],
        obs_out: &mut [f32],
        act_out: &mut [i32],
        rew_out: &mut [f32],
        next_obs_out: &mut [f32],
        done_out: &mut [f32],
    ) -> Result<()> {
        let d = self.obs_dim;
        assert_eq!(obs_out.len(), indices.len() * d);
        for (row, &idx) in indices.iter().enumerate() {
            ensure!(
                idx < self.len,
                "replay index {idx} out of range (ring holds {} transitions)",
                self.len
            );
            obs_out[row * d..(row + 1) * d].copy_from_slice(self.obs_of(idx));
            next_obs_out[row * d..(row + 1) * d]
                .copy_from_slice(self.next_obs_of(idx));
            act_out[row] = self.actions[idx] as i32;
            rew_out[row] = self.rewards[idx];
            done_out[row] = self.dones[idx] as u8 as f32;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32, done: bool) -> Experience {
        Experience {
            obs: vec![v, v + 0.5],
            action: v as u32,
            reward: v * 2.0,
            next_obs: vec![v + 1.0, v + 1.5],
            done,
        }
    }

    #[test]
    fn push_and_read_back() {
        let mut ring = ExperienceRing::new(4, 2);
        let idx = ring.push(&exp(1.0, false));
        assert_eq!(idx, 0);
        assert_eq!(ring.obs_of(0), &[1.0, 1.5]);
        assert_eq!(ring.next_obs_of(0), &[2.0, 2.5]);
        assert_eq!(ring.action_of(0), 1);
        assert_eq!(ring.reward_of(0), 2.0);
        assert!(!ring.done_of(0));
        assert_eq!(ring.len(), 1);
    }

    #[test]
    fn wraps_and_evicts_oldest() {
        let mut ring = ExperienceRing::new(3, 2);
        for i in 0..5 {
            let idx = ring.push(&exp(i as f32, false));
            assert_eq!(idx, i % 3);
        }
        assert_eq!(ring.len(), 3);
        // slot 0 now holds experience 3, slot 1 holds 4, slot 2 holds 2
        assert_eq!(ring.obs_of(0), &[3.0, 3.5]);
        assert_eq!(ring.obs_of(1), &[4.0, 4.5]);
        assert_eq!(ring.obs_of(2), &[2.0, 2.5]);
    }

    #[test]
    fn gather_batches() {
        let mut ring = ExperienceRing::new(8, 2);
        for i in 0..8 {
            ring.push(&exp(i as f32, i % 2 == 0));
        }
        let idx = [3usize, 0, 7];
        let mut obs = vec![0.0; 6];
        let mut act = vec![0i32; 3];
        let mut rew = vec![0.0; 3];
        let mut nobs = vec![0.0; 6];
        let mut done = vec![0.0; 3];
        ring.gather(&idx, &mut obs, &mut act, &mut rew, &mut nobs, &mut done)
            .unwrap();
        assert_eq!(obs, vec![3.0, 3.5, 0.0, 0.5, 7.0, 7.5]);
        assert_eq!(act, vec![3, 0, 7]);
        assert_eq!(rew, vec![6.0, 0.0, 14.0]);
        assert_eq!(done, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn gather_rejects_out_of_range_index_in_release_too() {
        let mut ring = ExperienceRing::new(8, 2);
        for i in 0..3 {
            ring.push(&exp(i as f32, false));
        }
        let idx = [1usize, 5]; // slot 5 was never written
        let mut obs = vec![0.0; 4];
        let mut act = vec![0i32; 2];
        let mut rew = vec![0.0; 2];
        let mut nobs = vec![0.0; 4];
        let mut done = vec![0.0; 2];
        let err = ring
            .gather(&idx, &mut obs, &mut act, &mut rew, &mut nobs, &mut done)
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn batch_builder_and_accessors() {
        let exps: Vec<Experience> =
            (0..5).map(|i| exp(i as f32, i % 2 == 0)).collect();
        let b = ExperienceBatch::from_experiences(&exps);
        assert_eq!(b.len(), 5);
        assert_eq!(b.obs_dim(), 2);
        for (row, (e, r)) in exps.iter().zip(b.iter()).enumerate() {
            assert_eq!(r.obs, &e.obs[..], "row {row}");
            assert_eq!(r.next_obs, &e.next_obs[..]);
            assert_eq!(r.action, e.action);
            assert_eq!(r.reward, e.reward);
            assert_eq!(r.done, e.done);
            assert_eq!(&r.to_experience(), e);
        }
        let mut split = ExperienceBatch::new(2);
        split.push_row(&b, 3);
        assert_eq!(split.get(0), b.get(3));
        let mut reused = b.clone();
        reused.clear();
        assert!(reused.is_empty());
        assert_eq!(reused.obs_dim(), 2);
    }

    #[test]
    fn from_experience_matches_one_row_builder() {
        let e = exp(3.0, true);
        let a = ExperienceBatch::from_experience(e.clone());
        let b = ExperienceBatch::from_experiences(std::slice::from_ref(&e));
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        assert_eq!(a.obs_dim(), 2);
    }

    #[test]
    fn push_batch_matches_scalar_pushes_across_wrap() {
        // same data through both paths, including a capacity wrap inside
        // one batch and one batch larger than the whole ring
        for batch_len in [1usize, 3, 5, 13] {
            let mut scalar = ExperienceRing::new(5, 2);
            let mut batched = ExperienceRing::new(5, 2);
            let mut next = 0.0f32;
            for round in 0..4 {
                let exps: Vec<Experience> = (0..batch_len)
                    .map(|_| {
                        next += 1.0;
                        exp(next, next as usize % 3 == 0)
                    })
                    .collect();
                let scalar_slots: Vec<usize> =
                    exps.iter().map(|e| scalar.push(e)).collect();
                let b = ExperienceBatch::from_experiences(&exps);
                let mut batch_slots = Vec::new();
                batched.push_batch(&b, &mut batch_slots);
                assert_eq!(batch_slots, scalar_slots, "round {round}");
            }
            assert_eq!(scalar.len(), batched.len());
            for idx in 0..scalar.len() {
                assert_eq!(scalar.obs_of(idx), batched.obs_of(idx));
                assert_eq!(scalar.next_obs_of(idx), batched.next_obs_of(idx));
                assert_eq!(scalar.action_of(idx), batched.action_of(idx));
                assert_eq!(scalar.reward_of(idx), batched.reward_of(idx));
                assert_eq!(scalar.done_of(idx), batched.done_of(idx));
            }
        }
    }

    #[test]
    fn write_at_overwrites_in_place_without_moving_head() {
        let mut ring = ExperienceRing::new(4, 2);
        for i in 0..3 {
            ring.push(&exp(i as f32, false));
        }
        ring.write_at(1, &exp(9.0, true));
        assert_eq!(ring.obs_of(1), &[9.0, 9.5]);
        assert!(ring.done_of(1));
        assert_eq!(ring.len(), 3);
        // head is untouched: the next FIFO push lands on slot 3
        assert_eq!(ring.push(&exp(5.0, false)), 3);
        // writing past the current length raises the high-water mark
        let mut gap = ExperienceRing::new(8, 2);
        gap.write_at(5, &exp(1.0, false));
        assert_eq!(gap.len(), 6);
    }

    #[test]
    fn copy_slot_replicates_one_row() {
        let mut ring = ExperienceRing::new(6, 2);
        for i in 0..3 {
            ring.push(&exp(i as f32, i == 2));
        }
        ring.copy_slot(2, 4);
        assert_eq!(ring.obs_of(4), ring.obs_of(2));
        assert_eq!(ring.next_obs_of(4), ring.next_obs_of(2));
        assert_eq!(ring.action_of(4), ring.action_of(2));
        assert_eq!(ring.reward_of(4), ring.reward_of(2));
        assert_eq!(ring.done_of(4), ring.done_of(2));
        assert_eq!(ring.len(), 5);
        ring.copy_slot(1, 1); // self-copy is a no-op
        assert_eq!(ring.obs_of(1), &[1.0, 1.5]);
    }

    #[test]
    #[should_panic(expected = "obs dim mismatch")]
    fn dim_mismatch_panics() {
        let mut ring = ExperienceRing::new(2, 3);
        ring.push(&exp(0.0, false));
    }
}
