//! Experience storage: a fixed-capacity ring of transitions with
//! flat, cache-friendly observation storage.

/// One state transition `(s, a, r, s', done)` (paper Fig 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Experience {
    pub obs: Vec<f32>,
    pub action: u32,
    pub reward: f32,
    pub next_obs: Vec<f32>,
    pub done: bool,
}

/// Ring buffer of experiences with contiguous obs storage.
///
/// Observations for all slots live in two flat `Vec<f32>`s (`obs`,
/// `next_obs`), so batch gathering writes straight into the literal
/// buffers without per-experience pointer chasing. When full, the oldest
/// entry is overwritten (paper §4.1.2: "If the ER memory is full, it
/// discards the oldest experience").
#[derive(Debug, Clone)]
pub struct ExperienceRing {
    capacity: usize,
    obs_dim: usize,
    obs: Vec<f32>,
    next_obs: Vec<f32>,
    actions: Vec<u32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    len: usize,
    head: usize,
}

impl ExperienceRing {
    /// Create a ring for `capacity` transitions of `obs_dim`-dim states.
    pub fn new(capacity: usize, obs_dim: usize) -> Self {
        assert!(capacity > 0);
        ExperienceRing {
            capacity,
            obs_dim,
            obs: vec![0.0; capacity * obs_dim],
            next_obs: vec![0.0; capacity * obs_dim],
            actions: vec![0; capacity],
            rewards: vec![0.0; capacity],
            dones: vec![false; capacity],
            len: 0,
            head: 0,
        }
    }

    /// Lazily (re)size for the first pushed experience when `obs_dim` was
    /// unknown at construction (capacity preserved).
    pub fn ensure_dim(&mut self, obs_dim: usize) {
        if self.obs_dim != obs_dim {
            assert_eq!(self.len, 0, "cannot change obs_dim of non-empty ring");
            self.obs_dim = obs_dim;
            self.obs = vec![0.0; self.capacity * obs_dim];
            self.next_obs = vec![0.0; self.capacity * obs_dim];
        }
    }

    /// Insert, returning the slot index written (== evicted slot if full).
    pub fn push(&mut self, e: &Experience) -> usize {
        assert_eq!(e.obs.len(), self.obs_dim, "obs dim mismatch");
        assert_eq!(e.next_obs.len(), self.obs_dim);
        let idx = self.head;
        let o = idx * self.obs_dim;
        self.obs[o..o + self.obs_dim].copy_from_slice(&e.obs);
        self.next_obs[o..o + self.obs_dim].copy_from_slice(&e.next_obs);
        self.actions[idx] = e.action;
        self.rewards[idx] = e.reward;
        self.dones[idx] = e.done;
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        idx
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    /// Observation slice of slot `idx`.
    #[inline]
    pub fn obs_of(&self, idx: usize) -> &[f32] {
        let o = idx * self.obs_dim;
        &self.obs[o..o + self.obs_dim]
    }

    /// Next-observation slice of slot `idx`.
    #[inline]
    pub fn next_obs_of(&self, idx: usize) -> &[f32] {
        let o = idx * self.obs_dim;
        &self.next_obs[o..o + self.obs_dim]
    }

    #[inline]
    pub fn action_of(&self, idx: usize) -> u32 {
        self.actions[idx]
    }

    #[inline]
    pub fn reward_of(&self, idx: usize) -> f32 {
        self.rewards[idx]
    }

    #[inline]
    pub fn done_of(&self, idx: usize) -> bool {
        self.dones[idx]
    }

    /// Gather a batch into flat buffers (one memcpy per row) — the literal
    /// staging used by the runtime hot path.
    pub fn gather(
        &self,
        indices: &[usize],
        obs_out: &mut [f32],
        act_out: &mut [i32],
        rew_out: &mut [f32],
        next_obs_out: &mut [f32],
        done_out: &mut [f32],
    ) {
        let d = self.obs_dim;
        assert_eq!(obs_out.len(), indices.len() * d);
        for (row, &idx) in indices.iter().enumerate() {
            debug_assert!(idx < self.len);
            obs_out[row * d..(row + 1) * d].copy_from_slice(self.obs_of(idx));
            next_obs_out[row * d..(row + 1) * d]
                .copy_from_slice(self.next_obs_of(idx));
            act_out[row] = self.actions[idx] as i32;
            rew_out[row] = self.rewards[idx];
            done_out[row] = self.dones[idx] as u8 as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32, done: bool) -> Experience {
        Experience {
            obs: vec![v, v + 0.5],
            action: v as u32,
            reward: v * 2.0,
            next_obs: vec![v + 1.0, v + 1.5],
            done,
        }
    }

    #[test]
    fn push_and_read_back() {
        let mut ring = ExperienceRing::new(4, 2);
        let idx = ring.push(&exp(1.0, false));
        assert_eq!(idx, 0);
        assert_eq!(ring.obs_of(0), &[1.0, 1.5]);
        assert_eq!(ring.next_obs_of(0), &[2.0, 2.5]);
        assert_eq!(ring.action_of(0), 1);
        assert_eq!(ring.reward_of(0), 2.0);
        assert!(!ring.done_of(0));
        assert_eq!(ring.len(), 1);
    }

    #[test]
    fn wraps_and_evicts_oldest() {
        let mut ring = ExperienceRing::new(3, 2);
        for i in 0..5 {
            let idx = ring.push(&exp(i as f32, false));
            assert_eq!(idx, i % 3);
        }
        assert_eq!(ring.len(), 3);
        // slot 0 now holds experience 3, slot 1 holds 4, slot 2 holds 2
        assert_eq!(ring.obs_of(0), &[3.0, 3.5]);
        assert_eq!(ring.obs_of(1), &[4.0, 4.5]);
        assert_eq!(ring.obs_of(2), &[2.0, 2.5]);
    }

    #[test]
    fn gather_batches() {
        let mut ring = ExperienceRing::new(8, 2);
        for i in 0..8 {
            ring.push(&exp(i as f32, i % 2 == 0));
        }
        let idx = [3usize, 0, 7];
        let mut obs = vec![0.0; 6];
        let mut act = vec![0i32; 3];
        let mut rew = vec![0.0; 3];
        let mut nobs = vec![0.0; 6];
        let mut done = vec![0.0; 3];
        ring.gather(&idx, &mut obs, &mut act, &mut rew, &mut nobs, &mut done);
        assert_eq!(obs, vec![3.0, 3.5, 0.0, 0.5, 7.0, 7.5]);
        assert_eq!(act, vec![3, 0, 7]);
        assert_eq!(rew, vec![6.0, 0.0, 14.0]);
        assert_eq!(done, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "obs dim mismatch")]
    fn dim_mismatch_panics() {
        let mut ring = ExperienceRing::new(2, 3);
        ring.push(&exp(0.0, false));
    }
}
