//! Uniform experience replay (UER): the pre-PER baseline (paper §2.1).

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;

/// Uniform-sampling replay memory.
#[derive(Debug)]
pub struct UniformReplay {
    ring: ExperienceRing,
}

impl UniformReplay {
    pub fn new(capacity: usize) -> Self {
        UniformReplay { ring: ExperienceRing::new(capacity, 4) }
    }
}

impl ReplayMemory for UniformReplay {
    fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        self.ring.push(&e)
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        _rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        self.ring.push_batch(batch, slots);
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let n = self.ring.len();
        assert!(n > 0, "cannot sample an empty memory");
        out.indices.clear();
        out.indices.extend((0..batch).map(|_| rng.below(n)));
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    fn update_priorities(&mut self, _indices: &[usize], _td: &[f32]) {
        // uniform ER has no priorities
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::Uniform
    }

    fn priority_of(&self, _idx: usize) -> f32 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    #[test]
    fn sample_covers_memory_uniformly() {
        let mut rng = Rng::new(0);
        let mut mem = UniformReplay::new(100);
        for i in 0..100 {
            mem.push(exp(i as f32), &mut rng);
        }
        let mut counts = vec![0usize; 100];
        for _ in 0..1000 {
            for &i in &mem.sample(64, &mut rng).indices {
                counts[i] += 1;
            }
        }
        let mean = 64.0 * 1000.0 / 100.0;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > mean * 0.7 && (c as f64) < mean * 1.3,
                "slot {i}: {c} vs mean {mean}"
            );
        }
    }

    #[test]
    fn weights_are_unit() {
        let mut rng = Rng::new(1);
        let mut mem = UniformReplay::new(16);
        mem.push(exp(1.0), &mut rng);
        let b = mem.sample(8, &mut rng);
        assert!(b.is_weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn sample_never_exceeds_len() {
        let mut rng = Rng::new(2);
        let mut mem = UniformReplay::new(64);
        for i in 0..5 {
            mem.push(exp(i as f32), &mut rng);
        }
        for _ in 0..100 {
            assert!(mem.sample(32, &mut rng).indices.iter().all(|&i| i < 5));
        }
    }
}
