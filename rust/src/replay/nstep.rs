//! N-step return wrapper (Rainbow-style extension; the paper sets its
//! agent hyper-parameters "as [5]" = Rainbow, whose replay uses 3-step
//! returns — provided here as an optional composition over any
//! [`ReplayMemory`]).
//!
//! Transitions are buffered for `n` steps; the stored experience is
//! `(s_t, a_t, Σ_{k<n} γ^k r_{t+k}, s_{t+n}, done)` with the sum
//! truncated at episode end. The inner memory (PER/AMPER/...) is
//! untouched — priorities then measure n-step TD errors.

use std::collections::VecDeque;

use super::experience::{Experience, ExperienceRing};
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;

/// N-step composition over an inner replay memory.
pub struct NStepReplay {
    inner: Box<dyn ReplayMemory>,
    n: usize,
    gamma: f32,
    pending: VecDeque<Experience>,
}

impl NStepReplay {
    pub fn new(inner: Box<dyn ReplayMemory>, n: usize, gamma: f32) -> Self {
        assert!(n >= 1);
        NStepReplay { inner, n, gamma, pending: VecDeque::with_capacity(n) }
    }

    pub fn inner(&self) -> &dyn ReplayMemory {
        self.inner.as_ref()
    }

    /// Fold the pending window into one n-step transition.
    fn fold(&self) -> Experience {
        let first = self.pending.front().expect("non-empty window");
        let last = self.pending.back().unwrap();
        let mut reward = 0.0f32;
        let mut g = 1.0f32;
        for e in &self.pending {
            reward += g * e.reward;
            g *= self.gamma;
            if e.done {
                break;
            }
        }
        Experience {
            obs: first.obs.clone(),
            action: first.action,
            reward,
            next_obs: last.next_obs.clone(),
            done: self.pending.iter().any(|e| e.done),
        }
    }

    /// Flush remaining sub-n windows at episode end.
    fn flush_terminal(&mut self, rng: &mut Rng) {
        while !self.pending.is_empty() {
            let folded = self.fold();
            self.inner.push(folded, rng);
            self.pending.pop_front();
        }
    }
}

impl ReplayMemory for NStepReplay {
    fn push(&mut self, e: Experience, rng: &mut Rng) -> usize {
        let done = e.done;
        self.pending.push_back(e);
        if done {
            self.flush_terminal(rng);
            return self.inner.len().saturating_sub(1);
        }
        if self.pending.len() == self.n {
            let folded = self.fold();
            let idx = self.inner.push(folded, rng);
            self.pending.pop_front();
            return idx;
        }
        self.inner.len().saturating_sub(1)
    }

    // `push_batch` intentionally keeps the trait's scalar default: each
    // row must flow through the n-step window fold one at a time. The
    // sample/update surface forwards to the inner memory's batched paths.

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        self.inner.sample(batch, rng)
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        self.inner.sample_into(batch, rng, out)
    }

    fn update_priorities(&mut self, indices: &[usize], td: &[f32]) {
        self.inner.update_priorities(indices, td)
    }

    fn update_priorities_batch(&mut self, indices: &[usize], td: &[f32]) {
        self.inner.update_priorities_batch(indices, td)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        self.inner.ring()
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        self.inner.ring_mut()
    }

    fn kind(&self) -> ReplayKind {
        self.inner.kind()
    }

    fn priority_of(&self, idx: usize) -> f32 {
        self.inner.priority_of(idx)
    }

    fn modeled_device_ns(&self) -> Option<f64> {
        self.inner.modeled_device_ns()
    }

    fn set_thread_pool(&mut self, pool: std::sync::Arc<crate::runtime::ThreadPool>) {
        self.inner.set_thread_pool(pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;

    fn exp(v: f32, r: f32, done: bool) -> Experience {
        Experience {
            obs: vec![v; 2],
            action: v as u32,
            reward: r,
            next_obs: vec![v + 1.0; 2],
            done,
        }
    }

    #[test]
    fn folds_n_rewards_with_discount() {
        let mut mem =
            NStepReplay::new(Box::new(UniformReplay::new(16)), 3, 0.9);
        let mut rng = Rng::new(0);
        mem.push(exp(0.0, 1.0, false), &mut rng);
        mem.push(exp(1.0, 2.0, false), &mut rng);
        assert_eq!(mem.len(), 0, "window not full yet");
        mem.push(exp(2.0, 4.0, false), &mut rng);
        assert_eq!(mem.len(), 1);
        let ring = mem.ring();
        // reward = 1 + 0.9*2 + 0.81*4 = 6.04
        assert!((ring.reward_of(0) - 6.04).abs() < 1e-5);
        assert_eq!(ring.obs_of(0), &[0.0, 0.0]); // s_t
        assert_eq!(ring.next_obs_of(0), &[3.0, 3.0]); // s_{t+3}
        assert_eq!(ring.action_of(0), 0);
    }

    #[test]
    fn terminal_flushes_partial_windows() {
        let mut mem =
            NStepReplay::new(Box::new(UniformReplay::new(16)), 3, 1.0);
        let mut rng = Rng::new(1);
        mem.push(exp(0.0, 1.0, false), &mut rng);
        mem.push(exp(1.0, 1.0, true), &mut rng); // episode ends early
        // both windows flushed: [0,1] and [1]
        assert_eq!(mem.len(), 2);
        assert_eq!(mem.ring().reward_of(0), 2.0); // 1 + 1
        assert_eq!(mem.ring().reward_of(1), 1.0);
        assert!(mem.ring().done_of(0));
    }

    #[test]
    fn reward_sum_stops_at_done_inside_window() {
        let mut mem =
            NStepReplay::new(Box::new(UniformReplay::new(16)), 1, 0.5);
        let mut rng = Rng::new(2);
        mem.push(exp(0.0, 3.0, false), &mut rng);
        assert_eq!(mem.len(), 1);
        assert_eq!(mem.ring().reward_of(0), 3.0);
    }

    #[test]
    fn n1_equals_plain_replay() {
        let mut a = NStepReplay::new(Box::new(UniformReplay::new(8)), 1, 0.9);
        let mut b = UniformReplay::new(8);
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        for i in 0..5 {
            a.push(exp(i as f32, i as f32, i == 4), &mut r1);
            b.push(exp(i as f32, i as f32, i == 4), &mut r2);
        }
        assert_eq!(a.len(), b.len());
        for i in 0..5 {
            assert_eq!(a.ring().reward_of(i), b.ring().reward_of(i));
        }
    }
}
