//! Hardware-backed replay memory: the full co-design integration.
//!
//! [`HwAmperReplay`] implements [`ReplayMemory`] by driving the
//! bit-accurate [`AmperAccelerator`] for every store, sample and priority
//! update — i.e. the DQN agent literally trains against the simulated
//! in-memory-computing device, while the accelerator accumulates the
//! modeled hardware nanoseconds the paper's Fig 9 reports. Enabled via
//! `amper train --replay amper-fr --set hw_replay=true`; the CLI then
//! prints the "what would this agent's replay traffic cost on the AM
//! device" accounting recorded in EXPERIMENTS.md.

use super::amper::Variant;
use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::hardware::accelerator::{AccelConfig, AmperAccelerator};
use crate::util::Rng;

/// Replay memory whose sampling decisions come from the simulated AMPER
/// accelerator.
pub struct HwAmperReplay {
    ring: ExperienceRing,
    accel: AmperAccelerator,
    variant: Variant,
    eps: f32,
    alpha: f32,
    max_priority: f32,
    /// Total modeled device time spent on replay ops (ns).
    pub modeled_ns: f64,
    /// Device operations issued (sample + update + store).
    pub device_ops: u64,
}

impl HwAmperReplay {
    pub fn new(
        capacity: usize,
        config: AccelConfig,
        variant: Variant,
        seed: u32,
    ) -> Self {
        HwAmperReplay {
            ring: ExperienceRing::new(capacity, 4),
            accel: AmperAccelerator::new(capacity, config, seed | 1),
            variant,
            eps: 1e-2,
            alpha: 0.6,
            max_priority: 1.0,
            modeled_ns: 0.0,
            device_ops: 0,
        }
    }

    pub fn accelerator(&self) -> &AmperAccelerator {
        &self.accel
    }

    /// Mean modeled device latency per operation so far.
    pub fn mean_op_ns(&self) -> f64 {
        if self.device_ops == 0 {
            0.0
        } else {
            self.modeled_ns / self.device_ops as f64
        }
    }
}

impl ReplayMemory for HwAmperReplay {
    fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        let idx = self.ring.push(&e);
        // new experiences get max priority (as PER); one TCAM row write
        let r = self.accel.write_priority(idx, self.max_priority);
        self.modeled_ns += r.total_ns;
        self.device_ops += 1;
        idx
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        _rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        let start = slots.len();
        self.ring.push_batch(batch, slots);
        // one wide parallel device operation for the whole batch (the
        // paper's write port takes the rows back-to-back; the host issues
        // a single command instead of one per transition)
        let priorities = vec![self.max_priority; slots.len() - start];
        let r = self.accel.update_priorities(&slots[start..], &priorities);
        self.modeled_ns += r.total_ns;
        self.device_ops += 1;
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, _rng: &mut Rng, out: &mut SampledBatch) {
        assert!(!self.ring.is_empty(), "cannot sample an empty memory");
        // one wide parallel search serves the whole batch (paper §3.4)
        let s = self.accel.sample(batch, self.variant);
        self.modeled_ns += s.report.total_ns;
        self.device_ops += 1;
        // clamp stale slots (accelerator holds `capacity` rows; before
        // the ring wraps only `len` are valid — they coincide by
        // construction since writes track pushes)
        let n = self.ring.len();
        out.indices.clear();
        out.indices.extend(s.indices.into_iter().map(|i| i.min(n - 1)));
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]) {
        let priorities: Vec<f32> = td_errors
            .iter()
            .map(|&td| super::priority_from_td(td, self.eps, self.alpha))
            .collect();
        for &p in &priorities {
            self.max_priority = self.max_priority.max(p);
        }
        let r = self.accel.update_priorities(indices, &priorities);
        self.modeled_ns += r.total_ns;
        self.device_ops += 1;
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        match self.variant {
            Variant::Knn => ReplayKind::AmperK,
            Variant::Frnn => ReplayKind::AmperFr,
        }
    }

    fn priority_of(&self, idx: usize) -> f32 {
        super::amper::quant::dequantize(self.accel.bank().value(idx))
    }

    fn modeled_device_ns(&self) -> Option<f64> {
        Some(self.modeled_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    #[test]
    fn device_time_accumulates_per_op() {
        let mut mem =
            HwAmperReplay::new(256, AccelConfig::default(), Variant::Frnn, 7);
        let mut rng = Rng::new(0);
        for i in 0..256 {
            mem.push(exp(i as f32), &mut rng);
        }
        // 256 stores = 256 TCAM writes = 512 ns modeled
        assert!((mem.modeled_ns - 256.0 * 2.0).abs() < 1e-6);
        let b = mem.sample(64, &mut rng);
        assert_eq!(b.indices.len(), 64);
        mem.update_priorities(&b.indices, &[0.5; 64]);
        assert!(mem.modeled_ns > 512.0);
        assert_eq!(mem.device_ops, 256 + 2);
    }

    #[test]
    fn priorities_visible_through_quantized_view() {
        let mut mem =
            HwAmperReplay::new(64, AccelConfig::default(), Variant::Knn, 9);
        let mut rng = Rng::new(1);
        for i in 0..64 {
            mem.push(exp(i as f32), &mut rng);
        }
        mem.update_priorities(&[5], &[2.0]);
        let want = crate::replay::priority_from_td(2.0, 1e-2, 0.6);
        assert!((mem.priority_of(5) - want).abs() < 1e-3);
    }

    #[test]
    fn high_priority_oversampled_through_the_device() {
        let mut mem =
            HwAmperReplay::new(512, AccelConfig::default(), Variant::Frnn, 11);
        let mut rng = Rng::new(2);
        for i in 0..512 {
            mem.push(exp(i as f32), &mut rng);
        }
        let idx: Vec<usize> = (0..512).collect();
        let tds: Vec<f32> = (0..512).map(|_| rng.f32() * 0.2).collect();
        mem.update_priorities(&idx, &tds);
        // one very hot transition
        mem.update_priorities(&[100], &[10.0]);
        let mut hits = 0;
        for _ in 0..200 {
            hits += mem
                .sample(64, &mut rng)
                .indices
                .iter()
                .filter(|&&i| i == 100)
                .count();
        }
        // uniform rate would be 200*64/512 = 25
        assert!(hits > 40, "hot slot sampled only {hits} times");
    }
}
