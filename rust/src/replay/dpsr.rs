//! DPSR — experience replay with **d**ouble **p**rioritization and
//! **s**tate **r**ecycling (arXiv:2007.03961).
//!
//! Two ideas on top of PER:
//!
//! 1. **Double prioritization**: a transition's priority is set by TD
//!    error like PER, but every time it is *sampled* its priority decays
//!    by a multiplicative factor — recently replayed transitions yield
//!    the floor to ones the learner has not seen lately, bounding the
//!    over-replay of a few high-TD outliers between priority updates.
//! 2. **State recycling**: once the memory is full, an incoming
//!    transition sometimes (with probability `recycle_frac`) replaces the
//!    *lowest-priority* of a few randomly probed slots instead of the
//!    FIFO-oldest one, so long-lived useful experiences survive the ring
//!    wrap while exhausted ones are evicted early.
//!
//! Batched overrides are state-identical to the scalar loops (pinned in
//! `batch_equivalence`): victim probing reads only the leaf array, which
//! `set_leaf` keeps current between deferred ancestor refreshes.

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::sum_tree::SumTree;
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;

/// DPSR hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct DpsrParams {
    /// Priority exponent α (shared with PER).
    pub alpha: f32,
    /// Priority floor ε.
    pub eps: f32,
    /// Multiplicative priority decay applied to a slot each time it is
    /// sampled (1.0 disables the second prioritization).
    pub decay: f32,
    /// Probability that a push into a full memory recycles a
    /// low-priority slot instead of evicting FIFO-oldest (0 = plain PER
    /// eviction).
    pub recycle_frac: f32,
    /// Random probes per recycling eviction; the lowest-priority probe
    /// becomes the victim.
    pub recycle_candidates: usize,
}

impl Default for DpsrParams {
    fn default() -> Self {
        DpsrParams {
            alpha: 0.6,
            eps: 1e-2,
            decay: 0.7,
            recycle_frac: 0.1,
            recycle_candidates: 8,
        }
    }
}

/// Double-prioritized replay memory with state recycling.
#[derive(Debug)]
pub struct DpsrReplay {
    ring: ExperienceRing,
    tree: SumTree,
    params: DpsrParams,
    max_priority: f32,
    /// Ancestor-node scratch for [`SumTree::refresh_leaves`].
    refresh_scratch: Vec<usize>,
}

impl DpsrReplay {
    pub fn new(capacity: usize, params: DpsrParams) -> Self {
        assert!(params.recycle_candidates > 0, "need at least one probe");
        DpsrReplay {
            ring: ExperienceRing::new(capacity, 4),
            tree: SumTree::new(capacity),
            params,
            max_priority: 1.0,
            refresh_scratch: Vec::new(),
        }
    }

    /// Direct access to the priorities (studies/tests).
    pub fn tree(&self) -> &SumTree {
        &self.tree
    }

    /// Choose the slot for one incoming row and write it, returning the
    /// slot index. Shared verbatim by the scalar and batched push paths
    /// so their rng streams and ring states match exactly. Only consumes
    /// rng once the memory is full — before that, placement is plain
    /// FIFO with nothing to recycle.
    fn place_row(
        &mut self,
        obs: &[f32],
        action: u32,
        reward: f32,
        next_obs: &[f32],
        done: bool,
        rng: &mut Rng,
    ) -> usize {
        let cap = self.ring.capacity();
        if self.ring.len() == cap
            && self.params.recycle_frac > 0.0
            && rng.chance(self.params.recycle_frac as f64)
        {
            // probe a few random slots, evict the lowest-priority one
            // (reads the leaf array only: identical under deferred
            // ancestor refresh)
            let mut victim = rng.below(cap);
            let mut victim_p = self.tree.get(victim);
            for _ in 1..self.params.recycle_candidates {
                let probe = rng.below(cap);
                let p = self.tree.get(probe);
                if p < victim_p {
                    victim = probe;
                    victim_p = p;
                }
            }
            self.ring
                .write_at_parts(victim, obs, action, reward, next_obs, done);
            victim
        } else {
            self.ring.push_parts(obs, action, reward, next_obs, done)
        }
    }
}

impl ReplayMemory for DpsrReplay {
    fn push(&mut self, e: Experience, rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        let idx =
            self.place_row(&e.obs, e.action, e.reward, &e.next_obs, e.done, rng);
        // new experiences enter at max priority, like PER
        self.tree.set(idx, self.max_priority as f64);
        idx
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        let start = slots.len();
        // rows place one by one (placement is rng- and priority-dependent,
        // so there is no memcpy shortcut), but the tree pays one deferred
        // ancestor refresh for the whole batch instead of a root-ward
        // walk per row
        let p = self.max_priority as f64;
        for row in 0..batch.len() {
            let r = batch.get(row);
            let idx =
                self.place_row(r.obs, r.action, r.reward, r.next_obs, r.done, rng);
            self.tree.set_leaf(idx, p);
            slots.push(idx);
        }
        self.tree
            .refresh_leaves(&slots[start..], &mut self.refresh_scratch);
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let n = self.ring.len();
        assert!(n > 0, "cannot sample an empty memory");
        let total = self.tree.total();
        out.indices.clear();
        // stratified draws over the *pre-decay* mass, like PER
        let seg = total / batch as f64;
        for j in 0..batch {
            let y = seg * j as f64 + rng.f64() * seg;
            out.indices.push(self.tree.find(y));
        }
        // second prioritization: every sampled slot decays, compounding
        // for duplicates (set_leaf makes the decayed value visible to the
        // next duplicate within the batch); one deferred ancestor refresh
        if self.params.decay < 1.0 {
            for &idx in &out.indices {
                let p = self.tree.get(idx) * self.params.decay as f64;
                self.tree.set_leaf(idx, p);
            }
            self.tree
                .refresh_leaves(&out.indices, &mut self.refresh_scratch);
        }
        // no importance weights: the decay is a replay-frequency control,
        // not a probability correction
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]) {
        debug_assert_eq!(indices.len(), td_errors.len());
        for (&idx, &td) in indices.iter().zip(td_errors) {
            // a NaN/inf TD error must not poison the tree; treat it as a
            // zero-error transition (priority floor)
            let td = if td.is_finite() { td } else { 0.0 };
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.tree.set(idx, p as f64);
            self.max_priority = self.max_priority.max(p);
        }
    }

    fn update_priorities_batch(&mut self, indices: &[usize], td_errors: &[f32]) {
        debug_assert_eq!(indices.len(), td_errors.len());
        let mut batch_max = self.max_priority;
        for (&idx, &td) in indices.iter().zip(td_errors) {
            let td = if td.is_finite() { td } else { 0.0 };
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.tree.set_leaf(idx, p as f64);
            if p > batch_max {
                batch_max = p;
            }
        }
        self.tree.refresh_leaves(indices, &mut self.refresh_scratch);
        self.max_priority = batch_max;
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::Dpsr
    }

    fn priority_of(&self, idx: usize) -> f32 {
        self.tree.get(idx) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    fn filled(n: usize) -> (DpsrReplay, Rng) {
        let mut rng = Rng::new(0);
        let mut mem = DpsrReplay::new(n, DpsrParams::default());
        for i in 0..n {
            mem.push(exp(i as f32), &mut rng);
        }
        (mem, rng)
    }

    #[test]
    fn sampling_decays_sampled_priorities() {
        let (mut mem, mut rng) = filled(32);
        let before: Vec<f32> = (0..32).map(|i| mem.priority_of(i)).collect();
        let b = mem.sample(8, &mut rng);
        for &idx in &b.indices {
            assert!(
                mem.priority_of(idx) < before[idx],
                "slot {idx} did not decay"
            );
        }
        // unsampled slots keep their priority
        for i in 0..32 {
            if !b.indices.contains(&i) {
                assert_eq!(mem.priority_of(i), before[i]);
            }
        }
        assert!(b.is_weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn decay_one_disables_second_prioritization() {
        let mut rng = Rng::new(2);
        let mut mem =
            DpsrReplay::new(16, DpsrParams { decay: 1.0, ..Default::default() });
        for i in 0..16 {
            mem.push(exp(i as f32), &mut rng);
        }
        mem.sample(8, &mut rng);
        for i in 0..16 {
            assert_eq!(mem.priority_of(i), 1.0);
        }
    }

    #[test]
    fn recycling_prefers_low_priority_victims() {
        let mut rng = Rng::new(3);
        let mut mem = DpsrReplay::new(
            64,
            DpsrParams {
                recycle_frac: 1.0, // always recycle once full
                recycle_candidates: 256,
                ..Default::default()
            },
        );
        for i in 0..64 {
            mem.push(exp(i as f32), &mut rng);
        }
        // slot 13 is the unique low-priority slot; with 256 probes over a
        // 64-slot memory it is all but surely probed and must be evicted
        let idx: Vec<usize> = (0..64).collect();
        let mut tds = vec![10.0f32; 64];
        tds[13] = 0.0;
        mem.update_priorities(&idx, &tds);
        let mut hit = false;
        for k in 0..8 {
            hit |= mem.push(exp(100.0 + k as f32), &mut rng) == 13;
        }
        assert!(hit, "the low-priority slot was never recycled");
    }

    #[test]
    fn no_rng_consumed_before_full_matches_fifo() {
        // placement is plain FIFO until the ring fills, so the slots and
        // the rng stream match a PER push sequence exactly
        let mut rng = Rng::new(7);
        let mut mem = DpsrReplay::new(16, DpsrParams::default());
        for i in 0..16 {
            assert_eq!(mem.push(exp(i as f32), &mut rng), i);
        }
        let mut fresh = Rng::new(7);
        assert_eq!(rng.next_u64(), fresh.next_u64(), "rng was consumed");
    }

    #[test]
    fn non_finite_td_errors_fall_to_the_floor() {
        let (mut mem, _) = filled(8);
        mem.update_priorities(&[0, 1], &[f32::NAN, f32::INFINITY]);
        let floor =
            super::super::priority_from_td(0.0, 1e-2, 0.6);
        assert_eq!(mem.priority_of(0), floor);
        assert_eq!(mem.priority_of(1), floor);
        assert!(mem.tree().total().is_finite());
    }

    #[test]
    fn high_priority_sampled_more() {
        let (mut mem, mut rng) = filled(100);
        mem.update_priorities(&[7], &[100.0]);
        let mut count7 = 0usize;
        let rounds = 200;
        for _ in 0..rounds {
            count7 += mem
                .sample(16, &mut rng)
                .indices
                .iter()
                .filter(|&&i| i == 7)
                .count();
            // restore: decay would otherwise erode the signal under test
            mem.update_priorities(&[7], &[100.0]);
        }
        let got = count7 as f64 / (rounds * 16) as f64;
        assert!(got > 0.5, "high-TD slot sampled only {got:.3} of the time");
    }
}
