//! Fixed-radius NN via the prefix-based ternary query (AMPER-fr,
//! §3.3-§3.4.2).
//!
//! The radius `Δ_i` is approximated by its covering power of two: the mask
//! generator finds the leftmost '1' of `Δ_i` (bit position `p`) and marks
//! bits `p..0` of the query as don't-care (Fig 6b2). A single exact-match
//! TCAM search then returns every stored priority in the 2^(p+1)-aligned
//! block containing `V(g_i)` — the paper's acknowledged approximation
//! (range snaps to powers of two).
//!
//! This module computes the *same selection* in software, on the same
//! Q16.16 encoding the hardware stores, so `crate::hardware`'s functional
//! simulation and this selection agree bit-for-bit (pinned by tests).

use super::quant;

/// Compute the ternary query for representative `v` and radius `delta`
/// (both in priority value space). Returns `(query_word, care_mask)`:
/// bits with `care = 0` are don't-care.
pub fn prefix_query(v: f32, delta: f32) -> (u32, u32) {
    let qv = quant::quantize(v);
    let qd = quant::quantize(delta.max(0.0));
    let care = care_mask_for_delta(qd);
    (qv & care, care)
}

/// Mask generator (Fig 6b2): find the leftmost '1' of `qd`; that bit and
/// everything below become don't-care. `qd == 0` degrades to exact match.
#[inline]
pub fn care_mask_for_delta(qd: u32) -> u32 {
    if qd == 0 {
        return u32::MAX;
    }
    let p = 31 - qd.leading_zeros(); // leftmost-one position
    if p == 31 {
        0 // entire word don't-care
    } else {
        !((1u32 << (p + 1)) - 1)
    }
}

/// The accepted value range of a prefix query: the aligned block
/// `[base, base + size)` in quantized space.
pub fn accepted_range(query: u32, care: u32) -> (u32, u64) {
    let base = query & care;
    let size = (!care) as u64 + 1;
    (base, size)
}

/// Append every slot whose quantized priority matches the prefix query,
/// up to `budget` entries. `order` is the ascending `(priority, slot)`
/// view; monotonic quantization makes the accepted block a contiguous
/// range of it, found by binary search (software stand-in for the
/// parallel exact-match search).
pub fn select_frnn(
    order: &[(f32, usize)],
    pri_q: &[u32],
    v: f32,
    delta: f32,
    budget: usize,
    out: &mut Vec<usize>,
) {
    let (query, care) = prefix_query(v, delta);
    let (base, size) = accepted_range(query, care);
    // back off by one quantization step: an f32 just below the block base
    // can still round *into* the block
    let lo_val = quant::dequantize(base) - 1.0 / quant::SCALE;
    let start = super::csp::lower_bound(order, lo_val);
    let mut taken = 0usize;
    for &(_, slot) in &order[start..] {
        let q = pri_q[slot];
        if (q ^ query) & care != 0 {
            // past the block (ascending order) — done with this group
            if (q as u64) >= base as u64 + size {
                break;
            }
            continue; // below base due to f32 rounding at the boundary
        }
        out.push(slot);
        taken += 1;
        if taken >= budget {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mask_for_zero_delta_is_exact() {
        assert_eq!(care_mask_for_delta(0), u32::MAX);
    }

    #[test]
    fn mask_positions_match_paper_example() {
        // paper Fig 6b2: Q=8 example with p=4 -> low 5 bits don't-care.
        // Here Δ with leftmost-one at bit 4 (e.g. 0b0001_0000..0b0001_1111)
        for qd in [0b0001_0000u32, 0b0001_1111] {
            let care = care_mask_for_delta(qd);
            assert_eq!(care, !0b0001_1111u32, "qd={qd:#b}");
        }
        assert_eq!(care_mask_for_delta(1), !1u32);
        assert_eq!(care_mask_for_delta(0x8000_0000), 0);
    }

    #[test]
    fn accepted_range_is_pow2_block_containing_v() {
        let (q, care) = prefix_query(0.5, 0.01);
        let (base, size) = accepted_range(q, care);
        let qv = quant::quantize(0.5);
        assert!(base <= qv && (qv as u64) < base as u64 + size);
        assert!(size.is_power_of_two());
        // block must cover at least Δ on the covered side
        assert!(size >= quant::quantize(0.01) as u64);
    }

    #[test]
    fn selection_matches_linear_scan() {
        let mut rng = Rng::new(42);
        for trial in 0..30 {
            let n = 50 + rng.below(500);
            let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
            let mut order: Vec<(f32, usize)> =
                pri.iter().copied().zip(0..n).collect();
            order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let v = rng.f32();
            let delta = rng.f32() * 0.1;
            let mut got = Vec::new();
            select_frnn(&order, &pri_q, v, delta, usize::MAX, &mut got);
            got.sort_unstable();
            // linear TCAM-style scan oracle
            let (query, care) = prefix_query(v, delta);
            let mut want: Vec<usize> = (0..n)
                .filter(|&i| (pri_q[i] ^ query) & care == 0)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want, "trial {trial} v={v} delta={delta}");
        }
    }

    #[test]
    fn budget_truncates() {
        let pri: Vec<f32> = vec![0.5; 100];
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let order: Vec<(f32, usize)> = pri.iter().copied().zip(0..100).collect();
        let mut out = Vec::new();
        select_frnn(&order, &pri_q, 0.5, 0.1, 7, &mut out);
        assert_eq!(out.len(), 7);
    }

    #[test]
    fn radius_grows_with_delta() {
        let mut rng = Rng::new(7);
        let n = 2000;
        let pri: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let pri_q: Vec<u32> = pri.iter().map(|&p| quant::quantize(p)).collect();
        let mut order: Vec<(f32, usize)> = pri.iter().copied().zip(0..n).collect();
        order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut small = Vec::new();
        let mut large = Vec::new();
        select_frnn(&order, &pri_q, 0.5, 0.001, usize::MAX, &mut small);
        select_frnn(&order, &pri_q, 0.5, 0.2, usize::MAX, &mut large);
        assert!(large.len() > small.len());
    }
}
