//! CSP construction — the core of Algorithm 1.
//!
//! Per sample call: partition `[0, Vmax]` into `m` groups, draw a
//! representative `V(g_i)` per group, select a subset around it (kNN or
//! frNN; see the sibling modules), union the subsets into the CSP, then
//! uniformly draw the batch from the CSP.
//!
//! The software path sorts `(priority, slot)` once per call (O(n log n))
//! and answers group counts / neighbor expansion with binary search — the
//! "keeping the priority list sorted is costly on CPU/GPU" cost the paper
//! calls out in §3.1; the TCAM hardware (crate::hardware) avoids it, which
//! is exactly the co-design argument.

use super::{frnn, knn, AmperParams, Variant};
use crate::runtime::threadpool::{SendPtr, ThreadPool};
use crate::util::Rng;

/// Build the CSP: appends selected slot indices into `out` (cleared by the
/// caller), capped at `params.csp_cap` (the CSB capacity).
pub fn build_csp(
    pri: &[f32],
    pri_q: &[u32],
    params: &AmperParams,
    variant: Variant,
    rng: &mut Rng,
    out: &mut Vec<usize>,
) {
    let mut order = Vec::new();
    build_csp_with_scratch(pri, pri_q, params, variant, rng, out, &mut order);
}

/// [`build_csp`] with a caller-owned sort scratch (§Perf: the per-sample
/// allocation of the (priority, slot) view showed up in the replay_micro
/// profile; hot callers keep the buffer across calls).
///
/// This is the float-comparator reference path; the hot path is
/// [`build_csp_sorted_keys`], which sorts integer keys instead and is
/// pinned state-identical to this one in `batch_equivalence`.
pub fn build_csp_with_scratch(
    pri: &[f32],
    pri_q: &[u32],
    params: &AmperParams,
    variant: Variant,
    rng: &mut Rng,
    out: &mut Vec<usize>,
    order: &mut Vec<(f32, usize)>,
) {
    let n = pri.len();
    debug_assert_eq!(pri_q.len(), n);
    if n == 0 {
        return;
    }
    let vmax = pri.iter().copied().fold(0.0f32, f32::max);
    if vmax <= 0.0 {
        return; // degenerate: caller falls back to uniform draws
    }

    // sorted view: (priority, slot), ascending — shared by both variants.
    // total_cmp, not partial_cmp().unwrap(): a NaN priority (a poisoned
    // TD error that slipped past the debug assertions upstream) must not
    // panic the sampler mid-serve — under the IEEE total order NaN sorts
    // to the ends instead of aborting the comparison. The slot tiebreak
    // makes the order *unique*, so this path and the integer-key path
    // produce the same permutation.
    order.clear();
    order.extend(pri.iter().copied().zip(0..n));
    order.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    select_groups(pri_q, params, variant, rng, out, order, vmax);
}

/// Scratch for [`build_csp_sorted_keys`]: the packed key array, the merge
/// buffer for the parallel chunk sort, and the rebuilt `(priority, slot)`
/// view the group-selection pass consumes. Hot callers keep one across
/// sample calls so the build allocates nothing at steady state.
#[derive(Debug, Default, Clone)]
pub struct CspScratch {
    /// Packed `(sort_key(priority) << 32) | slot` — sorted as plain u64s.
    keys: Vec<u64>,
    /// Merge target for the chunked parallel sort.
    merge: Vec<u64>,
    /// Sorted `(priority, slot)` view rebuilt from `keys`.
    order: Vec<(f32, usize)>,
}

/// Total-order-preserving f32 → u32 key transform: for any `a`, `b`,
/// `sort_key(a) < sort_key(b)` ⇔ `a.total_cmp(&b) == Less`. Negative
/// floats flip all bits (descending magnitude → ascending key), others
/// set the sign bit — NaNs land at the extremes exactly as `total_cmp`
/// places them, so the NaN-robustness of the float path carries over
/// (pinned by the existing NaN regression test).
#[inline]
pub fn sort_key(p: f32) -> u32 {
    let b = p.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

/// Keys below this stay on the single-threaded sort — chunk-sort + merge
/// only pays for itself on large memories.
const PAR_SORT_MIN: usize = 1 << 15;

/// [`build_csp_with_scratch`] restructured for speed, same selection:
/// extract `(u32 key, u32 slot)` integer keys (branch-light u64 compares
/// instead of f32 total-order comparators), sort — in parallel chunks
/// merged on the caller when `pool` has workers and the memory is large —
/// then rebuild the sorted `(priority, slot)` view and run the same
/// group-selection pass. Keys are unique (the slot is the low half), so
/// any sort/merge schedule yields the same permutation: state-identical
/// to the float path, deterministic at any worker count.
#[allow(clippy::too_many_arguments)]
pub fn build_csp_sorted_keys(
    pri: &[f32],
    pri_q: &[u32],
    params: &AmperParams,
    variant: Variant,
    rng: &mut Rng,
    out: &mut Vec<usize>,
    scratch: &mut CspScratch,
    pool: Option<&ThreadPool>,
) {
    let n = pri.len();
    debug_assert_eq!(pri_q.len(), n);
    if n == 0 {
        return;
    }
    debug_assert!(n <= u32::MAX as usize, "slot index must fit the key's low half");
    let vmax = pri.iter().copied().fold(0.0f32, f32::max);
    if vmax <= 0.0 {
        return; // degenerate: caller falls back to uniform draws
    }

    let keys = &mut scratch.keys;
    keys.clear();
    keys.extend(
        pri.iter()
            .enumerate()
            .map(|(slot, &p)| ((sort_key(p) as u64) << 32) | slot as u64),
    );
    match pool {
        Some(pool) if pool.threads() > 1 && n >= PAR_SORT_MIN => {
            sort_keys_parallel(keys, &mut scratch.merge, pool);
        }
        _ => keys.sort_unstable(),
    }

    // rebuild the (priority, slot) view the selection pass (and the kNN /
    // frNN expansions) consume — same permutation as the float path
    let order = &mut scratch.order;
    order.clear();
    order.extend(keys.iter().map(|&k| {
        let slot = (k & 0xFFFF_FFFF) as usize;
        (pri[slot], slot)
    }));

    select_groups(pri_q, params, variant, rng, out, order, vmax);
}

/// Sort `keys` by chunk-sorting on the pool and multiway-merging on the
/// caller. Keys are unique, so the merge (and therefore the result) is
/// deterministic regardless of chunk boundaries or worker count.
fn sort_keys_parallel(keys: &mut Vec<u64>, merge: &mut Vec<u64>, pool: &ThreadPool) {
    let n = keys.len();
    let chunks = pool.threads().clamp(2, 8);
    let per = n.div_ceil(chunks);
    let mut bounds = [0usize; 9];
    for (c, b) in bounds.iter_mut().enumerate() {
        *b = (c * per).min(n);
    }
    let key_ptr = SendPtr(keys.as_mut_ptr());
    pool.run(chunks, &|c| {
        let (lo, hi) = (bounds[c], bounds[c + 1]);
        // chunks are disjoint subranges of the key array
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(key_ptr.0.add(lo), hi - lo) };
        chunk.sort_unstable();
    });
    // multiway min-scan merge: ≤ 8 head compares per output element
    merge.clear();
    merge.reserve(n);
    let mut heads = [0usize; 8];
    for c in 0..chunks {
        heads[c] = bounds[c];
    }
    for _ in 0..n {
        let mut best = usize::MAX;
        let mut best_key = u64::MAX;
        for c in 0..chunks {
            if heads[c] < bounds[c + 1] {
                let k = keys[heads[c]];
                if best == usize::MAX || k < best_key {
                    best = c;
                    best_key = k;
                }
            }
        }
        merge.push(best_key);
        heads[best] += 1;
    }
    std::mem::swap(keys, merge);
}

/// The m-group selection pass of Algorithm 1 (lines 3-13), shared by the
/// float-sort and integer-key build paths: partition `[0, Vmax]` into
/// `params.m` groups, draw a representative per group, and let the
/// variant expand its subset into `out` (capped at `csp_cap`).
fn select_groups(
    pri_q: &[u32],
    params: &AmperParams,
    variant: Variant,
    rng: &mut Rng,
    out: &mut Vec<usize>,
    order: &[(f32, usize)],
    vmax: f32,
) {
    let n = order.len();
    let m = params.m;
    for i in 0..m {
        if out.len() >= params.csp_cap {
            break;
        }
        let lo = vmax * i as f32 / m as f32;
        let hi = vmax * (i + 1) as f32 / m as f32;
        // Algorithm 1 line 3: V(g_i) ~ U[lo, hi)
        let v = rng.range_f32(lo, hi);
        // C(g_i): count of priorities within the group (line 5)
        let start = lower_bound(order, lo);
        let end = if i == m - 1 {
            n // last group includes Vmax itself
        } else {
            lower_bound(order, hi)
        };
        let count = end - start;
        if count == 0 {
            continue;
        }
        let budget = params.csp_cap - out.len();
        match variant {
            Variant::Knn => {
                // line 6: N_i = round(λ · V(g_i) · C(g_i))
                let n_i = (params.lambda * v * count as f32).round() as usize;
                let n_i = n_i.clamp(1, budget.min(n));
                knn::select_knn(order, v, n_i, out);
            }
            Variant::Frnn => {
                // line 10: Δ_i = round(λ′/m · V(g_i)), then prefix query
                let delta = params.lambda_prime / m as f32 * v;
                frnn::select_frnn(order, pri_q, v, delta, budget, out);
            }
        }
    }
}

/// Uniform draw of `batch` CSP entries (Algorithm 1 lines 14-17); falls
/// back to uniform-over-memory when the CSP is empty.
pub fn draw_batch(
    csp: &[usize],
    n: usize,
    batch: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut out = Vec::with_capacity(batch);
    draw_batch_into(csp, n, batch, rng, &mut out);
    out
}

/// [`draw_batch`] into a caller-owned buffer (appended; hot callers clear
/// and reuse it across sample calls).
pub fn draw_batch_into(
    csp: &[usize],
    n: usize,
    batch: usize,
    rng: &mut Rng,
    out: &mut Vec<usize>,
) {
    if csp.is_empty() {
        for _ in 0..batch {
            out.push(rng.below(n));
        }
    } else {
        for _ in 0..batch {
            out.push(csp[rng.below(csp.len())]);
        }
    }
}

/// First position in the ascending `(priority, slot)` order with
/// priority >= x.
pub fn lower_bound(order: &[(f32, usize)], x: f32) -> usize {
    order.partition_point(|&(p, _)| p < x)
}

#[cfg(test)]
mod tests {
    use super::super::quant;
    use super::*;

    fn mk(pri: &[f32]) -> (Vec<f32>, Vec<u32>) {
        (pri.to_vec(), pri.iter().map(|&p| quant::quantize(p)).collect())
    }

    #[test]
    fn lower_bound_basics() {
        let order = vec![(0.1, 0), (0.5, 1), (0.5, 2), (0.9, 3)];
        assert_eq!(lower_bound(&order, 0.0), 0);
        assert_eq!(lower_bound(&order, 0.5), 1);
        assert_eq!(lower_bound(&order, 0.500001), 3);
        assert_eq!(lower_bound(&order, 1.0), 4);
    }

    #[test]
    fn empty_or_zero_priorities_build_empty_csp() {
        let mut rng = Rng::new(0);
        let mut out = Vec::new();
        let (p, q) = mk(&[0.0, 0.0, 0.0]);
        build_csp(&p, &q, &AmperParams::default(), Variant::Knn, &mut rng, &mut out);
        assert!(out.is_empty());
        let drawn = draw_batch(&out, 3, 8, &mut rng);
        assert_eq!(drawn.len(), 8);
        assert!(drawn.iter().all(|&i| i < 3));
    }

    #[test]
    fn csp_prefers_large_priorities() {
        // Eq.1: subset size ∝ V(g_i)·C(g_i) — with equal counts per group,
        // high-value groups contribute more entries.
        let mut rng = Rng::new(1);
        let n = 1000;
        let pri: Vec<f32> = (0..n).map(|i| (i as f32 + 0.5) / n as f32).collect();
        let (p, q) = mk(&pri);
        let params = AmperParams { m: 10, lambda: 0.2, ..Default::default() };
        let mut hi_total = 0usize;
        let mut lo_total = 0usize;
        for _ in 0..50 {
            let mut out = Vec::new();
            build_csp(&p, &q, &params, Variant::Knn, &mut rng, &mut out);
            hi_total += out.iter().filter(|&&s| pri[s] > 0.8).count();
            lo_total += out.iter().filter(|&&s| pri[s] < 0.2).count();
        }
        assert!(
            hi_total > lo_total * 3,
            "hi {hi_total} vs lo {lo_total}"
        );
    }

    #[test]
    fn nan_priority_does_not_panic_the_sort() {
        // regression: partial_cmp().unwrap() aborted the whole service
        // thread when one slot's priority was NaN.
        let mut rng = Rng::new(7);
        let mut pri: Vec<f32> = (0..64).map(|i| (i as f32 + 1.0) / 64.0).collect();
        pri[10] = f32::NAN;
        let pri_q: Vec<u32> = pri
            .iter()
            .map(|&p| if p.is_nan() { 0 } else { quant::quantize(p) })
            .collect();
        for variant in [Variant::Knn, Variant::Frnn] {
            let mut out = Vec::new();
            build_csp(&pri, &pri_q, &AmperParams::default(), variant, &mut rng, &mut out);
            let drawn = draw_batch(&out, pri.len(), 16, &mut rng);
            assert_eq!(drawn.len(), 16);
            assert!(drawn.iter().all(|&i| i < pri.len()));
        }
    }

    #[test]
    fn csp_cap_is_hard() {
        let mut rng = Rng::new(2);
        let pri: Vec<f32> = (0..5000).map(|i| (i % 100) as f32 / 100.0 + 0.01).collect();
        let (p, q) = mk(&pri);
        for variant in [Variant::Knn, Variant::Frnn] {
            let params = AmperParams {
                csp_cap: 64,
                lambda: 100.0,
                lambda_prime: 100.0,
                ..Default::default()
            };
            let mut out = Vec::new();
            build_csp(&p, &q, &params, variant, &mut rng, &mut out);
            assert!(out.len() <= 64, "{variant:?}: {}", out.len());
        }
    }

    #[test]
    fn draw_batch_uniform_over_csp() {
        let mut rng = Rng::new(3);
        let csp: Vec<usize> = (10..20).collect();
        let mut counts = [0usize; 10];
        for _ in 0..1000 {
            for &i in &draw_batch(&csp, 100, 10, &mut rng) {
                counts[i - 10] += 1;
            }
        }
        for &c in &counts {
            assert!(c > 700 && c < 1300, "{counts:?}");
        }
    }
}
