//! INT-32 fixed-point priority encoding — the representation stored in
//! the TCAM rows (paper §4.2.1: "Each priority entry is represented with
//! INT-32 bits", Q = 32).
//!
//! Encoding: unsigned Q16.16. Priorities are non-negative (p = (|td|+ε)^α),
//! so 16 integer bits (max ≈ 65535) and 16 fractional bits (resolution
//! ≈ 1.5e-5) comfortably cover DQN TD-error priorities. The encoding is
//! monotonic, which is what both the prefix query (order-preserving bit
//! blocks) and the kNN distance search rely on.

/// Fractional bits of the fixed-point format.
pub const FRAC_BITS: u32 = 16;
/// Scale factor 2^16.
pub const SCALE: f32 = (1u32 << FRAC_BITS) as f32;

/// f32 priority -> Q16.16, saturating at the format bounds.
#[inline]
pub fn quantize(p: f32) -> u32 {
    debug_assert!(!p.is_nan());
    let clamped = p.max(0.0);
    let scaled = clamped as f64 * SCALE as f64;
    if scaled >= u32::MAX as f64 {
        u32::MAX
    } else {
        scaled.round() as u32
    }
}

/// Q16.16 -> f32 priority.
#[inline]
pub fn dequantize(q: u32) -> f32 {
    q as f32 / SCALE
}

/// Absolute distance in quantized space (the TCAM's value metric).
#[inline]
pub fn qdist(a: u32, b: u32) -> u32 {
    a.abs_diff(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_resolution() {
        for p in [0.0f32, 0.001, 0.5, 1.0, 3.25, 100.0, 1000.5] {
            let q = quantize(p);
            assert!((dequantize(q) - p).abs() <= 1.0 / SCALE, "{p}");
        }
    }

    #[test]
    fn monotonic() {
        let mut prev = quantize(0.0);
        for i in 1..1000 {
            let q = quantize(i as f32 * 0.37);
            assert!(q > prev);
            prev = q;
        }
    }

    #[test]
    fn saturates() {
        assert_eq!(quantize(f32::MAX), u32::MAX);
        assert_eq!(quantize(70000.0), u32::MAX);
        assert_eq!(quantize(-1.0), 0);
    }

    #[test]
    fn qdist_symmetric() {
        assert_eq!(qdist(5, 9), 4);
        assert_eq!(qdist(9, 5), 4);
        assert_eq!(qdist(7, 7), 0);
    }
}
