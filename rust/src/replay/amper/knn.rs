//! kNN candidate selection (AMPER-k, §3.2): the `N_i` stored priorities
//! nearest in value to the representative `V(g_i)`.
//!
//! On hardware this is `N_i` successive best-match TCAM searches with
//! winner masking (§3.4.1). In software we expand two pointers outward
//! from `V`'s insertion point in the sorted order — identical selection,
//! O(log n + N_i) per group.

/// Append the `n_i` slots whose priorities are nearest to `v` (ties break
/// toward the smaller value, matching the hardware's lowest-row-wins
/// matchline arbitration).
pub fn select_knn(
    order: &[(f32, usize)],
    v: f32,
    n_i: usize,
    out: &mut Vec<usize>,
) {
    let n = order.len();
    debug_assert!(n_i <= n);
    let pivot = super::csp::lower_bound(order, v);
    // lo = last index with priority < v; hi = first with >= v
    let mut lo: isize = pivot as isize - 1;
    let mut hi: usize = pivot;
    for _ in 0..n_i {
        let take_lo = if lo < 0 {
            false
        } else if hi >= n {
            true
        } else {
            // distance comparison; tie -> smaller value (lo side)
            (v - order[lo as usize].0) <= (order[hi].0 - v)
        };
        if take_lo {
            out.push(order[lo as usize].1);
            lo -= 1;
        } else if hi < n {
            out.push(order[hi].1);
            hi += 1;
        } else {
            break; // fewer than n_i stored priorities
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn order_of(ps: &[f32]) -> Vec<(f32, usize)> {
        let mut o: Vec<(f32, usize)> = ps.iter().copied().zip(0..).collect();
        o.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        o
    }

    #[test]
    fn selects_nearest_by_value() {
        let order = order_of(&[0.1, 0.9, 0.48, 0.52, 0.3]);
        let mut out = Vec::new();
        select_knn(&order, 0.5, 2, &mut out);
        out.sort_unstable();
        assert_eq!(out, vec![2, 3]); // 0.48 and 0.52
    }

    #[test]
    fn matches_bruteforce_on_random_data() {
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        for trial in 0..50 {
            let n = 1 + rng.below(200);
            let ps: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let v = rng.f32();
            let k = 1 + rng.below(n);
            let order = order_of(&ps);
            let mut got = Vec::new();
            select_knn(&order, v, k, &mut got);
            assert_eq!(got.len(), k, "trial {trial}");
            // brute force: k smallest |p - v|
            let mut dists: Vec<f32> = ps.iter().map(|p| (p - v).abs()).collect();
            dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let kth = dists[k - 1];
            for &s in &got {
                assert!(
                    (ps[s] - v).abs() <= kth + 1e-6,
                    "trial {trial}: slot {s} dist {} > kth {kth}",
                    (ps[s] - v).abs()
                );
            }
            // no duplicates
            let mut dedup = got.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k);
        }
    }

    #[test]
    fn k_equals_n_takes_everything() {
        let order = order_of(&[0.2, 0.4, 0.6]);
        let mut out = Vec::new();
        select_knn(&order, 0.4, 3, &mut out);
        out.sort_unstable();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn v_outside_range_still_works() {
        let order = order_of(&[0.2, 0.4, 0.6]);
        let mut out = Vec::new();
        select_knn(&order, 5.0, 2, &mut out);
        out.sort_unstable();
        assert_eq!(out, vec![1, 2]); // two largest
    }
}
