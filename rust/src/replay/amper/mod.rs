//! AMPER — the paper's Algorithm 1: AM-friendly priority sampling.
//!
//! Priority sampling is approximated by *uniform* sampling over a
//! candidate set of priorities (CSP). The CSP is rebuilt on every sample
//! call from `m` priority groups; group `g_i` covers values
//! `[Vmax·i/m, Vmax·(i+1)/m)` and contributes a subset chosen around a
//! uniformly drawn representative `V(g_i)`:
//!
//! * **AMPER-k** ([`AmperK`]): the `N_i = round(λ·V(g_i)·C(g_i))` nearest
//!   neighbors of `V(g_i)` (TCAM best-match searches, §3.2);
//! * **AMPER-fr** ([`AmperFr`]): all values within
//!   `Δ_i = round(λ'/m·V(g_i))`, realized with a prefix ternary query on
//!   the INT-32 fixed-point encoding — one exact-match search (§3.3-3.4).
//!
//! Software selection here is bit-compatible with the hardware simulator
//! in [`crate::hardware`]: both operate on the same [`quant`] encoding, so
//! algorithm-level studies (Fig 7/8) and the accelerator latency model
//! (Fig 9) agree on *which* experiences are selected.

pub mod csp;
pub mod frnn;
pub mod knn;
pub mod quant;

use std::sync::Arc;

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::runtime::ThreadPool;
use crate::util::Rng;

pub use csp::CspScratch;

/// Which nearest-neighbor flavor a memory uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Knn,
    Frnn,
}

/// AMPER hyper-parameters (paper §3.2-3.3, studied in Fig 7).
#[derive(Debug, Clone, Copy)]
pub struct AmperParams {
    /// Group count m (quantization-level analogue).
    pub m: usize,
    /// kNN subset scaling factor λ (Eq. 1).
    pub lambda: f32,
    /// frNN radius scaling factor λ′ (Eq. 4).
    pub lambda_prime: f32,
    /// Priority floor ε (as PER).
    pub eps: f32,
    /// Priority exponent α (as PER).
    pub alpha: f32,
    /// Candidate-set buffer capacity (hardware CSB holds 8000 entries).
    pub csp_cap: usize,
}

impl Default for AmperParams {
    fn default() -> Self {
        // m=20 / CSP ratio 0.15 is the paper's "best learning performance"
        // operating point (§4.2.2). Expected CSP ratios: kNN ≈ λ·E[V] ≈
        // λ/2; frNN ≈ 0.75·λ′ (prefix block ≈ 1.5·Δ_i per group summed
        // over groups) — λ=0.3 / λ′=0.2 both land ≈ 0.15.
        AmperParams {
            m: 20,
            lambda: 0.3,
            lambda_prime: 0.2,
            eps: 1e-2,
            alpha: 0.6,
            csp_cap: 8000,
        }
    }
}

/// Shared state of both AMPER variants.
#[derive(Debug)]
pub struct AmperCore {
    ring: ExperienceRing,
    /// f32 priorities per slot (the algorithm view).
    pri: Vec<f32>,
    /// INT-32 fixed-point priorities (the TCAM view; kept in sync).
    pri_q: Vec<u32>,
    params: AmperParams,
    variant: Variant,
    max_priority: f32,
    /// Scratch CSP buffer reused across sample calls (models the CSB).
    csp_buf: Vec<usize>,
    /// Integer-key sort scratch reused across sample calls (§Perf).
    csp_scratch: CspScratch,
    /// Worker pool for the chunked CSP sort on large memories — installed
    /// by serve via [`ReplayMemory::set_thread_pool`] (shard-local builds
    /// share the engine's pool); `None` = single-threaded sort.
    pool: Option<Arc<ThreadPool>>,
}

impl AmperCore {
    pub fn new(capacity: usize, params: AmperParams, variant: Variant) -> Self {
        assert!(params.m >= 1);
        AmperCore {
            ring: ExperienceRing::new(capacity, 4),
            pri: vec![0.0; capacity],
            pri_q: vec![0; capacity],
            params,
            variant,
            max_priority: 1.0,
            csp_buf: Vec::with_capacity(params.csp_cap.min(1 << 16)),
            csp_scratch: CspScratch::default(),
            pool: None,
        }
    }

    pub fn params(&self) -> &AmperParams {
        &self.params
    }

    /// Live priority slice (first `len` entries valid).
    pub fn priorities(&self) -> &[f32] {
        &self.pri[..self.ring.len()]
    }

    /// Quantized priorities (the TCAM contents).
    pub fn priorities_q(&self) -> &[u32] {
        &self.pri_q[..self.ring.len()]
    }

    /// Size of the CSP built by the most recent sample call.
    pub fn last_csp_len(&self) -> usize {
        self.csp_buf.len()
    }

    fn set_priority(&mut self, idx: usize, p: f32) {
        self.pri[idx] = p;
        self.pri_q[idx] = quant::quantize(p);
        if p > self.max_priority {
            self.max_priority = p;
        }
    }

    fn push_impl(&mut self, e: Experience) -> usize {
        self.ring.ensure_dim(e.obs.len());
        let idx = self.ring.push(&e);
        let p = self.max_priority;
        self.set_priority(idx, p);
        idx
    }

    /// Batched store: one chunked ring insert, then one priority/quantized
    /// write per row with `quantize(max_priority)` computed once for the
    /// whole batch (every new experience enters at max priority, so the
    /// TCAM word is shared). State-identical to `push_impl` per row.
    fn push_batch_impl(&mut self, b: &ExperienceBatch, slots: &mut Vec<usize>) {
        if b.is_empty() {
            return;
        }
        self.ring.ensure_dim(b.obs_dim());
        let start = slots.len();
        self.ring.push_batch(b, slots);
        let p = self.max_priority;
        let q = quant::quantize(p);
        for i in start..slots.len() {
            let idx = slots[i];
            self.pri[idx] = p;
            self.pri_q[idx] = q;
        }
    }

    fn sample_impl(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into_impl(batch, rng, &mut out);
        out
    }

    /// One CSP build — one sorted pass over the priority list — serves
    /// the entire batch (Algorithm 1: the CSP is built per sample call,
    /// then the whole batch draws uniformly from it).
    fn sample_into_impl(
        &mut self,
        batch: usize,
        rng: &mut Rng,
        out: &mut SampledBatch,
    ) {
        let n = self.ring.len();
        assert!(n > 0, "cannot sample an empty memory");
        self.csp_buf.clear();
        csp::build_csp_sorted_keys(
            &self.pri[..n],
            &self.pri_q[..n],
            &self.params,
            self.variant,
            rng,
            &mut self.csp_buf,
            &mut self.csp_scratch,
            self.pool.as_deref(),
        );
        out.indices.clear();
        csp::draw_batch_into(&self.csp_buf, n, batch, rng, &mut out.indices);
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    /// Batched TD-error feedback: one pass computing priorities and
    /// quantized words, with the max-priority refresh folded once per
    /// batch. State-identical to per-element `set_priority` calls.
    fn update_batch_impl(&mut self, indices: &[usize], td: &[f32]) {
        debug_assert_eq!(indices.len(), td.len());
        let mut batch_max = self.max_priority;
        for (&idx, &e) in indices.iter().zip(td) {
            debug_assert!(e.is_finite(), "non-finite TD error {e} for slot {idx}");
            let p = super::priority_from_td(e, self.params.eps, self.params.alpha);
            self.pri[idx] = p;
            self.pri_q[idx] = quant::quantize(p);
            if p > batch_max {
                batch_max = p;
            }
        }
        self.max_priority = batch_max;
    }
}

macro_rules! amper_variant {
    ($name:ident, $variant:expr, $kind:expr, $doc:literal) => {
        #[doc = $doc]
        #[derive(Debug)]
        pub struct $name(pub AmperCore);

        impl $name {
            pub fn new(capacity: usize, params: AmperParams) -> Self {
                $name(AmperCore::new(capacity, params, $variant))
            }

            /// Access the shared core (priorities, CSP stats).
            pub fn core(&self) -> &AmperCore {
                &self.0
            }

            /// Seed a slot priority directly (sampling-error studies).
            pub fn set_priority_raw(&mut self, idx: usize, p: f32) {
                self.0.set_priority(idx, p);
            }
        }

        impl ReplayMemory for $name {
            fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
                self.0.push_impl(e)
            }

            fn push_batch(
                &mut self,
                batch: &ExperienceBatch,
                _rng: &mut Rng,
                slots: &mut Vec<usize>,
            ) {
                self.0.push_batch_impl(batch, slots)
            }

            fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
                self.0.sample_impl(batch, rng)
            }

            fn sample_into(
                &mut self,
                batch: usize,
                rng: &mut Rng,
                out: &mut SampledBatch,
            ) {
                self.0.sample_into_impl(batch, rng, out)
            }

            fn update_priorities(&mut self, indices: &[usize], td: &[f32]) {
                debug_assert_eq!(indices.len(), td.len());
                for (&idx, &e) in indices.iter().zip(td) {
                    // a NaN/inf TD error would poison the priority list
                    // and the TCAM encoding; reject it at the boundary
                    debug_assert!(
                        e.is_finite(),
                        "non-finite TD error {e} for slot {idx}"
                    );
                    let p = super::priority_from_td(
                        e,
                        self.0.params.eps,
                        self.0.params.alpha,
                    );
                    self.0.set_priority(idx, p);
                }
            }

            fn update_priorities_batch(&mut self, indices: &[usize], td: &[f32]) {
                self.0.update_batch_impl(indices, td)
            }

            fn set_thread_pool(&mut self, pool: Arc<crate::runtime::ThreadPool>) {
                self.0.pool = Some(pool);
            }

            fn len(&self) -> usize {
                self.0.ring.len()
            }

            fn capacity(&self) -> usize {
                self.0.ring.capacity()
            }

            fn ring(&self) -> &ExperienceRing {
                &self.0.ring
            }

            fn ring_mut(&mut self) -> &mut ExperienceRing {
                &mut self.0.ring
            }

            fn kind(&self) -> ReplayKind {
                $kind
            }

            fn priority_of(&self, idx: usize) -> f32 {
                self.0.pri[idx]
            }
        }
    };
}

amper_variant!(
    AmperK,
    Variant::Knn,
    ReplayKind::AmperK,
    "AMPER with kNN candidate selection (paper §3.2, Algorithm 1 lines 4-8)."
);
amper_variant!(
    AmperFr,
    Variant::Frnn,
    ReplayKind::AmperFr,
    "AMPER with fixed-radius NN + prefix-query selection (paper §3.3-3.4, \
     Algorithm 1 lines 9-12)."
);

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 2],
            action: 0,
            reward: v,
            next_obs: vec![v; 2],
            done: false,
        }
    }

    fn seeded<M: ReplayMemory + ?Sized>(mem: &mut M, n: usize, rng: &mut Rng) {
        for i in 0..n {
            mem.push(exp(i as f32), rng);
        }
        // spread of priorities ~ U[0,1] like the paper's Fig 7 study
        let idx: Vec<usize> = (0..n).collect();
        let tds: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        mem.update_priorities(&idx, &tds);
    }

    #[test]
    fn sample_returns_batch_for_both_variants() {
        for variant in [Variant::Knn, Variant::Frnn] {
            let mut rng = Rng::new(5);
            let mut core = AmperCore::new(512, AmperParams::default(), variant);
            for i in 0..512 {
                core.push_impl(exp(i as f32));
            }
            let b = core.sample_impl(64, &mut rng);
            assert_eq!(b.indices.len(), 64);
            assert!(b.indices.iter().all(|&i| i < 512));
            assert!(b.is_weights.iter().all(|&w| w == 1.0));
        }
    }

    #[test]
    fn higher_priorities_oversampled() {
        for (name, mem) in [
            ("k", &mut AmperK::new(1000, AmperParams::default()) as &mut dyn ReplayMemory),
            ("fr", &mut AmperFr::new(1000, AmperParams::default())),
        ] {
            let mut rng = Rng::new(9);
            seeded(mem, 1000, &mut rng);
            // top decile of priorities should receive far more than 10% of draws
            let top: Vec<usize> = (0..1000)
                .filter(|&i| mem.priority_of(i) > 0.9f32.powf(0.6))
                .collect();
            let mut hits = 0usize;
            let mut total = 0usize;
            for _ in 0..200 {
                for &i in &mem.sample(64, &mut rng).indices {
                    total += 1;
                    if top.contains(&i) {
                        hits += 1;
                    }
                }
            }
            let frac = hits as f64 / total as f64;
            let base = top.len() as f64 / 1000.0;
            assert!(
                frac > base * 1.5,
                "amper-{name}: top-decile frac {frac} vs base {base}"
            );
        }
    }

    #[test]
    fn all_equal_priorities_degenerates_to_uniformish() {
        let mut rng = Rng::new(11);
        let mut mem = AmperFr::new(256, AmperParams::default());
        for i in 0..256 {
            mem.push(exp(i as f32), &mut rng);
        }
        // all at max priority 1.0 — every slot must remain samplable
        let mut seen = vec![false; 256];
        for _ in 0..300 {
            for &i in &mem.sample(64, &mut rng).indices {
                seen[i] = true;
            }
        }
        let cov = seen.iter().filter(|&&s| s).count();
        assert!(cov > 200, "coverage {cov}/256");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite TD error")]
    fn non_finite_td_rejected_in_debug() {
        let mut rng = Rng::new(0);
        let mut mem = AmperFr::new(8, AmperParams::default());
        mem.push(exp(0.0), &mut rng);
        mem.update_priorities(&[0], &[f32::NAN]);
    }

    #[test]
    fn csp_respects_buffer_cap() {
        let mut rng = Rng::new(13);
        let params = AmperParams { csp_cap: 100, lambda: 10.0, ..Default::default() };
        let mut mem = AmperK::new(2000, params);
        seeded(&mut mem, 2000, &mut rng);
        mem.sample(64, &mut rng);
        assert!(mem.core().last_csp_len() <= 100);
    }

    #[test]
    fn quantized_view_stays_in_sync() {
        let mut rng = Rng::new(17);
        let mut mem = AmperFr::new(64, AmperParams::default());
        seeded(&mut mem, 64, &mut rng);
        for (i, (&p, &q)) in mem
            .core()
            .priorities()
            .iter()
            .zip(mem.core().priorities_q())
            .enumerate()
        {
            assert_eq!(q, quant::quantize(p), "slot {i}");
        }
    }
}
