//! Prioritized Experience Replay (Schaul et al. 2015) — the paper's
//! baseline. Sum-based priority sampling on a [`SumTree`] with the
//! standard `p = (|td| + ε)^α` priorities and β-annealed importance
//! weights. This is the implementation whose sampling+update latency the
//! AMPER hardware is compared against (Fig 9a).

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::sum_tree::SumTree;
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;

/// PER hyper-parameters (defaults per Schaul et al. / Rainbow).
#[derive(Debug, Clone, Copy)]
pub struct PerParams {
    /// Priority exponent α (0 = uniform).
    pub alpha: f32,
    /// Initial importance-sampling exponent β.
    pub beta0: f32,
    /// Steps over which β anneals to 1.
    pub beta_steps: u64,
    /// Priority floor ε.
    pub eps: f32,
}

impl Default for PerParams {
    fn default() -> Self {
        PerParams { alpha: 0.6, beta0: 0.4, beta_steps: 100_000, eps: 1e-2 }
    }
}

/// Sum-tree PER memory.
#[derive(Debug)]
pub struct PerReplay {
    ring: ExperienceRing,
    tree: SumTree,
    params: PerParams,
    max_priority: f32,
    /// Cached minimum non-zero priority (§Perf: exact O(n) rescans per
    /// sample dominated large memories). The cache is *exact*, not a
    /// bound: any write that removes or raises the current minimum marks
    /// it dirty ([`Self::note_write`]) and the next sample rescans — a
    /// stale low value would silently shrink every IS weight through
    /// `max_w`. A periodic rescan every [`MIN_REFRESH`] samples remains
    /// as a numerical backstop.
    min_priority: f64,
    /// Cache invalidated by an overwrite/raise of the minimum slot.
    min_dirty: bool,
    samples_since_refresh: u64,
    samples_drawn: u64,
    /// Sampling-probability scratch reused across sample calls (§Perf:
    /// batch-first path keeps the hot loop allocation-free).
    probs_scratch: Vec<f64>,
    /// Ancestor-node scratch for [`SumTree::refresh_leaves`] (chunked
    /// batch updates).
    refresh_scratch: Vec<usize>,
}

/// Samples between exact min-priority rescans.
const MIN_REFRESH: u64 = 1024;

impl PerReplay {
    pub fn new(capacity: usize, params: PerParams) -> Self {
        PerReplay {
            ring: ExperienceRing::new(capacity, 4),
            tree: SumTree::new(capacity),
            params,
            max_priority: 1.0,
            min_priority: f64::INFINITY,
            min_dirty: false,
            samples_since_refresh: 0,
            samples_drawn: 0,
            probs_scratch: Vec::new(),
            refresh_scratch: Vec::new(),
        }
    }

    /// Current annealed β.
    pub fn beta(&self) -> f32 {
        let frac =
            (self.samples_drawn as f64 / self.params.beta_steps as f64).min(1.0);
        self.params.beta0 + (1.0 - self.params.beta0) * frac as f32
    }

    /// Direct access to the priorities (sampling-error studies, Fig 7).
    pub fn tree(&self) -> &SumTree {
        &self.tree
    }

    /// Seed the memory with explicit priorities (sampling studies).
    pub fn set_priority_raw(&mut self, idx: usize, p: f32) {
        self.note_write(self.tree.get(idx), p as f64);
        self.tree.set(idx, p as f64);
        self.max_priority = self.max_priority.max(p);
    }

    /// Maintain the min-priority cache across a leaf write `old -> new`.
    /// Lowering the min is tracked exactly; removing or raising the slot
    /// that holds the cached min invalidates the cache (the true minimum
    /// may now live anywhere).
    #[inline]
    fn note_write(&mut self, old: f64, new: f64) {
        if new > 0.0 && new < self.min_priority {
            self.min_priority = new;
        } else if old > 0.0 && old <= self.min_priority && (new > old || new <= 0.0) {
            self.min_dirty = true;
        }
    }

    /// Cached min non-zero priority; rescans when the cache was
    /// invalidated by an overwrite, plus every [`MIN_REFRESH`] samples as
    /// a backstop.
    fn min_nonzero_cached(&mut self) -> f64 {
        if self.min_dirty
            || self.min_priority.is_infinite()
            || self.samples_since_refresh >= MIN_REFRESH
        {
            self.min_priority = self.tree.min_nonzero(self.ring.len());
            self.min_dirty = false;
            self.samples_since_refresh = 0;
        }
        self.min_priority
    }

    #[cfg(test)]
    fn min_cache_for_test(&mut self) -> f64 {
        self.min_nonzero_cached()
    }
}

impl ReplayMemory for PerReplay {
    fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        let idx = self.ring.push(&e);
        // new experiences enter with max priority (Schaul §3.3); a ring
        // wrap may overwrite the slot holding the cached min
        self.note_write(self.tree.get(idx), self.max_priority as f64);
        self.tree.set(idx, self.max_priority as f64);
        idx
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        _rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        let start = slots.len();
        self.ring.push_batch(batch, slots);
        // all rows enter at the same max priority (Schaul §3.3); the
        // max itself cannot move during the batch, so read it once.
        // Chunked write: leaves land back-to-back, then one level-by-level
        // ancestor refresh visits each shared internal node once.
        let p = self.max_priority as f64;
        for i in start..slots.len() {
            let idx = slots[i];
            self.note_write(self.tree.get(idx), p);
            self.tree.set_leaf(idx, p);
        }
        self.tree
            .refresh_leaves(&slots[start..], &mut self.refresh_scratch);
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let n = self.ring.len();
        assert!(n > 0, "cannot sample an empty memory");
        let total = self.tree.total();
        out.indices.clear();
        let mut probs = std::mem::take(&mut self.probs_scratch);
        probs.clear();
        // stratified sampling: one draw per equal-mass segment (Schaul §3.3)
        let seg = total / batch as f64;
        for j in 0..batch {
            let y = seg * j as f64 + rng.f64() * seg;
            let idx = self.tree.find(y);
            out.indices.push(idx);
            probs.push(self.tree.get(idx) / total);
        }
        // importance weights w = (N p)^-β, normalized by the max weight
        let beta = self.beta() as f64;
        self.samples_since_refresh += 1;
        let min_prob = self.min_nonzero_cached() / total;
        let max_w = (n as f64 * min_prob).powf(-beta);
        out.is_weights.clear();
        out.is_weights.extend(probs.iter().map(|&p| {
            let w = (n as f64 * p.max(1e-12)).powf(-beta) / max_w;
            w as f32
        }));
        self.samples_drawn += 1;
        self.probs_scratch = probs;
    }

    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]) {
        debug_assert_eq!(indices.len(), td_errors.len());
        for (&idx, &td) in indices.iter().zip(td_errors) {
            debug_assert!(td.is_finite(), "non-finite TD error {td} for slot {idx}");
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.note_write(self.tree.get(idx), p as f64);
            self.tree.set(idx, p as f64);
            self.max_priority = self.max_priority.max(p);
        }
    }

    fn update_priorities_batch(&mut self, indices: &[usize], td_errors: &[f32]) {
        // state-identical to the scalar loop (pinned bitwise in
        // `batch_equivalence`): the max-priority refresh folds over the
        // batch once, the leaf writes land back-to-back with **no**
        // root-ward walk, and one level-by-level [`SumTree::refresh_leaves`]
        // pass recomputes each shared ancestor exactly once — O(B + A)
        // node writes instead of O(B log N)
        debug_assert_eq!(indices.len(), td_errors.len());
        let mut batch_max = self.max_priority;
        for (&idx, &td) in indices.iter().zip(td_errors) {
            debug_assert!(td.is_finite(), "non-finite TD error {td} for slot {idx}");
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.note_write(self.tree.get(idx), p as f64);
            self.tree.set_leaf(idx, p as f64);
            if p > batch_max {
                batch_max = p;
            }
        }
        self.tree.refresh_leaves(indices, &mut self.refresh_scratch);
        self.max_priority = batch_max;
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::Per
    }

    fn priority_of(&self, idx: usize) -> f32 {
        self.tree.get(idx) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    fn filled(n: usize) -> (PerReplay, Rng) {
        let mut rng = Rng::new(0);
        let mut mem = PerReplay::new(n, PerParams::default());
        for i in 0..n {
            mem.push(exp(i as f32), &mut rng);
        }
        (mem, rng)
    }

    #[test]
    fn new_experiences_get_max_priority() {
        let (mem, _) = filled(8);
        for i in 0..8 {
            assert_eq!(mem.priority_of(i), 1.0);
        }
    }

    #[test]
    fn high_priority_sampled_more() {
        let (mut mem, mut rng) = filled(100);
        // give slot 7 a huge TD error
        mem.update_priorities(&[7], &[100.0]);
        let mut count7 = 0usize;
        let total = 500 * 64;
        for _ in 0..500 {
            count7 += mem
                .sample(64, &mut rng)
                .indices
                .iter()
                .filter(|&&i| i == 7)
                .count();
        }
        // slot 7 holds ~ (100.01)^0.6 / (99 + that) of the mass
        let p7 = 100.01f64.powf(0.6);
        let expect = p7 / (99.0 * 1.01f64.powf(0.6) + p7);
        let got = count7 as f64 / total as f64;
        assert!((got - expect).abs() < 0.02, "got {got}, want {expect}");
    }

    #[test]
    fn beta_anneals_to_one() {
        let mut mem = PerReplay::new(8, PerParams { beta_steps: 10, ..Default::default() });
        let mut rng = Rng::new(1);
        mem.push(exp(0.0), &mut rng);
        assert!((mem.beta() - 0.4).abs() < 1e-6);
        for _ in 0..20 {
            mem.sample(4, &mut rng);
        }
        assert!((mem.beta() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weights_bounded_by_one() {
        let (mut mem, mut rng) = filled(64);
        mem.update_priorities(&[3, 9], &[5.0, 0.001]);
        let b = mem.sample(32, &mut rng);
        assert!(b.is_weights.iter().all(|&w| w > 0.0 && w <= 1.0 + 1e-5));
    }

    #[test]
    fn priority_floor_keeps_everything_samplable() {
        let (mut mem, mut rng) = filled(16);
        let idx: Vec<usize> = (0..16).collect();
        mem.update_priorities(&idx, &[0.0; 16]);
        // all priorities = eps^alpha > 0; sampling must still work
        let b = mem.sample(8, &mut rng);
        assert_eq!(b.indices.len(), 8);
        assert!(mem.tree().total() > 0.0);
    }

    #[test]
    fn min_cache_refreshes_when_min_slot_is_raised() {
        // regression: the cached min used to only ever go down, so raising
        // the minimum-priority slot left `max_w` computed from a dead
        // value and every IS weight silently shrank.
        let (mut mem, mut rng) = filled(16);
        mem.update_priorities(&[3], &[100.0]); // make the others the min
        mem.update_priorities(&[5], &[-0.5]); // irrelevant churn
        mem.sample(8, &mut rng); // warm the cache
        let tiny = super::super::priority_from_td(0.0, 1e-2, 0.6) as f64;
        // drive slot 5 far below everything, warm the cache on it...
        let idx: Vec<usize> = (0..16).collect();
        let mut tds = vec![1.0f32; 16];
        tds[5] = 0.0;
        mem.update_priorities(&idx, &tds);
        mem.sample(8, &mut rng);
        assert!((mem.min_cache_for_test() - tiny).abs() < 1e-9);
        // ...then raise it: the cache must follow the true minimum up
        mem.update_priorities(&[5], &[1.0]);
        let want = mem.tree().min_nonzero(16);
        assert!(
            (mem.min_cache_for_test() - want).abs() < 1e-12,
            "cache {} vs true min {}",
            mem.min_cache_for_test(),
            want
        );
        assert!(mem.min_cache_for_test() > tiny);
    }

    #[test]
    fn min_cache_refreshes_on_ring_wrap_overwrite() {
        // regression: overwriting the min-priority slot on ring wrap left
        // the cache pointing at the evicted value.
        let mut rng = Rng::new(3);
        let mut mem = PerReplay::new(8, PerParams::default());
        for i in 0..8 {
            mem.push(exp(i as f32), &mut rng);
        }
        let mut tds = vec![2.0f32; 8];
        tds[0] = 0.0; // slot 0 becomes the unique minimum
        let idx: Vec<usize> = (0..8).collect();
        mem.update_priorities(&idx, &tds);
        mem.sample(4, &mut rng); // cache now holds slot 0's tiny priority
        let stale = mem.min_cache_for_test();
        // wrap: the next push lands in slot 0 with max priority
        mem.push(exp(9.0), &mut rng);
        let want = mem.tree().min_nonzero(8);
        assert!(
            (mem.min_cache_for_test() - want).abs() < 1e-12,
            "cache {} vs true min {} (stale was {stale})",
            mem.min_cache_for_test(),
            want
        );
        // and IS weights for equal-priority slots must be ~1, not damped
        let b = mem.sample(4, &mut rng);
        for (&i, &w) in b.indices.iter().zip(&b.is_weights) {
            if (mem.priority_of(i) - mem.priority_of(1)).abs() < 1e-6 {
                assert!(w > 0.99, "slot {i}: weight {w} damped by stale min");
            }
        }
    }

    #[test]
    fn stratified_sampling_spans_the_range() {
        let (mut mem, mut rng) = filled(1000);
        let b = mem.sample(64, &mut rng);
        // with equal priorities, stratified draws must be spread out
        let lo = b.indices.iter().filter(|&&i| i < 500).count();
        assert!(lo > 20 && lo < 44, "lo half draws: {lo}");
    }
}
