//! Predictive PER (arXiv:2011.13093) — priority/diversity balancing.
//!
//! Two deviations from vanilla PER:
//!
//! 1. **Predicted entry priorities**: instead of admitting every new
//!    transition at the historical max priority (which lets one stale
//!    outlier dominate admission for a long time), new transitions enter
//!    at a priority *predicted* from an exponential moving average of
//!    recent |TD| errors — a cheap stand-in for the paper's TD-predictor
//!    network that keeps admission calibrated to the current loss scale.
//! 2. **Diversity floor**: every priority update clamps priorities from
//!    below at `div_floor` times the current *mean* priority, bounding
//!    the sampling-distribution skew so low-TD transitions keep a real
//!    chance of being replayed (the paper's anti-"priority collapse"
//!    mechanism).
//!
//! Sampling is stratified sum-tree sampling with unit importance weights;
//! the diversity floor plays the role the IS correction plays in PER.

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use super::sum_tree::SumTree;
use super::traits::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::Rng;

/// Predictive-PER hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct PperParams {
    /// Priority exponent α (shared with PER).
    pub alpha: f32,
    /// Priority floor ε.
    pub eps: f32,
    /// EMA factor for the |TD| predictor (closer to 1 = slower).
    pub ema_decay: f32,
    /// Diversity floor as a fraction of the mean priority, in [0, 1).
    pub div_floor: f32,
}

impl Default for PperParams {
    fn default() -> Self {
        PperParams { alpha: 0.6, eps: 1e-2, ema_decay: 0.95, div_floor: 0.02 }
    }
}

/// Predictive PER memory.
#[derive(Debug)]
pub struct PperReplay {
    ring: ExperienceRing,
    tree: SumTree,
    params: PperParams,
    /// EMA of recent |TD| errors — the entry-priority predictor.
    ema_td: f64,
    /// Ancestor-node scratch for [`SumTree::refresh_leaves`].
    refresh_scratch: Vec<usize>,
}

impl PperReplay {
    pub fn new(capacity: usize, params: PperParams) -> Self {
        PperReplay {
            ring: ExperienceRing::new(capacity, 4),
            tree: SumTree::new(capacity),
            params,
            // seeded to 1.0 like PER's initial max priority: early pushes
            // enter with weight before any TD error has been observed
            ema_td: 1.0,
            refresh_scratch: Vec::new(),
        }
    }

    /// Direct access to the priorities (studies/tests).
    pub fn tree(&self) -> &SumTree {
        &self.tree
    }

    /// Current |TD| EMA (the predictor state).
    pub fn predicted_td(&self) -> f64 {
        self.ema_td
    }

    /// Predicted priority for a new transition: the EMA pushed through
    /// the same `(|td| + ε)^α` transform stored priorities use.
    fn entry_priority(&self) -> f64 {
        (self.ema_td + self.params.eps as f64).powf(self.params.alpha as f64)
    }
}

impl ReplayMemory for PperReplay {
    fn push(&mut self, e: Experience, _rng: &mut Rng) -> usize {
        self.ring.ensure_dim(e.obs.len());
        let idx = self.ring.push(&e);
        self.tree.set(idx, self.entry_priority());
        idx
    }

    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        _rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        if batch.is_empty() {
            return;
        }
        self.ring.ensure_dim(batch.obs_dim());
        let start = slots.len();
        self.ring.push_batch(batch, slots);
        // the predictor only moves on TD feedback, so the entry priority
        // is constant across the batch: chunked leaf writes + one
        // deferred ancestor refresh, state-identical to the scalar loop
        let p = self.entry_priority();
        for &idx in &slots[start..] {
            self.tree.set_leaf(idx, p);
        }
        self.tree
            .refresh_leaves(&slots[start..], &mut self.refresh_scratch);
    }

    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch {
        let mut out = SampledBatch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let n = self.ring.len();
        assert!(n > 0, "cannot sample an empty memory");
        let total = self.tree.total();
        out.indices.clear();
        // stratified sampling over the floored priorities (PER §3.3)
        let seg = total / batch as f64;
        for j in 0..batch {
            let y = seg * j as f64 + rng.f64() * seg;
            out.indices.push(self.tree.find(y));
        }
        // unit weights: the diversity floor bounds the skew instead of an
        // IS correction
        out.is_weights.clear();
        out.is_weights.resize(batch, 1.0);
    }

    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]) {
        debug_assert_eq!(indices.len(), td_errors.len());
        // the floor is computed once per feedback call from the pre-update
        // mean priority — both paths do this, which is what keeps the
        // batched override state-identical
        let floor = self.params.div_floor as f64 * self.tree.total()
            / self.ring.len().max(1) as f64;
        for (&idx, &td) in indices.iter().zip(td_errors) {
            // a NaN/inf TD error must not poison the tree or the EMA;
            // treat it as a zero-error transition
            let td = if td.is_finite() { td } else { 0.0 };
            self.ema_td = self.params.ema_decay as f64 * self.ema_td
                + (1.0 - self.params.ema_decay as f64) * td.abs() as f64;
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.tree.set(idx, floor.max(p as f64));
        }
    }

    fn update_priorities_batch(&mut self, indices: &[usize], td_errors: &[f32]) {
        debug_assert_eq!(indices.len(), td_errors.len());
        let floor = self.params.div_floor as f64 * self.tree.total()
            / self.ring.len().max(1) as f64;
        for (&idx, &td) in indices.iter().zip(td_errors) {
            let td = if td.is_finite() { td } else { 0.0 };
            self.ema_td = self.params.ema_decay as f64 * self.ema_td
                + (1.0 - self.params.ema_decay as f64) * td.abs() as f64;
            let p = super::priority_from_td(td, self.params.eps, self.params.alpha);
            self.tree.set_leaf(idx, floor.max(p as f64));
        }
        self.tree.refresh_leaves(indices, &mut self.refresh_scratch);
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    fn ring(&self) -> &ExperienceRing {
        &self.ring
    }

    fn ring_mut(&mut self) -> &mut ExperienceRing {
        &mut self.ring
    }

    fn kind(&self) -> ReplayKind {
        ReplayKind::Pper
    }

    fn priority_of(&self, idx: usize) -> f32 {
        self.tree.get(idx) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    fn filled(n: usize) -> (PperReplay, Rng) {
        let mut rng = Rng::new(0);
        let mut mem = PperReplay::new(n, PperParams::default());
        for i in 0..n {
            mem.push(exp(i as f32), &mut rng);
        }
        (mem, rng)
    }

    #[test]
    fn entry_priority_tracks_the_td_ema() {
        let (mut mem, mut rng) = filled(32);
        let p0 = mem.priority_of(0);
        // feed consistently small TD errors: the predictor EMA drops...
        for _ in 0..64 {
            let b = mem.sample(8, &mut rng);
            let tds = vec![0.01f32; b.indices.len()];
            mem.update_priorities(&b.indices, &tds);
        }
        assert!(mem.predicted_td() < 0.1, "ema {}", mem.predicted_td());
        // ...so a new transition enters *below* the old entry priority
        let idx = mem.push(exp(99.0), &mut rng);
        assert!(
            mem.priority_of(idx) < p0,
            "entry priority did not follow the EMA down"
        );
    }

    #[test]
    fn diversity_floor_bounds_the_skew() {
        let (mut mem, _) = filled(64);
        // one huge outlier, everything else at zero TD
        let idx: Vec<usize> = (0..64).collect();
        let mut tds = vec![0.0f32; 64];
        tds[7] = 1e6;
        mem.update_priorities(&idx, &tds);
        // second feedback round: the floor is now derived from a mean the
        // outlier dominates, so it must catch every zero-TD slot
        let floor = 0.02 * mem.tree().total() / 64.0;
        let unfloored = super::super::priority_from_td(0.0, 1e-2, 0.6) as f64;
        assert!(floor > unfloored, "outlier too small to exercise the floor");
        mem.update_priorities(&idx, &tds);
        for i in 0..64 {
            if i != 7 {
                assert!(
                    (mem.priority_of(i) as f64 - floor).abs() < 1e-6,
                    "slot {i} not clamped to the diversity floor"
                );
            }
        }
        // the outlier still dominates, it just cannot starve the rest
        assert!(mem.priority_of(7) > mem.priority_of(0) * 100.0);
    }

    #[test]
    fn zero_td_everywhere_keeps_sampling_alive() {
        let (mut mem, mut rng) = filled(16);
        let idx: Vec<usize> = (0..16).collect();
        mem.update_priorities(&idx, &[0.0; 16]);
        let b = mem.sample(8, &mut rng);
        assert_eq!(b.indices.len(), 8);
        assert!(mem.tree().total() > 0.0);
        assert!(b.is_weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn non_finite_td_errors_are_neutralized() {
        let (mut mem, _) = filled(8);
        let ema_before = mem.predicted_td();
        mem.update_priorities(&[0, 1], &[f32::NAN, f32::NEG_INFINITY]);
        assert!(mem.predicted_td().is_finite());
        assert!(mem.predicted_td() <= ema_before);
        assert!(mem.tree().total().is_finite());
        assert!(mem.priority_of(0) > 0.0);
    }

    #[test]
    fn high_td_sampled_more() {
        let (mut mem, mut rng) = filled(100);
        let idx: Vec<usize> = (0..100).collect();
        let mut tds = vec![0.1f32; 100];
        tds[7] = 50.0;
        mem.update_priorities(&idx, &tds);
        let mut count7 = 0usize;
        let total = 300 * 32;
        for _ in 0..300 {
            count7 += mem
                .sample(32, &mut rng)
                .indices
                .iter()
                .filter(|&&i| i == 7)
                .count();
        }
        let got = count7 as f64 / total as f64;
        // slot 7 holds p7/(99*p_small + p7) of the mass
        let p7 = 50.01f64.powf(0.6);
        let ps = 0.11f64.powf(0.6);
        let expect = p7 / (99.0 * ps + p7);
        assert!((got - expect).abs() < 0.05, "got {got}, want {expect}");
    }
}
