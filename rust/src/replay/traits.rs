//! The replay-memory abstraction shared by all four ER techniques.

use super::experience::{Experience, ExperienceRing};
use crate::util::Rng;

/// Which replay technique to instantiate (CLI/config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReplayKind {
    Uniform,
    Per,
    AmperK,
    AmperFr,
}

impl ReplayKind {
    pub fn parse(s: &str) -> Option<ReplayKind> {
        match s {
            "uniform" | "uer" => Some(ReplayKind::Uniform),
            "per" => Some(ReplayKind::Per),
            "amper-k" | "amperk" | "knn" => Some(ReplayKind::AmperK),
            "amper-fr" | "amperfr" | "frnn" => Some(ReplayKind::AmperFr),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplayKind::Uniform => "uniform",
            ReplayKind::Per => "per",
            ReplayKind::AmperK => "amper-k",
            ReplayKind::AmperFr => "amper-fr",
        }
    }

    pub const ALL: [ReplayKind; 4] = [
        ReplayKind::Uniform,
        ReplayKind::Per,
        ReplayKind::AmperK,
        ReplayKind::AmperFr,
    ];
}

/// A sampled training batch: slot indices plus importance weights.
#[derive(Debug, Clone, Default)]
pub struct SampledBatch {
    /// Ring-slot index per sampled transition.
    pub indices: Vec<usize>,
    /// PER importance-sampling weights (all 1.0 for uniform/AMPER).
    pub is_weights: Vec<f32>,
}

/// Interface every ER technique implements (paper Fig 1: store / sample /
/// priority update).
pub trait ReplayMemory: Send {
    /// Store a transition (new experiences get max priority, per PER).
    fn push(&mut self, e: Experience, rng: &mut Rng) -> usize;

    /// Sample a training batch of `batch` transitions.
    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch;

    /// Feed back new TD errors for the sampled transitions.
    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]);

    /// Number of stored transitions.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage capacity.
    fn capacity(&self) -> usize;

    /// Access to the underlying transition storage for batch gathering.
    fn ring(&self) -> &ExperienceRing;

    /// Mutable ring access (used at init to set obs_dim).
    fn ring_mut(&mut self) -> &mut ExperienceRing;

    /// The technique's identity (for logs/CSV).
    fn kind(&self) -> ReplayKind;

    /// Current priority of slot `idx` (1.0 for uniform ER).
    fn priority_of(&self, idx: usize) -> f32;

    /// Accumulated *modeled* device time (ns) for hardware-backed
    /// memories ([`crate::replay::HwAmperReplay`]); `None` for software
    /// memories.
    fn modeled_device_ns(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in ReplayKind::ALL {
            assert_eq!(ReplayKind::parse(k.name()), Some(k));
        }
        assert_eq!(ReplayKind::parse("uer"), Some(ReplayKind::Uniform));
        assert_eq!(ReplayKind::parse("nope"), None);
    }
}
