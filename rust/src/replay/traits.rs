//! The replay-memory abstraction shared by all ER techniques.

use super::experience::{Experience, ExperienceBatch, ExperienceRing};
use crate::util::Rng;

/// A replay technique's identity: a thin newtype over the canonical
/// registry name, so the service protocol, CSV logs and
/// [`ReplayMemory::kind`] stay stable while the set of techniques is
/// open — new ones register a
/// [`ReplayDescriptor`](super::registry::ReplayDescriptor) and are
/// immediately parseable here, with no match arms to extend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReplayKind(&'static str);

impl ReplayKind {
    // Built-in techniques as associated consts so existing call sites
    // (`ReplayKind::Per`, ...) read exactly as the old enum variants did.
    #[allow(non_upper_case_globals)]
    pub const Uniform: ReplayKind = ReplayKind("uniform");
    #[allow(non_upper_case_globals)]
    pub const Per: ReplayKind = ReplayKind("per");
    #[allow(non_upper_case_globals)]
    pub const AmperK: ReplayKind = ReplayKind("amper-k");
    #[allow(non_upper_case_globals)]
    pub const AmperFr: ReplayKind = ReplayKind("amper-fr");
    #[allow(non_upper_case_globals)]
    pub const Dpsr: ReplayKind = ReplayKind("dpsr");
    #[allow(non_upper_case_globals)]
    pub const Dual: ReplayKind = ReplayKind("dual");
    #[allow(non_upper_case_globals)]
    pub const Pper: ReplayKind = ReplayKind("pper");

    /// Parse a CLI/config name (case-insensitive: `"PER"` == `"per"`).
    /// Resolves through the technique registry, so names and aliases of
    /// dynamically registered techniques parse too.
    pub fn parse(s: &str) -> Option<ReplayKind> {
        super::registry::find(s).map(|d| ReplayKind(d.name))
    }

    /// The accepted names (canonical + aliases), for CLI/config error
    /// messages. Generated from the registry.
    pub fn valid_names() -> String {
        super::registry::valid_names()
    }

    /// Wrap a canonical registry name (descriptor implementations).
    pub const fn from_name(name: &'static str) -> ReplayKind {
        ReplayKind(name)
    }

    pub fn name(&self) -> &'static str {
        self.0
    }
}

/// Global slot addressing for sharded replay deployments.
///
/// A sharded service partitions one logical ER memory over N single-owner
/// shard workers (one search/write port per bank, as in the paper's
/// hardware). Batch replies must carry indices a learner can hand back to
/// `update_priorities` without knowing the shard layout, so every index
/// crossing the service boundary encodes `(shard, slot)` in one `usize`:
/// the shard id lives in the top [`SHARD_BITS`] bits, the in-shard slot
/// in the remaining low bits. Shard 0 therefore encodes to the identity,
/// so unsharded code (and every existing test) is unaffected.
pub mod global_index {
    /// Bits reserved for the shard id (top bits).
    pub const SHARD_BITS: u32 = 12;
    /// Shift placing the shard id above the slot bits.
    pub const SHARD_SHIFT: u32 = usize::BITS - SHARD_BITS;
    /// Maximum shard count addressable by the encoding.
    pub const MAX_SHARDS: usize = 1 << SHARD_BITS;
    /// Maximum in-shard slot index addressable by the encoding.
    pub const MAX_SLOT: usize = (1 << SHARD_SHIFT) - 1;

    /// Pack `(shard, slot)` into one global index.
    #[inline]
    pub fn encode(shard: usize, slot: usize) -> usize {
        debug_assert!(shard < MAX_SHARDS, "shard {shard} exceeds {MAX_SHARDS}");
        debug_assert!(slot <= MAX_SLOT, "slot {slot} exceeds {MAX_SLOT}");
        (shard << SHARD_SHIFT) | slot
    }

    /// Unpack a global index into `(shard, slot)`.
    #[inline]
    pub fn decode(global: usize) -> (usize, usize) {
        (global >> SHARD_SHIFT, global & MAX_SLOT)
    }
}

/// A sampled training batch: slot indices plus importance weights.
#[derive(Debug, Clone, Default)]
pub struct SampledBatch {
    /// Ring-slot index per sampled transition.
    pub indices: Vec<usize>,
    /// PER importance-sampling weights (all 1.0 for uniform/AMPER).
    pub is_weights: Vec<f32>,
}

impl SampledBatch {
    /// Drop all rows, keeping the allocations (scratch reuse in service
    /// workers and agent hot loops).
    pub fn clear(&mut self) {
        self.indices.clear();
        self.is_weights.clear();
    }
}

/// Interface every ER technique implements (paper Fig 1: store / sample /
/// priority update).
///
/// The batch-first methods (`push_batch` / `sample_into` /
/// `update_priorities_batch`) are the native unit of the data path; every
/// one has a scalar-loop default so wrappers ([`super::NStepReplay`])
/// and future techniques stay correct, and every concrete technique
/// overrides them with an amortized implementation that is
/// **state-identical** to the scalar loop (pinned by the
/// `batch_equivalence` integration suite).
pub trait ReplayMemory: Send {
    /// Store a transition (new experiences get max priority, per PER).
    fn push(&mut self, e: Experience, rng: &mut Rng) -> usize;

    /// Store a whole batch, appending the written slot indices (in row
    /// order) to `slots`. Default: scalar loop over [`Self::push`].
    fn push_batch(
        &mut self,
        batch: &ExperienceBatch,
        rng: &mut Rng,
        slots: &mut Vec<usize>,
    ) {
        for row in 0..batch.len() {
            slots.push(self.push(batch.get(row).to_experience(), rng));
        }
    }

    /// Sample a training batch of `batch` transitions.
    fn sample(&mut self, batch: usize, rng: &mut Rng) -> SampledBatch;

    /// Sample into a caller-owned buffer (`out` is cleared first), so hot
    /// loops reuse the index/weight allocations across calls. Default:
    /// delegates to [`Self::sample`].
    fn sample_into(&mut self, batch: usize, rng: &mut Rng, out: &mut SampledBatch) {
        let b = self.sample(batch, rng);
        out.clear();
        out.indices.extend_from_slice(&b.indices);
        out.is_weights.extend_from_slice(&b.is_weights);
    }

    /// Feed back new TD errors for the sampled transitions.
    fn update_priorities(&mut self, indices: &[usize], td_errors: &[f32]);

    /// Batched TD-error feedback: one pass over the batch with
    /// per-batch (not per-element) refresh of derived state. Default:
    /// delegates to [`Self::update_priorities`].
    fn update_priorities_batch(&mut self, indices: &[usize], td_errors: &[f32]) {
        self.update_priorities(indices, td_errors);
    }

    /// Number of stored transitions.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage capacity.
    fn capacity(&self) -> usize;

    /// Access to the underlying transition storage for batch gathering.
    fn ring(&self) -> &ExperienceRing;

    /// Mutable ring access (used at init to set obs_dim).
    fn ring_mut(&mut self) -> &mut ExperienceRing;

    /// The technique's identity (for logs/CSV).
    fn kind(&self) -> ReplayKind;

    /// Current priority of slot `idx` (1.0 for uniform ER).
    fn priority_of(&self, idx: usize) -> f32;

    /// Accumulated *modeled* device time (ns) for hardware-backed
    /// memories ([`crate::replay::HwAmperReplay`]); `None` for software
    /// memories.
    fn modeled_device_ns(&self) -> Option<f64> {
        None
    }

    /// Install a worker pool for the memory's internal batch passes (the
    /// AMPER CSP chunk-sort uses it on large memories; serve hands every
    /// shard the engine's pool so shard-local builds share workers).
    /// Default: no-op — techniques without a parallelizable pass, and the
    /// hardware-modeled memory, ignore it. Must never change *what* is
    /// sampled, only how fast (pinned by `batch_equivalence`).
    fn set_thread_pool(&mut self, _pool: std::sync::Arc<crate::runtime::ThreadPool>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_index_roundtrip() {
        use global_index::*;
        for shard in [0usize, 1, 7, 255, MAX_SHARDS - 1] {
            for slot in [0usize, 1, 63, 100_000, MAX_SLOT] {
                let g = encode(shard, slot);
                assert_eq!(decode(g), (shard, slot), "shard {shard} slot {slot}");
            }
        }
        // shard 0 is the identity (unsharded compatibility)
        assert_eq!(encode(0, 42), 42);
        assert_eq!(decode(1234), (0, 1234));
        // distinct (shard, slot) pairs never collide in a realistic range
        let mut seen = std::collections::HashSet::new();
        for shard in 0..16 {
            for slot in 0..128 {
                assert!(seen.insert(encode(shard, slot)));
            }
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for d in crate::replay::registry::all() {
            let k = ReplayKind::from_name(d.name);
            assert_eq!(ReplayKind::parse(k.name()), Some(k));
        }
        assert_eq!(ReplayKind::parse("uer"), Some(ReplayKind::Uniform));
        assert_eq!(ReplayKind::parse("nope"), None);
    }

    #[test]
    fn kind_parse_is_case_insensitive() {
        assert_eq!(ReplayKind::parse("PER"), Some(ReplayKind::Per));
        assert_eq!(ReplayKind::parse("Uniform"), Some(ReplayKind::Uniform));
        assert_eq!(ReplayKind::parse("AMPER-FR"), Some(ReplayKind::AmperFr));
        assert_eq!(ReplayKind::parse("AmperK"), Some(ReplayKind::AmperK));
        assert_eq!(ReplayKind::parse("DPSR"), Some(ReplayKind::Dpsr));
        // every canonical name survives an uppercase round trip
        for d in crate::replay::registry::all() {
            let k = ReplayKind::from_name(d.name);
            assert_eq!(
                ReplayKind::parse(&k.name().to_ascii_uppercase()),
                Some(k)
            );
            assert!(ReplayKind::valid_names().contains(k.name()));
        }
    }
}
