//! The open replay-technique registry: every technique — built-in or
//! registered at runtime — is described by one [`ReplayDescriptor`]
//! (name, aliases, help line, paper reference, parameter namespace and a
//! `build` function). Config parsing, CLI errors, the serve paths, the
//! studies and the docs table all resolve through here, so adding a
//! technique is **one registration** with no match arms to extend
//! anywhere else (pinned by `tests/registry.rs`, which drives a dummy
//! descriptor through config parse → build → serve).

use std::sync::{OnceLock, RwLock};

use super::amper::{AmperFr, AmperK, AmperParams, Variant};
use super::dpsr::{DpsrParams, DpsrReplay};
use super::dual::{DualParams, DualReplay};
use super::hw_backed::HwAmperReplay;
use super::per::{PerParams, PerReplay};
use super::pper::{PperParams, PperReplay};
use super::traits::ReplayMemory;
use super::uniform::UniformReplay;

/// Unified parameter bag for every registered technique: one field per
/// built-in namespace plus a free-form `extra` list for dynamically
/// registered techniques. Parsed from the `replay.<technique>.<field>`
/// config namespace (legacy `per.*` / `amper.*` keys route here too).
#[derive(Debug, Clone, Default)]
pub struct ReplayParams {
    pub per: PerParams,
    pub amper: AmperParams,
    pub dpsr: DpsrParams,
    pub dual: DualParams,
    pub pper: PperParams,
    /// `(field, value)` pairs for techniques registered outside the
    /// crate; their `set_param` hooks stash raw strings here.
    pub extra: Vec<(String, String)>,
}

impl ReplayParams {
    /// Look up a raw `extra` field set for a non-built-in technique.
    pub fn extra_get(&self, field: &str) -> Option<&str> {
        self.extra
            .iter()
            .rev()
            .find(|(f, _)| f == field)
            .map(|(_, v)| v.as_str())
    }
}

/// Everything the config layer, CLI, serve paths and docs need to know
/// about one replay technique.
#[derive(Debug, Clone, Copy)]
pub struct ReplayDescriptor {
    /// Canonical name — what [`ReplayKind::name`] reports and what the
    /// wire protocol / CSV logs carry.
    ///
    /// [`ReplayKind::name`]: super::ReplayKind::name
    pub name: &'static str,
    /// Accepted aliases (parse-only; never reported back).
    pub aliases: &'static [&'static str],
    /// One-line help for CLI listings.
    pub help: &'static str,
    /// Paper reference (README table).
    pub paper: &'static str,
    /// Config namespace under `replay.<ns>.<field>` (shared namespaces
    /// are allowed: both AMPER variants read `replay.amper.*`).
    pub param_ns: &'static str,
    /// Accepted parameter fields (README table + unknown-key errors).
    pub param_fields: &'static [&'static str],
    /// Whether `amper serve` / `replay-serve` can host it (all software
    /// techniques are servable through the batch-first trait).
    pub servable: bool,
    /// Whether the sharded service can partition it.
    pub shardable: bool,
    /// Construct the memory.
    pub build: fn(usize, &ReplayParams) -> Box<dyn ReplayMemory>,
    /// Optional hardware-backed construction (`hw_replay = true`); `None`
    /// falls back to [`Self::build`].
    pub hw_build: Option<fn(usize, &ReplayParams, u64) -> Box<dyn ReplayMemory>>,
    /// Set one `replay.<ns>.<field>` parameter from its string value.
    pub set_param: fn(&mut ReplayParams, &str, &str) -> Result<(), String>,
}

static REGISTRY: OnceLock<RwLock<Vec<ReplayDescriptor>>> = OnceLock::new();

fn registry() -> &'static RwLock<Vec<ReplayDescriptor>> {
    REGISTRY.get_or_init(|| RwLock::new(builtins()))
}

/// Snapshot of every registered descriptor, in registration order
/// (built-ins first).
pub fn all() -> Vec<ReplayDescriptor> {
    registry().read().expect("replay registry poisoned").clone()
}

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<ReplayDescriptor> {
    let reg = registry().read().expect("replay registry poisoned");
    reg.iter()
        .find(|d| {
            d.name.eq_ignore_ascii_case(name)
                || d.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
        })
        .copied()
}

/// Case-insensitive lookup by parameter namespace (falling back to
/// name/alias, so `replay.dpsr.x` and `dpsr.x` both route).
pub fn find_by_ns(ns: &str) -> Option<ReplayDescriptor> {
    let reg = registry().read().expect("replay registry poisoned");
    reg.iter()
        .find(|d| d.param_ns.eq_ignore_ascii_case(ns))
        .copied()
        .or_else(|| {
            drop(reg);
            find(ns)
        })
}

/// Register a new technique. Fails on a name/alias collision with any
/// existing descriptor.
pub fn register(d: ReplayDescriptor) -> Result<(), String> {
    let mut reg = registry().write().expect("replay registry poisoned");
    let mut new_names = vec![d.name];
    new_names.extend_from_slice(d.aliases);
    for existing in reg.iter() {
        let mut names = vec![existing.name];
        names.extend_from_slice(existing.aliases);
        for n in &names {
            if new_names.iter().any(|m| m.eq_ignore_ascii_case(n)) {
                return Err(format!(
                    "replay technique name '{n}' already registered \
                     (by '{}')",
                    existing.name
                ));
            }
        }
    }
    reg.push(d);
    Ok(())
}

/// The accepted names for CLI/config error messages, in the
/// `name|alias1|alias2, ...` style.
pub fn valid_names() -> String {
    let reg = registry().read().expect("replay registry poisoned");
    reg.iter()
        .map(|d| {
            let mut s = d.name.to_string();
            for a in d.aliases {
                s.push('|');
                s.push_str(a);
            }
            s
        })
        .collect::<Vec<_>>()
        .join(", ")
}

/// Shared unknown-field error naming the technique's accepted fields.
pub fn unknown_field_error(tech: &str, field: &str, accepted: &[&str]) -> String {
    if accepted.is_empty() {
        format!(
            "unknown field '{field}' for replay technique '{tech}' \
             (it takes no parameters)"
        )
    } else {
        format!(
            "unknown field '{field}' for replay technique '{tech}' \
             (accepted: {})",
            accepted.join(", ")
        )
    }
}

// ---- built-in descriptors ---------------------------------------------

const UNIFORM_FIELDS: &[&str] = &[];
const PER_FIELDS: &[&str] = &["alpha", "beta0", "beta_steps", "eps"];
const AMPER_FIELDS: &[&str] =
    &["m", "lambda", "lambda_prime", "eps", "alpha", "csp_cap"];
const DPSR_FIELDS: &[&str] =
    &["alpha", "eps", "decay", "recycle_frac", "recycle_candidates"];
const DUAL_FIELDS: &[&str] = &["st_frac", "lt_frac", "promote_margin"];
const PPER_FIELDS: &[&str] = &["alpha", "eps", "ema_decay", "div_floor"];

fn bad_value(tech: &str, field: &str, val: &str) -> String {
    format!("invalid value '{val}' for key 'replay.{tech}.{field}'")
}

fn set_uniform(_p: &mut ReplayParams, field: &str, _v: &str) -> Result<(), String> {
    Err(unknown_field_error("uniform", field, UNIFORM_FIELDS))
}

fn set_per(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    let bad = || bad_value("per", field, val);
    match field {
        "alpha" => p.per.alpha = val.parse().map_err(|_| bad())?,
        "beta0" => p.per.beta0 = val.parse().map_err(|_| bad())?,
        "beta_steps" => p.per.beta_steps = val.parse().map_err(|_| bad())?,
        "eps" => p.per.eps = val.parse().map_err(|_| bad())?,
        _ => return Err(unknown_field_error("per", field, PER_FIELDS)),
    }
    Ok(())
}

fn set_amper(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    let bad = || bad_value("amper", field, val);
    match field {
        "m" => p.amper.m = val.parse().map_err(|_| bad())?,
        "lambda" => p.amper.lambda = val.parse().map_err(|_| bad())?,
        "lambda_prime" => p.amper.lambda_prime = val.parse().map_err(|_| bad())?,
        "eps" => p.amper.eps = val.parse().map_err(|_| bad())?,
        "alpha" => p.amper.alpha = val.parse().map_err(|_| bad())?,
        "csp_cap" => p.amper.csp_cap = val.parse().map_err(|_| bad())?,
        _ => return Err(unknown_field_error("amper", field, AMPER_FIELDS)),
    }
    Ok(())
}

fn set_dpsr(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    let bad = || bad_value("dpsr", field, val);
    match field {
        "alpha" => p.dpsr.alpha = val.parse().map_err(|_| bad())?,
        "eps" => p.dpsr.eps = val.parse().map_err(|_| bad())?,
        "decay" => p.dpsr.decay = val.parse().map_err(|_| bad())?,
        "recycle_frac" => p.dpsr.recycle_frac = val.parse().map_err(|_| bad())?,
        "recycle_candidates" => {
            p.dpsr.recycle_candidates = val.parse().map_err(|_| bad())?;
            if p.dpsr.recycle_candidates == 0 {
                return Err(bad());
            }
        }
        _ => return Err(unknown_field_error("dpsr", field, DPSR_FIELDS)),
    }
    Ok(())
}

fn set_dual(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    let bad = || bad_value("dual", field, val);
    match field {
        "st_frac" => {
            p.dual.st_frac = val.parse().map_err(|_| bad())?;
            if !(p.dual.st_frac > 0.0 && p.dual.st_frac < 1.0) {
                return Err(bad());
            }
        }
        "lt_frac" => {
            p.dual.lt_frac = val.parse().map_err(|_| bad())?;
            if !(0.0..=1.0).contains(&p.dual.lt_frac) {
                return Err(bad());
            }
        }
        "promote_margin" => {
            p.dual.promote_margin = val.parse().map_err(|_| bad())?
        }
        _ => return Err(unknown_field_error("dual", field, DUAL_FIELDS)),
    }
    Ok(())
}

fn set_pper(p: &mut ReplayParams, field: &str, val: &str) -> Result<(), String> {
    let bad = || bad_value("pper", field, val);
    match field {
        "alpha" => p.pper.alpha = val.parse().map_err(|_| bad())?,
        "eps" => p.pper.eps = val.parse().map_err(|_| bad())?,
        "ema_decay" => {
            p.pper.ema_decay = val.parse().map_err(|_| bad())?;
            if !(0.0..1.0).contains(&p.pper.ema_decay) {
                return Err(bad());
            }
        }
        "div_floor" => {
            p.pper.div_floor = val.parse().map_err(|_| bad())?;
            if !(0.0..1.0).contains(&p.pper.div_floor) {
                return Err(bad());
            }
        }
        _ => return Err(unknown_field_error("pper", field, PPER_FIELDS)),
    }
    Ok(())
}

fn hw_accel_config(p: &AmperParams) -> crate::hardware::accelerator::AccelConfig {
    crate::hardware::accelerator::AccelConfig {
        m: p.m,
        lambda: p.lambda,
        lambda_prime: p.lambda_prime,
        csb_capacity: p.csp_cap,
    }
}

fn builtins() -> Vec<ReplayDescriptor> {
    vec![
        ReplayDescriptor {
            name: "uniform",
            aliases: &["uer"],
            help: "uniform experience replay (the pre-PER baseline)",
            paper: "Lin 1992",
            param_ns: "uniform",
            param_fields: UNIFORM_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, _p| Box::new(UniformReplay::new(cap)),
            hw_build: None,
            set_param: set_uniform,
        },
        ReplayDescriptor {
            name: "per",
            aliases: &[],
            help: "prioritized experience replay on a sum tree",
            paper: "arXiv:1511.05952",
            param_ns: "per",
            param_fields: PER_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(PerReplay::new(cap, p.per)),
            hw_build: None,
            set_param: set_per,
        },
        ReplayDescriptor {
            name: "amper-k",
            aliases: &["amperk", "knn"],
            help: "AMPER with kNN candidate-set selection (Algorithm 1)",
            paper: "arXiv:2207.07791",
            param_ns: "amper",
            param_fields: AMPER_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(AmperK::new(cap, p.amper)),
            hw_build: Some(|cap, p, seed| {
                Box::new(HwAmperReplay::new(
                    cap,
                    hw_accel_config(&p.amper),
                    Variant::Knn,
                    seed as u32,
                ))
            }),
            set_param: set_amper,
        },
        ReplayDescriptor {
            name: "amper-fr",
            aliases: &["amperfr", "frnn"],
            help: "AMPER with fixed-radius-NN candidate-set selection",
            paper: "arXiv:2207.07791",
            param_ns: "amper",
            param_fields: AMPER_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(AmperFr::new(cap, p.amper)),
            hw_build: Some(|cap, p, seed| {
                Box::new(HwAmperReplay::new(
                    cap,
                    hw_accel_config(&p.amper),
                    Variant::Frnn,
                    seed as u32,
                ))
            }),
            set_param: set_amper,
        },
        ReplayDescriptor {
            name: "dpsr",
            aliases: &[],
            help: "double prioritization (sampled-priority decay) + state \
                   recycling of low-priority slots",
            paper: "arXiv:2007.03961",
            param_ns: "dpsr",
            param_fields: DPSR_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(DpsrReplay::new(cap, p.dpsr)),
            hw_build: None,
            set_param: set_dpsr,
        },
        ReplayDescriptor {
            name: "dual",
            aliases: &["dual-memory"],
            help: "short-term/long-term dual memory with episode-return-\
                   gated promotion",
            paper: "arXiv:1907.06396",
            param_ns: "dual",
            param_fields: DUAL_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(DualReplay::new(cap, p.dual)),
            hw_build: None,
            set_param: set_dual,
        },
        ReplayDescriptor {
            name: "pper",
            aliases: &["predictive-per"],
            help: "predictive PER: TD-EMA-driven entry priorities with a \
                   diversity floor",
            paper: "arXiv:2011.13093",
            param_ns: "pper",
            param_fields: PPER_FIELDS,
            servable: true,
            shardable: true,
            build: |cap, p| Box::new(PperReplay::new(cap, p.pper)),
            hw_build: None,
            set_param: set_pper,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_seven_techniques() {
        let names: Vec<&str> = all().iter().map(|d| d.name).collect();
        assert_eq!(
            names,
            ["uniform", "per", "amper-k", "amper-fr", "dpsr", "dual", "pper"]
        );
    }

    #[test]
    fn find_resolves_names_and_aliases_case_insensitively() {
        assert_eq!(find("PER").unwrap().name, "per");
        assert_eq!(find("uer").unwrap().name, "uniform");
        assert_eq!(find("KNN").unwrap().name, "amper-k");
        assert_eq!(find("dual-memory").unwrap().name, "dual");
        assert_eq!(find("Predictive-PER").unwrap().name, "pper");
        assert!(find("bogus").is_none());
    }

    #[test]
    fn find_by_ns_routes_shared_and_fallback_namespaces() {
        // both AMPER variants share the "amper" namespace; the first
        // registrant answers and both use the same set_param
        assert_eq!(find_by_ns("amper").unwrap().name, "amper-k");
        assert_eq!(find_by_ns("dpsr").unwrap().name, "dpsr");
        // falls back to name/alias lookup
        assert_eq!(find_by_ns("frnn").unwrap().name, "amper-fr");
    }

    #[test]
    fn valid_names_lists_every_builtin() {
        let names = valid_names();
        for d in all() {
            assert!(names.contains(d.name), "{} missing from {names}", d.name);
        }
        assert!(names.contains("uniform|uer"));
    }

    #[test]
    fn set_param_roundtrips_defaults_and_names_accepted_fields() {
        let mut p = ReplayParams::default();
        (find("per").unwrap().set_param)(&mut p, "alpha", "0.9").unwrap();
        assert!((p.per.alpha - 0.9).abs() < 1e-6);
        (find("dpsr").unwrap().set_param)(&mut p, "recycle_frac", "0.25")
            .unwrap();
        assert!((p.dpsr.recycle_frac - 0.25).abs() < 1e-6);
        let err = (find("dpsr").unwrap().set_param)(&mut p, "nope", "1")
            .unwrap_err();
        assert!(err.contains("recycle_frac") && err.contains("dpsr"), "{err}");
        let err = (find("uniform").unwrap().set_param)(&mut p, "x", "1")
            .unwrap_err();
        assert!(err.contains("no parameters"), "{err}");
    }

    #[test]
    fn register_rejects_collisions() {
        fn build(cap: usize, _p: &ReplayParams) -> Box<dyn ReplayMemory> {
            Box::new(UniformReplay::new(cap))
        }
        let d = ReplayDescriptor {
            name: "per",
            aliases: &[],
            help: "",
            paper: "",
            param_ns: "per2",
            param_fields: &[],
            servable: true,
            shardable: true,
            build,
            hw_build: None,
            set_param: set_uniform,
        };
        assert!(register(d).is_err());
        let d = ReplayDescriptor {
            name: "fresh-technique-x",
            aliases: &["uer"], // collides via alias
            ..d
        };
        assert!(register(d).is_err());
    }

    #[test]
    fn every_builtin_builds_and_samples() {
        let p = ReplayParams::default();
        let mut rng = crate::util::Rng::new(3);
        for d in all() {
            let mut mem = (d.build)(64, &p);
            for i in 0..32 {
                mem.push(
                    crate::replay::Experience {
                        obs: vec![i as f32; 4],
                        action: 0,
                        reward: 1.0,
                        next_obs: vec![i as f32; 4],
                        done: i % 10 == 9,
                    },
                    &mut rng,
                );
            }
            let b = mem.sample(8, &mut rng);
            assert_eq!(b.indices.len(), 8, "{}", d.name);
            assert_eq!(mem.kind().name(), d.name);
        }
    }
}
