//! Array-backed sum tree: the data structure behind PER's priority
//! sampling (paper Fig 2c). Internal nodes hold the sum of their children;
//! leaves hold priorities. `sample(y)` descends from the root comparing
//! the uniform draw against the left-child sum — O(log n) per sample and
//! per update, with the frequent, irregular access pattern the paper
//! identifies as the GPU/CPU bottleneck.

/// Fixed-capacity sum tree over `capacity` leaves (rounded up to a power
/// of two internally).
#[derive(Debug, Clone)]
pub struct SumTree {
    /// Number of leaves (power of two).
    leaves: usize,
    /// Flat heap layout: nodes[1] is the root; leaf i is nodes[leaves + i].
    nodes: Vec<f64>,
    /// Logical capacity requested by the caller.
    capacity: usize,
}

impl SumTree {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        let leaves = capacity.next_power_of_two();
        SumTree { leaves, nodes: vec![0.0; 2 * leaves], capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total priority mass (the root).
    #[inline]
    pub fn total(&self) -> f64 {
        self.nodes[1]
    }

    /// Priority of leaf `idx`.
    #[inline]
    pub fn get(&self, idx: usize) -> f64 {
        debug_assert!(idx < self.capacity);
        self.nodes[self.leaves + idx]
    }

    /// Set leaf `idx` to `priority`, updating the path to the root.
    ///
    /// Internal nodes are **recomputed from their children** (not
    /// delta-propagated), so every node is a pure function of the final
    /// leaves — a batch of writes followed by one ancestor refresh
    /// ([`Self::refresh_leaves`]) lands bit-identically to this per-leaf
    /// path, in any write order.
    pub fn set(&mut self, idx: usize, priority: f64) {
        debug_assert!(idx < self.capacity, "{idx} >= {}", self.capacity);
        debug_assert!(priority >= 0.0 && priority.is_finite());
        let mut node = self.leaves + idx;
        self.nodes[node] = priority;
        while node > 1 {
            node /= 2;
            self.nodes[node] = self.nodes[2 * node] + self.nodes[2 * node + 1];
        }
    }

    /// Write leaf `idx` without touching its ancestors — the batch-write
    /// half of the chunked update path. Call [`Self::refresh_leaves`]
    /// with every written index before the next `total`/`find`/`set`.
    #[inline]
    pub fn set_leaf(&mut self, idx: usize, priority: f64) {
        debug_assert!(idx < self.capacity, "{idx} >= {}", self.capacity);
        debug_assert!(priority >= 0.0 && priority.is_finite());
        self.nodes[self.leaves + idx] = priority;
    }

    /// Recompute the ancestors of a batch of leaf writes, level by level,
    /// visiting each shared ancestor **once** instead of once per leaf —
    /// the chunked replacement for per-leaf root-ward walks. `scratch`
    /// is reused across calls (holds at most `indices.len()` nodes).
    ///
    /// Because [`Self::set`] also recomputes from children, the tree
    /// state after `set_leaf × n + refresh_leaves` is bit-identical to
    /// `set × n` (pinned in `batch_equivalence`).
    pub fn refresh_leaves(&mut self, indices: &[usize], scratch: &mut Vec<usize>) {
        scratch.clear();
        for &idx in indices {
            debug_assert!(idx < self.capacity);
            scratch.push((self.leaves + idx) / 2);
        }
        while !scratch.is_empty() && scratch[0] >= 1 {
            scratch.sort_unstable();
            scratch.dedup();
            for i in 0..scratch.len() {
                let node = scratch[i];
                self.nodes[node] = self.nodes[2 * node] + self.nodes[2 * node + 1];
                scratch[i] = node / 2;
            }
            if scratch[0] == 0 {
                break; // just refreshed the root (node 1)
            }
        }
        scratch.clear();
    }

    /// The raw heap array (tests: whole-state bit comparison between the
    /// per-leaf and batched update paths).
    pub fn raw_nodes(&self) -> &[f64] {
        &self.nodes
    }

    /// Find the leaf whose cumulative-range contains `y ∈ [0, total)`.
    /// This is the tree-traversal the paper replaces (Fig 2c, red path).
    #[inline]
    pub fn find(&self, y: f64) -> usize {
        debug_assert!(y >= 0.0);
        let mut y = y.min(self.total() * (1.0 - 1e-12));
        let mut node = 1usize;
        while node < self.leaves {
            let left = 2 * node;
            let left_sum = self.nodes[left];
            if y < left_sum {
                node = left;
            } else {
                y -= left_sum;
                node = left + 1;
            }
        }
        (node - self.leaves).min(self.capacity - 1)
    }

    /// Minimum non-zero leaf priority over the first `n` leaves (for PER's
    /// max IS weight). O(n); cached by the caller when hot.
    pub fn min_nonzero(&self, n: usize) -> f64 {
        let mut m = f64::INFINITY;
        for i in 0..n.min(self.capacity) {
            let p = self.nodes[self.leaves + i];
            if p > 0.0 && p < m {
                m = p;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn total_is_sum_of_leaves() {
        let mut t = SumTree::new(5);
        for (i, p) in [3.0, 1.0, 5.0, 2.0, 0.5].iter().enumerate() {
            t.set(i, *p);
        }
        assert!((t.total() - 11.5).abs() < 1e-9);
        assert_eq!(t.get(2), 5.0);
    }

    #[test]
    fn find_matches_linear_scan() {
        let ps = [3.0, 1.0, 5.0, 2.0];
        let mut t = SumTree::new(4);
        for (i, p) in ps.iter().enumerate() {
            t.set(i, *p);
        }
        // paper Fig 2b: Y=4 falls into p2 (0-indexed leaf 1 boundary at 3..4)
        assert_eq!(t.find(0.0), 0);
        assert_eq!(t.find(2.999), 0);
        assert_eq!(t.find(3.0), 1);
        assert_eq!(t.find(4.0), 2);
        assert_eq!(t.find(8.999), 2);
        assert_eq!(t.find(9.0), 3);
        assert_eq!(t.find(10.999), 3);
    }

    #[test]
    fn update_rebalances() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 1.0);
        t.set(0, 10.0); // overwrite
        assert!((t.total() - 11.0).abs() < 1e-9);
        assert_eq!(t.find(9.5), 0);
        assert_eq!(t.find(10.5), 1);
    }

    #[test]
    fn sampling_frequencies_proportional_to_priorities() {
        let ps = [1.0f64, 2.0, 4.0, 8.0];
        let mut t = SumTree::new(4);
        for (i, p) in ps.iter().enumerate() {
            t.set(i, *p);
        }
        let mut rng = Rng::new(123);
        let mut counts = [0usize; 4];
        let trials = 200_000;
        for _ in 0..trials {
            counts[t.find(rng.f64() * t.total())] += 1;
        }
        let total: f64 = ps.iter().sum();
        for i in 0..4 {
            let expect = ps[i] / total;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "leaf {i}: got {got}, want {expect}"
            );
        }
    }

    #[test]
    fn non_power_of_two_capacity() {
        let mut t = SumTree::new(10);
        for i in 0..10 {
            t.set(i, 1.0);
        }
        assert!((t.total() - 10.0).abs() < 1e-9);
        assert_eq!(t.find(9.99), 9);
    }

    #[test]
    fn min_nonzero_skips_zeros() {
        let mut t = SumTree::new(8);
        t.set(1, 4.0);
        t.set(5, 0.25);
        assert_eq!(t.min_nonzero(8), 0.25);
        assert_eq!(t.min_nonzero(4), 4.0);
    }

    #[test]
    fn zero_total_find_is_safe() {
        // with zero total mass any leaf is acceptable; it must just be
        // in bounds and not panic
        let t = SumTree::new(4);
        assert!(t.find(0.0) < 4);
    }

    #[test]
    fn batched_refresh_matches_per_leaf_sets_bitwise() {
        // set_leaf × n + refresh_leaves must leave the whole heap array
        // bit-identical to per-leaf set × n — including shared ancestors
        // written by several leaves in the batch and repeated indices
        for cap in [1usize, 2, 7, 10, 64] {
            let mut rng = Rng::new(cap as u64);
            let mut a = SumTree::new(cap);
            let mut b = SumTree::new(cap);
            let mut scratch = Vec::new();
            for round in 0..6 {
                let indices: Vec<usize> =
                    (0..cap.min(8)).map(|_| rng.below(cap)).collect();
                let ps: Vec<f64> =
                    indices.iter().map(|_| rng.f64() * 10.0).collect();
                for (&i, &p) in indices.iter().zip(&ps) {
                    a.set(i, p);
                }
                for (&i, &p) in indices.iter().zip(&ps) {
                    b.set_leaf(i, p);
                }
                b.refresh_leaves(&indices, &mut scratch);
                let ab: Vec<u64> =
                    a.raw_nodes().iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u64> =
                    b.raw_nodes().iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb, "cap {cap} round {round}");
            }
        }
    }
}
