//! Experience-replay memories: the paper's problem domain.
//!
//! * [`UniformReplay`] — the classic uniform ER baseline (UER).
//! * [`PerReplay`] — Prioritized Experience Replay (Schaul et al. 2015) on
//!   an array-backed [`sum_tree::SumTree`]: the baseline AMPER competes
//!   against (paper §2.1, Fig 2c).
//! * [`AmperK`] / [`AmperFr`] — the paper's Algorithm 1: priority sampling
//!   approximated by uniform sampling over a *candidate set of priorities*
//!   (CSP) built with kNN / fixed-radius-NN selection (§3.2, §3.3).
//! * [`DpsrReplay`] — double prioritization + state recycling
//!   (arXiv:2007.03961).
//! * [`DualReplay`] — short-term/long-term dual memory with
//!   episode-return-gated promotion (arXiv:1907.06396).
//! * [`PperReplay`] — Predictive PER: TD-EMA entry priorities with a
//!   diversity floor (arXiv:2011.13093).
//!
//! All memories implement [`ReplayMemory`] so the agent, profiler and
//! benches can swap them freely, and each is described by a
//! [`registry::ReplayDescriptor`] in the open technique [`registry`] —
//! config keys, CLI names, serve paths and studies all resolve through
//! it, so adding a technique is one registration.

pub mod amper;
pub mod dpsr;
pub mod dual;
pub mod experience;
pub mod hw_backed;
pub mod nstep;
pub mod per;
pub mod pper;
pub mod registry;
pub mod sum_tree;
pub mod traits;
pub mod uniform;

pub use amper::{AmperFr, AmperK, AmperParams};
pub use dpsr::{DpsrParams, DpsrReplay};
pub use dual::{DualParams, DualReplay};
pub use experience::{
    Experience, ExperienceBatch, ExperienceRef, ExperienceRing, GatheredBatch,
};
pub use hw_backed::HwAmperReplay;
pub use nstep::NStepReplay;
pub use per::{PerParams, PerReplay};
pub use pper::{PperParams, PperReplay};
pub use registry::{ReplayDescriptor, ReplayParams};
pub use sum_tree::SumTree;
pub use traits::{global_index, ReplayKind, ReplayMemory, SampledBatch};
pub use uniform::UniformReplay;

use crate::util::Rng;

/// Construct a replay memory by kind with default parameters (batch-size
/// independent; the sampler takes the batch size per call).
pub fn make(kind: ReplayKind, capacity: usize) -> Box<dyn ReplayMemory> {
    build(kind, capacity, &ReplayParams::default())
}

/// Construct a replay memory by kind with explicit parameters, resolving
/// through the technique [`registry`].
///
/// Panics when `kind` names a technique that is not registered — a
/// `ReplayKind` can only be obtained from a canonical registry name, so
/// this indicates a descriptor was never registered.
pub fn build(
    kind: ReplayKind,
    capacity: usize,
    params: &ReplayParams,
) -> Box<dyn ReplayMemory> {
    let d = registry::find(kind.name()).unwrap_or_else(|| {
        panic!("replay technique '{}' is not registered", kind.name())
    });
    (d.build)(capacity, params)
}

/// Shared helper: priority from a TD error, `p = (|td| + eps)^alpha`.
#[inline]
pub fn priority_from_td(td: f32, eps: f32, alpha: f32) -> f32 {
    (td.abs() + eps).powf(alpha)
}

/// Seeded sanity driver used by integration tests and docs; exercised
/// against every registered technique via [`registry::all`].
pub fn smoke(kind: ReplayKind) -> usize {
    let mut rng = Rng::new(7);
    let mut mem = make(kind, 256);
    for i in 0..512 {
        let e = Experience {
            obs: vec![i as f32; 4],
            action: (i % 2) as u32,
            reward: 1.0,
            next_obs: vec![(i + 1) as f32; 4],
            done: i % 100 == 99,
        };
        mem.push(e, &mut rng);
    }
    let batch = mem.sample(64, &mut rng);
    batch.indices.len()
}

#[cfg(test)]
mod smoke_tests {
    use super::*;

    #[test]
    fn smoke_covers_every_registered_technique() {
        // resolve through the registry, not a hardcoded list, so a newly
        // registered technique joins the smoke coverage automatically
        for d in registry::all() {
            assert_eq!(smoke(ReplayKind::from_name(d.name)), 64, "{}", d.name);
        }
    }
}
