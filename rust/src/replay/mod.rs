//! Experience-replay memories: the paper's problem domain.
//!
//! * [`UniformReplay`] — the classic uniform ER baseline (UER).
//! * [`PerReplay`] — Prioritized Experience Replay (Schaul et al. 2015) on
//!   an array-backed [`sum_tree::SumTree`]: the baseline AMPER competes
//!   against (paper §2.1, Fig 2c).
//! * [`AmperK`] / [`AmperFr`] — the paper's Algorithm 1: priority sampling
//!   approximated by uniform sampling over a *candidate set of priorities*
//!   (CSP) built with kNN / fixed-radius-NN selection (§3.2, §3.3).
//!
//! All memories implement [`ReplayMemory`] so the agent, profiler and
//! benches can swap them freely.

pub mod amper;
pub mod experience;
pub mod hw_backed;
pub mod nstep;
pub mod per;
pub mod sum_tree;
pub mod traits;
pub mod uniform;

pub use amper::{AmperFr, AmperK, AmperParams};
pub use experience::{
    Experience, ExperienceBatch, ExperienceRef, ExperienceRing, GatheredBatch,
};
pub use hw_backed::HwAmperReplay;
pub use nstep::NStepReplay;
pub use per::{PerParams, PerReplay};
pub use sum_tree::SumTree;
pub use traits::{global_index, ReplayKind, ReplayMemory, SampledBatch};
pub use uniform::UniformReplay;

use crate::util::Rng;

/// Construct a replay memory by kind with the given capacity (batch-size
/// independent; the sampler takes the batch size per call).
pub fn make(kind: ReplayKind, capacity: usize) -> Box<dyn ReplayMemory> {
    match kind {
        ReplayKind::Uniform => Box::new(UniformReplay::new(capacity)),
        ReplayKind::Per => Box::new(PerReplay::new(capacity, PerParams::default())),
        ReplayKind::AmperK => {
            Box::new(AmperK::new(capacity, AmperParams::default()))
        }
        ReplayKind::AmperFr => {
            Box::new(AmperFr::new(capacity, AmperParams::default()))
        }
    }
}

/// Shared helper: priority from a TD error, `p = (|td| + eps)^alpha`.
#[inline]
pub fn priority_from_td(td: f32, eps: f32, alpha: f32) -> f32 {
    (td.abs() + eps).powf(alpha)
}

/// Seeded sanity driver used by integration tests and docs.
pub fn smoke(kind: ReplayKind) -> usize {
    let mut rng = Rng::new(7);
    let mut mem = make(kind, 256);
    for i in 0..512 {
        let e = Experience {
            obs: vec![i as f32; 4],
            action: (i % 2) as u32,
            reward: 1.0,
            next_obs: vec![(i + 1) as f32; 4],
            done: i % 100 == 99,
        };
        mem.push(e, &mut rng);
    }
    let batch = mem.sample(64, &mut rng);
    batch.indices.len()
}
