//! # AMPER — Associative-Memory-Based Experience Replay for Deep RL
//!
//! Production reproduction of *"Associative Memory Based Experience Replay
//! for Deep Reinforcement Learning"* (Li, Kazemi, Laguna, Hu — ICCAD 2022).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the online DQN runtime: environments, replay
//!   memories (uniform / sum-tree PER / AMPER-k / AMPER-fr), the
//!   single-owner and sharded replay services ([`coordinator`]), the
//!   bit-accurate TCAM accelerator simulator with its analytic latency
//!   model, the agent loop, profiling, metrics, config and CLI.
//! * **L2** — the DQN compute graph (JAX, `python/compile/model.py`).
//!   The [`runtime`] engine natively computes the same graph in Rust
//!   (offline build — no PJRT crate); the AOT-lowered HLO artifacts and
//!   `artifacts/manifest.json` remain the spec contract.
//! * **L1** — Pallas kernels (fused dense, TD/Huber, TCAM bit-match),
//!   cross-checked against the Rust implementations by the Python tests.
//!
//! Python never runs on the request path; the binary is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a module and bench target.

pub mod agent;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod envs;
pub mod hardware;
pub mod metrics;
pub mod net;
pub mod profiling;
pub mod prop;
pub mod replay;
pub mod runtime;
pub mod studies;
pub mod util;

/// Crate version string exposed by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
