//! `amper` — the CLI launcher for the AMPER reproduction.
//!
//! ```text
//! amper train   [--preset P] [--replay R] [--set k=v ...] [--config F]
//! amper suite   [--steps N] [--seeds a,b,c] [--csv PATH]   # Table 1/Fig 8
//! amper sample-study [--out DIR]                           # Fig 7
//! amper latency [--out DIR]                                # Fig 9
//! amper profile [--env E] [--steps N]                      # Fig 4
//! amper table2                                             # Table 2
//! amper serve   [--envs N] [--secs S] [--replay R] [--replay-shards K]
//!               [--push-batch B] [--push-batch-min m] [--push-batch-max M]
//!               [--pipeline-depth D] [--reply-pool P] [--engine-threads N]
//!               [--snapshot-interval T] [--stats-json PATH]
//!               [--connect ADDR --role learner|actor]      # coordinator demo
//! amper replay-serve [--listen ADDR] [--secs S] [--replay R]
//!               [--replay-shards K] [--reply-pool P] [--stats-json PATH]
//!                                                          # standalone replay tier
//! amper study interplay [--smoke] [--steps N] [--seed S] [--er-size E]
//!               [--out PATH]          # technique x env interplay sweep
//! ```
//!
//! Hand-rolled arg parsing (offline build, DESIGN.md §4).

use std::collections::VecDeque;

use amper::config::{presets, ConfigMap, TrainConfig};
use amper::err;
use amper::replay::{ReplayKind, ReplayMemory};
use amper::util::csv::CsvWriter;
use amper::util::error::{Context, Result};

fn main() {
    amper::util::logging::init();
    let mut args: VecDeque<String> = std::env::args().skip(1).collect();
    let cmd = args.pop_front().unwrap_or_else(|| "help".into());
    let result = match cmd.as_str() {
        "train" => cmd_train(args),
        "suite" => cmd_suite(args),
        "sample-study" => cmd_sample_study(args),
        "latency" => cmd_latency(args),
        "profile" => cmd_profile(args),
        "table2" => cmd_table2(),
        "serve" => cmd_serve(args),
        "replay-serve" => cmd_replay_serve(args),
        "study" => cmd_study(args),
        "version" => {
            println!("amper {}", amper::VERSION);
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "amper {} — Associative-Memory-based Experience Replay (ICCAD'22 reproduction)\n\
         \n\
         USAGE: amper <command> [options]\n\
         \n\
         COMMANDS:\n\
           train         run one DQN training job (--preset, --replay, --set k=v)\n\
           suite         Table 1 / Fig 8: all envs x replay kinds x seeds\n\
           sample-study  Fig 7: sampling-error study (KL heat maps, histograms)\n\
           latency       Fig 9: accelerator vs software latency sweeps\n\
           profile       Fig 4: DQN phase-latency breakdown (UER vs PER)\n\
           table2        Table 2: hardware component latencies\n\
           serve         coordinator demo: snapshot-driven batched actors + pipelined zero-copy learner over the (sharded) replay service; --connect ADDR --role learner|actor joins a remote tier\n\
           replay-serve  standalone replay tier: serve the (sharded) replay service to remote learners/actors over TCP or unix sockets\n\
           study         research harnesses; `study interplay [--smoke]` sweeps every registered replay technique x the five envs (curves, KL-vs-uniform, final returns -> STUDY_interplay.json)\n\
         \n\
         PRESETS: {}",
        amper::VERSION,
        presets::PRESET_NAMES.join(", ")
    );
}

/// Pull `--key value` (or `--key=value`) out of the arg queue.
fn take_opt(args: &mut VecDeque<String>, key: &str) -> Option<String> {
    let flag = format!("--{key}");
    let prefix = format!("--{key}=");
    let mut i = 0;
    while i < args.len() {
        if args[i] == flag {
            args.remove(i);
            return args.remove(i).map(|v| v.to_string());
        }
        if let Some(v) = args[i].strip_prefix(&prefix) {
            let v = v.to_string();
            args.remove(i);
            return Some(v);
        }
        i += 1;
    }
    None
}

/// Pull a bare `--key` flag (no value) out of the arg queue.
fn take_flag(args: &mut VecDeque<String>, key: &str) -> bool {
    let flag = format!("--{key}");
    if let Some(i) = args.iter().position(|a| *a == flag) {
        args.remove(i);
        return true;
    }
    false
}

fn take_all(args: &mut VecDeque<String>, key: &str) -> Vec<String> {
    let mut out = Vec::new();
    while let Some(v) = take_opt(args, key) {
        out.push(v);
    }
    out
}

fn build_config(args: &mut VecDeque<String>) -> Result<TrainConfig> {
    build_config_from(TrainConfig::default(), args)
}

/// [`build_config`] with a caller-chosen base for when no `--preset` is
/// given (the serve command defaults differ from the train command's).
fn build_config_from(
    base: TrainConfig,
    args: &mut VecDeque<String>,
) -> Result<TrainConfig> {
    let mut config = match take_opt(args, "preset") {
        Some(p) => presets::preset(&p)
            .with_context(|| format!("unknown preset '{p}'"))?,
        None => base,
    };
    if let Some(path) = take_opt(args, "config") {
        let map = ConfigMap::load(&path)?;
        config.apply(&map)?;
    }
    if let Some(r) = take_opt(args, "replay") {
        config.replay = ReplayKind::parse(&r).with_context(|| {
            format!("unknown replay '{r}' (valid: {})", ReplayKind::valid_names())
        })?;
    }
    for kv in take_all(args, "set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| err!("--set expects key=value, got '{kv}'"))?;
        config.set(k, v)?;
    }
    Ok(config)
}

fn cmd_train(mut args: VecDeque<String>) -> Result<()> {
    let config = build_config(&mut args)?;
    println!(
        "training {} | replay {} | er {} | steps {} | seed {}",
        config.env,
        config.replay.name(),
        config.er_size,
        config.steps,
        config.seed
    );
    let out_csv = config.out_csv.clone();
    let mut agent = amper::agent::DqnAgent::new(config)?;
    let report = agent.run()?;
    println!("\n== phase breakdown (Fig 4 accounting) ==");
    println!("{}", report.profile.report());
    println!(
        "episodes {} | final-10 mean return {:.2} | test score {:.2}",
        report.returns.n_episodes(),
        report.returns.recent_mean(10),
        report.test_score
    );
    if let Some(ns) = report.modeled_replay_ns {
        println!(
            "modeled AM-device replay time: {} total (vs {} measured software ER time)",
            amper::bench_harness::fmt_ns(ns),
            amper::bench_harness::fmt_ns(
                report.profile.total_ns(amper::profiling::Phase::ErOp)
                    + report.profile.total_ns(amper::profiling::Phase::Store)
            ),
        );
    }
    if let Some(path) = out_csv {
        let mut w = CsvWriter::create(&path, &["step", "episode_return"])?;
        for &(step, ret) in report.returns.by_step() {
            w.write_nums(&[step as f64, ret])?;
        }
        w.flush()?;
        println!("curve -> {path}");
    }
    Ok(())
}

fn cmd_suite(mut args: VecDeque<String>) -> Result<()> {
    let steps = take_opt(&mut args, "steps").map(|s| s.parse()).transpose()?;
    let seeds: Vec<u64> = take_opt(&mut args, "seeds")
        .unwrap_or_else(|| "0,1,2".into())
        .split(',')
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;
    let csv = take_opt(&mut args, "csv");
    let names: Vec<String> = take_opt(&mut args, "presets")
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| {
            vec![
                "cartpole-2000".into(),
                "cartpole-5000".into(),
                "acrobot-10000".into(),
                "lunarlander-20000".into(),
            ]
        });
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let kinds = [ReplayKind::Per, ReplayKind::AmperK, ReplayKind::AmperFr];
    let rows = amper::studies::table1::table1(
        &name_refs,
        &kinds,
        &seeds,
        steps,
        csv.as_deref(),
    )?;
    println!("\n== Table 1: test scores (mean over {} seeds) ==", seeds.len());
    amper::studies::table1::print_table(&rows);
    Ok(())
}

fn cmd_sample_study(mut args: VecDeque<String>) -> Result<()> {
    use amper::replay::amper::Variant;
    use amper::studies::fig7;
    let out_dir = take_opt(&mut args, "out").unwrap_or_else(|| "results".into());
    std::fs::create_dir_all(&out_dir)?;

    // Fig 7a: histograms
    let mut rng = amper::util::Rng::new(7);
    let pri = fig7::priority_list(fig7::LIST_SIZE, &mut rng);
    let params = amper::replay::AmperParams {
        m: 20,
        lambda: 0.3,
        lambda_prime: 0.2,
        csp_cap: usize::MAX,
        ..Default::default()
    };
    let mut w = CsvWriter::create(
        format!("{out_dir}/fig7a_histogram.csv"),
        &["bin_center", "uniform", "amper_k", "amper_fr", "per"],
    )?;
    let hists: Vec<_> = [
        fig7::Sampler::Uniform,
        fig7::Sampler::AmperK,
        fig7::Sampler::AmperFr,
        fig7::Sampler::Per,
    ]
    .iter()
    .map(|&s| fig7::value_histogram(&pri, s, &params, 50, 11))
    .collect();
    let centers = hists[0].centers();
    for (i, &c) in centers.iter().enumerate() {
        let d: Vec<f64> = hists.iter().map(|h| h.density()[i]).collect();
        w.write_nums(&[c, d[0], d[1], d[2], d[3]])?;
    }
    w.flush()?;
    println!("fig7a histogram -> {out_dir}/fig7a_histogram.csv");

    // Fig 7b/c: heat maps
    let ms = [2usize, 4, 6, 8, 10, 12];
    let scales = [0.05f32, 0.1, 0.15, 0.2, 0.25];
    for (variant, tag) in [(Variant::Knn, "fig7b_knn"), (Variant::Frnn, "fig7c_frnn")] {
        let cells = fig7::heatmap(variant, &ms, &scales, 13);
        let mut w = CsvWriter::create(
            format!("{out_dir}/{tag}_kl.csv"),
            &["m", "scale", "kl_nats"],
        )?;
        for c in &cells {
            w.write_nums(&[c.m as f64, c.scale as f64, c.kl_nats])?;
        }
        w.flush()?;
        // quick console view: corners
        let kl_at = |m: usize, s: f32| {
            cells
                .iter()
                .find(|c| c.m == m && (c.scale - s).abs() < 1e-6)
                .map(|c| c.kl_nats)
                .unwrap_or(f64::NAN)
        };
        println!(
            "{tag}: KL(m=2,λ=0.05)={:.0} nats  KL(m=12,λ=0.25)={:.0} nats -> {out_dir}/{tag}_kl.csv",
            kl_at(2, 0.05),
            kl_at(12, 0.25)
        );
    }

    // Fig 7d: size sweep
    let cells = fig7::size_sweep(
        &[5_000, 10_000, 20_000],
        &[4, 8, 12],
        &[0.03, 0.06, 0.09, 0.12, 0.15],
        17,
    );
    let mut w = CsvWriter::create(
        format!("{out_dir}/fig7d_size_sweep.csv"),
        &["er_size", "m", "csp_ratio", "kl_nats"],
    )?;
    for c in &cells {
        w.write_nums(&[c.er_size as f64, c.m as f64, c.csp_ratio, c.kl_nats])?;
    }
    w.flush()?;
    println!("fig7d size sweep -> {out_dir}/fig7d_size_sweep.csv");
    Ok(())
}

fn cmd_latency(mut args: VecDeque<String>) -> Result<()> {
    use amper::studies::fig9;
    let out_dir = take_opt(&mut args, "out").unwrap_or_else(|| "results".into());
    std::fs::create_dir_all(&out_dir)?;
    let batch = 64;

    for (rows, tag) in [
        (fig9::fig9a(batch, 1), "fig9a_vs_gpu"),
        (fig9::fig9b(batch, 2), "fig9b_group_sweep"),
        (fig9::fig9c(batch, 3), "fig9c_csp_sweep"),
    ] {
        let mut w = CsvWriter::create(
            format!("{out_dir}/{tag}.csv"),
            &["er_size", "m", "csp_ratio", "variant", "latency_ns", "csp_len"],
        )?;
        println!("\n== {tag} ==");
        for r in &rows {
            w.write_row(&[
                r.er_size.to_string(),
                r.m.to_string(),
                format!("{:.2}", r.csp_ratio),
                r.variant.to_string(),
                format!("{:.1}", r.latency_ns),
                r.csp_len.to_string(),
            ])?;
            println!(
                "er={:>6} m={:>2} ratio={:.2} {:<18} {:>12}",
                r.er_size,
                r.m,
                r.csp_ratio,
                r.variant,
                amper::bench_harness::fmt_ns(r.latency_ns)
            );
        }
        w.flush()?;
    }
    // headline speedups
    let rows = fig9::fig9a(batch, 1);
    for &size in &amper::hardware::gpu_model::FIG9A_SIZES {
        let get = |v: &str| {
            rows.iter()
                .find(|r| r.er_size == size && r.variant == v)
                .unwrap()
                .latency_ns
        };
        println!(
            "ER {size}: speedup vs paper-GPU  k={:.0}x  fr={:.0}x   (vs measured CPU PER: k={:.1}x fr={:.1}x)",
            get("per-gpu(paper)") / get("amper-k"),
            get("per-gpu(paper)") / get("amper-fr"),
            get("per-cpu(measured)") / get("amper-k"),
            get("per-cpu(measured)") / get("amper-fr"),
        );
    }
    Ok(())
}

fn cmd_profile(mut args: VecDeque<String>) -> Result<()> {
    let env = take_opt(&mut args, "env").unwrap_or_else(|| "cartpole".into());
    let steps: u64 = take_opt(&mut args, "steps")
        .unwrap_or_else(|| "3000".into())
        .parse()?;
    let sizes: Vec<usize> = take_opt(&mut args, "sizes")
        .unwrap_or_else(|| "1000,10000,100000".into())
        .split(',')
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;
    let rows = amper::studies::fig4::breakdown_grid(&env, &sizes, steps, 0)?;
    println!("\n== Fig 4: phase breakdown ({env}, {steps} steps) ==");
    amper::studies::fig4::print_rows(&rows);
    Ok(())
}

fn cmd_study(mut args: VecDeque<String>) -> Result<()> {
    use amper::studies::interplay::{self, StudyConfig};
    let which = args.pop_front().unwrap_or_else(|| "interplay".into());
    if which != "interplay" {
        return Err(err!("unknown study '{which}' (valid: interplay)"));
    }
    let smoke = take_flag(&mut args, "smoke");
    let mut study =
        if smoke { StudyConfig::smoke() } else { StudyConfig::full() };
    if let Some(s) = take_opt(&mut args, "steps") {
        study.steps = s.parse()?;
    }
    if let Some(s) = take_opt(&mut args, "seed") {
        study.seed = s.parse()?;
    }
    if let Some(s) = take_opt(&mut args, "er-size") {
        study.er_size = s.parse()?;
    }
    let out = take_opt(&mut args, "out")
        .unwrap_or_else(|| "STUDY_interplay.json".into());
    println!(
        "== interplay study: {} techniques x {} envs ({} steps, seed {}) ==",
        amper::replay::registry::all().len(),
        interplay::ENVS.len(),
        study.steps,
        study.seed
    );
    interplay::run_and_write(&study, &out)
}

fn cmd_table2() -> Result<()> {
    let model = amper::hardware::LatencyModel::default();
    println!("== Table 2: AMPER hardware component latencies ==");
    for (name, ns) in amper::hardware::latency::table2_rows(&model) {
        println!("{name:<24} {ns:>6.2} ns");
    }
    Ok(())
}

/// The learner side of the serving demo: a pipelined drain of gathered
/// batches — `pipeline_depth` requests stay in flight while the engine
/// trains **directly on the pooled reply buffers** (zero copy:
/// [`amper::runtime::TrainBatchRef`] borrows the reply, which is then
/// recycled back to the service pool). Every `snapshot_interval` train
/// steps the learner freezes its online params into `slot`, where the
/// batched env actors pick the new epoch up (the Ape-X actor/learner
/// hand-off). Short batches (shards still warming) update with a
/// placeholder TD instead of training. Generic over the two service
/// handle shapes via [`amper::coordinator::LearnerPort`]. Returns
/// `(batches, trained, pool hits, pool misses)`.
#[allow(clippy::too_many_arguments)]
fn serve_learner_loop(
    handle: impl amper::coordinator::LearnerPort,
    engine: &amper::runtime::Engine,
    state: &mut amper::runtime::TrainState,
    slot: &amper::coordinator::SnapshotSlot,
    snapshot_interval: usize,
    t: &amper::util::Timer,
    secs: u64,
    batch: usize,
    depth: usize,
) -> Result<(u64, u64, u64, u64)> {
    use std::sync::atomic::Ordering;
    let spec_batch = engine.spec().batch;
    let obs_dim = engine.spec().obs_dim;
    let mut pipeline = amper::coordinator::GatherPipeline::new(handle, batch, depth);
    let mut scratch = amper::runtime::TrainScratch::default();
    let mut batches = 0u64;
    let mut trained = 0u64;
    while t.elapsed().as_secs() < secs {
        let g = pipeline.next_batch()?;
        if g.is_empty() {
            pipeline.recycle(g);
            std::thread::yield_now();
            continue;
        }
        let n = g.rows();
        if n == spec_batch && g.obs.len() == n * obs_dim {
            let tt = amper::util::Timer::start();
            let out = engine.train_step_scratch(state, (&g).into(), &mut scratch)?;
            let stages = &pipeline.port().service_stats().stages;
            stages.train.record(tt.ns() as u64);
            trained += 1;
            if trained % snapshot_interval as u64 == 0 {
                slot.publish(state.snapshot_params());
            }
            let _ = pipeline.feedback(&g, &out.td);
            // hand the TD buffer back to the scratch so the steady state
            // allocates nothing per train step
            scratch.recycle(out);
        } else {
            let _ = pipeline.feedback(&g, &vec![0.5; n]);
        }
        pipeline.recycle(g);
        batches += 1;
    }
    let pool = pipeline.port().reply_pool().stats();
    Ok((
        batches,
        trained,
        pool.hits.load(Ordering::Relaxed),
        pool.misses.load(Ordering::Relaxed),
    ))
}

fn cmd_serve(mut args: VecDeque<String>) -> Result<()> {
    let n_envs: usize = take_opt(&mut args, "envs").unwrap_or_else(|| "4".into()).parse()?;
    let secs: u64 = take_opt(&mut args, "secs").unwrap_or_else(|| "3".into()).parse()?;
    // serve defaults (no --preset): production-sized AMPER-fr memory,
    // single shard; --preset/--config/--set/--replay override, and
    // --replay-shards / --push-batch override the config keys on top.
    let base = TrainConfig {
        replay: ReplayKind::AmperFr,
        er_size: 100_000,
        ..TrainConfig::default()
    };
    let mut config = build_config_from(base, &mut args)?;
    if let Some(env) = take_opt(&mut args, "env") {
        config.env = env;
    }
    if let Some(s) = take_opt(&mut args, "replay-shards") {
        config.set("replay_shards", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "push-batch") {
        config.set("push_batch", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "push-batch-min") {
        config.set("push_batch_min", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "push-batch-max") {
        config.set("push_batch_max", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "pipeline-depth") {
        config.set("pipeline_depth", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "engine-threads") {
        config.set("engine_threads", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "reply-pool") {
        config.set("reply_pool", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "snapshot-interval") {
        config.set("snapshot_interval", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "stats-json") {
        config.set("stats_json", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "connect") {
        config.set("net_connect", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "role") {
        config.set("net_role", &s)?;
    }
    if !config.net_connect.is_empty() {
        return cmd_serve_remote(config, n_envs, secs);
    }
    let policy = config.flush_policy();
    let stats_path = config.stats_json.clone();
    let snapshot_interval = config.snapshot_interval;
    // actors run ε-greedy on the published snapshots at the schedule
    // floor (the serve demo has no decay phase)
    let eps = config.eps_end as f64;
    let (env, replay, shards, depth) = (
        config.env,
        config.replay,
        config.replay_shards,
        config.pipeline_depth,
    );
    let replay_params = config.replay_params.clone();
    const QUEUE_DEPTH: usize = 4096;
    let mut engine = amper::runtime::Engine::load(
        std::path::Path::new(&config.artifacts_dir),
        &env,
    )?;
    // one worker pool serves the whole process: the learner's train-step
    // kernels and every replay shard's CSP chunk-sort share it
    engine.set_threads(config.engine_threads);
    let pool = std::sync::Arc::clone(engine.pool());
    let batch = engine.spec().batch;
    let mut state = amper::runtime::TrainState::init(engine.spec(), config.seed)?;
    println!(
        "serving: {n_envs} actors on {env}, {secs}s, replay {} | er {} x{shards} \
         shard(s) | flush {}..{} | train-batch {batch} | pipeline depth {depth} \
         | reply pool {} | engine threads {}",
        replay.name(),
        config.er_size,
        policy.min(),
        policy.max(),
        config.reply_pool,
        engine.threads(),
    );

    let t = amper::util::Timer::start();
    let (steps, max_flush, batches, trained, stored, hits, misses, report) = if shards == 1 {
        let mut mem = amper::replay::build(replay, config.er_size, &replay_params);
        mem.set_thread_pool(std::sync::Arc::clone(&pool));
        let svc = amper::coordinator::ReplayService::spawn(
            mem,
            QUEUE_DEPTH,
            config.seed,
        );
        svc.handle().reply_pool().set_capacity(config.reply_pool);
        let slot = amper::coordinator::SnapshotSlot::with_stats(
            amper::coordinator::PolicySnapshot::new(
                state.snapshot_params(),
                engine.spec().dims.clone(),
                0,
            )?,
            svc.handle().stats().snapshot.clone(),
        );
        let driver = amper::coordinator::VectorEnvDriver::spawn_snapshot(
            &env,
            n_envs,
            slot.clone(),
            svc.handle(),
            7,
            eps,
            policy,
        );
        let (batches, trained, hits, misses) = serve_learner_loop(
            svc.handle(),
            &engine,
            &mut state,
            &slot,
            snapshot_interval,
            &t,
            secs,
            batch,
            depth,
        )?;
        let max_flush = driver.max_flush();
        let steps = driver.stop();
        let (mem, report) = svc.stop_with_report();
        (steps, max_flush, batches, trained, mem.len(), hits, misses, report)
    } else {
        let svc = amper::coordinator::ShardedReplayService::spawn_partitioned(
            config.er_size,
            shards,
            QUEUE_DEPTH,
            config.seed,
            |_, cap| {
                let mut mem = amper::replay::build(replay, cap, &replay_params);
                mem.set_thread_pool(std::sync::Arc::clone(&pool));
                mem
            },
        );
        svc.handle().reply_pool().set_capacity(config.reply_pool);
        svc.handle().segment_pool().set_capacity(config.reply_pool * shards);
        let slot = amper::coordinator::SnapshotSlot::with_stats(
            amper::coordinator::PolicySnapshot::new(
                state.snapshot_params(),
                engine.spec().dims.clone(),
                0,
            )?,
            svc.handle().stats().snapshot.clone(),
        );
        let driver = amper::coordinator::VectorEnvDriver::spawn_snapshot(
            &env,
            n_envs,
            slot.clone(),
            svc.handle(),
            7,
            eps,
            policy,
        );
        let (batches, trained, hits, misses) = serve_learner_loop(
            svc.handle(),
            &engine,
            &mut state,
            &slot,
            snapshot_interval,
            &t,
            secs,
            batch,
            depth,
        )?;
        let max_flush = driver.max_flush();
        let steps = driver.stop();
        let (mems, report) = svc.stop_with_report();
        let stored = mems.iter().map(|m| m.len()).sum();
        (steps, max_flush, batches, trained, stored, hits, misses, report)
    };
    println!(
        "ingested {} env steps ({:.0}/s, peak flush batch {}), served {} batches \
         ({:.0}/s, {} trained zero-copy), memory holds {}",
        steps,
        steps as f64 / secs as f64,
        max_flush,
        batches,
        batches as f64 / secs as f64,
        trained,
        stored
    );
    println!(
        "reply pool: {hits} hits / {misses} misses ({:.1}% of gathers served \
         allocation-free)",
        amper::coordinator::PoolStats::rate_percent(hits, misses),
    );
    if let Some(snap) = report.get("snapshot") {
        let num = |k: &str| snap.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        let behind = snap.get("behind_epochs");
        let bnum = |k: &str| {
            behind.and_then(|b| b.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        println!(
            "snapshots: {} published (epoch {}), actor staleness over {} reads: \
             p50={:.0} p99={:.0} max={:.0} epochs behind",
            num("publishes"),
            num("epoch"),
            bnum("count") as u64,
            bnum("p50_ns"),
            bnum("p99_ns"),
            bnum("max_ns"),
        );
    }
    println!("per-stage latency (post-drain):");
    print_stage_report(&report);
    if let Some(path) = stats_path {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&path, format!("{report}\n"))?;
        println!("service report -> {path}");
    }
    Ok(())
}

/// One process of the remote serving topology (`amper serve --connect`):
/// as a learner it trains on gathered batches from the remote tier and
/// publishes policy snapshots back to it; as an actor it waits for the
/// tier to relay a snapshot, then drives batched vec-envs against the
/// remote sink. Either way the in-process machinery
/// ([`serve_learner_loop`], [`amper::coordinator::VectorEnvDriver`])
/// runs unmodified — [`amper::net::RemoteReplayClient`] is just another
/// handle shape.
fn cmd_serve_remote(config: TrainConfig, n_envs: usize, secs: u64) -> Result<()> {
    use amper::coordinator::LearnerPort;
    use amper::net::{RemoteReplayClient, Role};
    use std::sync::atomic::Ordering;
    let addr = config.net_connect.clone();
    let role = config.net_role();
    let client =
        RemoteReplayClient::connect_with(&addr, role, config.net_client_options())?;
    println!(
        "joined replay tier {addr} as {} (client {})",
        role.as_str(),
        client.client_id()
    );
    let t = amper::util::Timer::start();
    match role {
        Role::Learner => {
            let mut engine = amper::runtime::Engine::load(
                std::path::Path::new(&config.artifacts_dir),
                &config.env,
            )?;
            engine.set_threads(config.engine_threads);
            let batch = engine.spec().batch;
            let mut state =
                amper::runtime::TrainState::init(engine.spec(), config.seed)?;
            let slot = amper::coordinator::SnapshotSlot::with_stats(
                amper::coordinator::PolicySnapshot::new(
                    state.snapshot_params(),
                    engine.spec().dims.clone(),
                    0,
                )?,
                client.service_stats().snapshot.clone(),
            );
            // publish every epoch (including the initial one, which
            // teaches a cold tier the policy dims) to the tier
            let _relay = client.relay_snapshots(slot.clone());
            let (batches, trained, hits, misses) = serve_learner_loop(
                client.clone(),
                &engine,
                &mut state,
                &slot,
                config.snapshot_interval,
                &t,
                secs,
                batch,
                config.pipeline_depth,
            )?;
            let stats = client.service_stats();
            println!(
                "served {batches} remote batches ({:.0}/s, {trained} trained \
                 zero-copy), snapshot epoch {}",
                batches as f64 / secs.max(1) as f64,
                slot.epoch(),
            );
            println!(
                "reply pool: {hits} hits / {misses} misses ({:.1}% of remote \
                 gathers served allocation-free)",
                amper::coordinator::PoolStats::rate_percent(hits, misses),
            );
            let report = amper::util::json::obj(vec![
                ("counters", stats.to_json()),
                ("stages", stats.stages.to_json()),
                ("reply_pool", client.reply_pool().stats().to_json()),
            ]);
            println!("per-stage latency (client side):");
            print_stage_report(&report);
            if let Some(path) = config.stats_json {
                std::fs::write(&path, format!("{report}\n"))?;
                println!("client report -> {path}");
            }
            client.close();
        }
        Role::Actor => {
            let slot = client
                .wait_snapshot_slot(std::time::Duration::from_secs(30))
                .with_context(|| {
                    format!("tier {addr} never relayed a policy snapshot \
                             (is a learner connected?)")
                })?;
            println!(
                "received policy snapshot (epoch {}), driving {n_envs} envs",
                slot.epoch()
            );
            let driver = amper::coordinator::VectorEnvDriver::spawn_snapshot(
                &config.env,
                n_envs,
                slot,
                client.clone(),
                7,
                config.eps_end as f64,
                config.flush_policy(),
            );
            while t.elapsed().as_secs() < secs {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            let max_flush = driver.max_flush();
            let steps = driver.stop();
            println!(
                "pushed {} env steps to the tier ({:.0}/s, peak flush batch \
                 {}, final epoch {})",
                steps,
                steps as f64 / secs.max(1) as f64,
                max_flush,
                client.service_stats().snapshot.epoch.load(Ordering::Relaxed),
            );
            client.close();
        }
    }
    Ok(())
}

/// `amper replay-serve` — the standalone replay tier: one process owns
/// the (sharded) replay memory and serves it over the wire protocol to
/// any number of learner/actor clients. `--secs 0` serves until killed.
fn cmd_replay_serve(mut args: VecDeque<String>) -> Result<()> {
    let secs: u64 =
        take_opt(&mut args, "secs").unwrap_or_else(|| "0".into()).parse()?;
    let base = TrainConfig {
        replay: ReplayKind::AmperFr,
        er_size: 100_000,
        ..TrainConfig::default()
    };
    let mut config = build_config_from(base, &mut args)?;
    if let Some(s) = take_opt(&mut args, "listen") {
        config.set("net_listen", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "replay-shards") {
        config.set("replay_shards", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "reply-pool") {
        config.set("reply_pool", &s)?;
    }
    if let Some(s) = take_opt(&mut args, "stats-json") {
        config.set("stats_json", &s)?;
    }
    const QUEUE_DEPTH: usize = 4096;
    let shards = config.replay_shards;
    let listener = amper::net::Listener::bind(&config.net_listen)?;
    let server_opts = amper::net::NetServerOptions {
        reply_pool: config.reply_pool,
        ..Default::default()
    };
    println!(
        "replay tier listening on {} | replay {} | er {} x{shards} shard(s) \
         | per-client reply pool {}{}",
        listener.local_addr()?,
        config.replay.name(),
        config.er_size,
        config.reply_pool,
        if secs == 0 { " | serving until killed".to_string() } else { format!(" | serving {secs}s") },
    );
    let (clients, report) = if shards == 1 {
        let svc = amper::coordinator::ReplayService::spawn(
            amper::replay::build(
                config.replay,
                config.er_size,
                &config.replay_params,
            ),
            QUEUE_DEPTH,
            config.seed,
        );
        let server =
            amper::net::NetServer::spawn_with(svc.handle(), listener, server_opts)?;
        wait_tier(secs);
        let clients = server.clients_json();
        server.stop();
        let (_mem, report) = svc.stop_with_report();
        (clients, report)
    } else {
        let svc = amper::coordinator::ShardedReplayService::spawn_partitioned(
            config.er_size,
            shards,
            QUEUE_DEPTH,
            config.seed,
            |_, cap| {
                amper::replay::build(config.replay, cap, &config.replay_params)
            },
        );
        let server =
            amper::net::NetServer::spawn_with(svc.handle(), listener, server_opts)?;
        wait_tier(secs);
        let clients = server.clients_json();
        server.stop();
        let (_mems, report) = svc.stop_with_report();
        (clients, report)
    };
    println!("clients: {clients}");
    println!("per-stage latency (post-drain):");
    print_stage_report(&report);
    if let Some(path) = config.stats_json {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let full = amper::util::json::obj(vec![
            ("service", report),
            ("clients", clients),
        ]);
        std::fs::write(&path, format!("{full}\n"))?;
        println!("tier report -> {path}");
    }
    Ok(())
}

fn wait_tier(secs: u64) {
    if secs == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(secs));
}

/// Print the per-stage latency table from a service report
/// ([`ServiceHandle::stats_json`] / [`ShardedHandle::stats_json`] shape).
///
/// [`ServiceHandle::stats_json`]: amper::coordinator::ServiceHandle::stats_json
/// [`ShardedHandle::stats_json`]: amper::coordinator::ShardedHandle::stats_json
fn print_stage_report(report: &amper::util::json::Json) {
    use amper::bench_harness::fmt_ns;
    let Some(stages) = report.get("stages") else { return };
    for key in ["flush_accept", "worker_gather", "reply_merge", "train_step"] {
        let Some(s) = stages.get(key) else { continue };
        let num = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let count = num("count") as u64;
        if count == 0 {
            continue;
        }
        println!(
            "  {key:<14} n={count:<8} p50={:>10} p99={:>10} max={:>10}",
            fmt_ns(num("p50_ns")),
            fmt_ns(num("p99_ns")),
            fmt_ns(num("max_ns")),
        );
    }
}
