//! Fig 4 — DQN execution-latency breakdown: store / ER op / train /
//! action shares for UER vs PER as ER memory size grows.
//!
//! The paper profiles CartPole (MLP) and Atari Pong (CNN) on a GTX 1080;
//! here the same loop runs on this host through the PJRT engine, with
//! the Pong CNN replaced by the pong-proxy large MLP (DESIGN.md §4).
//! The reported quantity is the *share* of step time per phase, which is
//! what Fig 4's stacked bars show.

use crate::agent::DqnAgent;
use crate::config::TrainConfig;
use crate::profiling::Phase;
use crate::replay::ReplayKind;
use crate::util::error::Result;

/// One profiled cell of Fig 4.
#[derive(Debug, Clone)]
pub struct BreakdownRow {
    pub env: String,
    pub replay: &'static str,
    pub er_size: usize,
    pub steps: u64,
    /// Phase shares of DQN time (store, er_op, train, action), 0..1.
    pub shares: [f64; 4],
    /// Mean ER-operation latency per training step (ns).
    pub er_op_mean_ns: f64,
    /// Total wall time of the run (s).
    pub wall_s: f64,
}

/// Profile one (env, replay, er_size) cell for `steps` env steps.
pub fn profile_cell(
    env: &str,
    replay: ReplayKind,
    er_size: usize,
    steps: u64,
    seed: u64,
) -> Result<BreakdownRow> {
    let mut config = TrainConfig {
        env: env.to_string(),
        replay,
        er_size,
        steps,
        warmup: (steps / 10).max(64),
        eps_decay_steps: steps / 2,
        seed,
        ..Default::default()
    };
    // profiling wants the steady-state mix: always train once warm
    config.train_every = 1;
    let t = crate::util::Timer::start();
    let mut agent = DqnAgent::new(config)?;
    // profile at capacity: the paper's ER-size sweep assumes a full
    // memory (sum-tree depth = log2(er_size))
    agent.prefill(er_size);
    let report = agent.run_steps(steps)?;
    let wall_s = t.elapsed().as_secs_f64();
    let p = &report.profile;
    Ok(BreakdownRow {
        env: env.to_string(),
        replay: replay.name(),
        er_size,
        steps,
        shares: [
            p.fraction(Phase::Store),
            p.fraction(Phase::ErOp),
            p.fraction(Phase::Train),
            p.fraction(Phase::Action),
        ],
        er_op_mean_ns: p.mean_ns(Phase::ErOp),
        wall_s,
    })
}

/// The Fig 4 grid: UER and PER across ER sizes for one env.
pub fn breakdown_grid(
    env: &str,
    er_sizes: &[usize],
    steps: u64,
    seed: u64,
) -> Result<Vec<BreakdownRow>> {
    let mut rows = Vec::new();
    for &size in er_sizes {
        for replay in [ReplayKind::Uniform, ReplayKind::Per] {
            rows.push(profile_cell(env, replay, size, steps, seed)?);
        }
    }
    Ok(rows)
}

/// Pretty-print rows as the Fig 4 stacked-bar data.
pub fn print_rows(rows: &[BreakdownRow]) {
    println!(
        "{:<12} {:<8} {:>8} {:>8} | {:>7} {:>7} {:>7} {:>7} | {:>12}",
        "env", "replay", "er_size", "steps", "store%", "er_op%", "train%",
        "action%", "er_op mean"
    );
    for r in rows {
        println!(
            "{:<12} {:<8} {:>8} {:>8} | {:>6.1}% {:>6.1}% {:>6.1}% {:>6.1}% | {:>12}",
            r.env,
            r.replay,
            r.er_size,
            r.steps,
            r.shares[0] * 100.0,
            r.shares[1] * 100.0,
            r.shares[2] * 100.0,
            r.shares[3] * 100.0,
            crate::bench_harness::fmt_ns(r.er_op_mean_ns),
        );
    }
}
