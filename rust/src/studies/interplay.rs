//! Prioritization-interplay study: every registered replay technique
//! crossed with the five environments, fixed seeds, one machine-readable
//! artifact.
//!
//! Per (technique, env) cell the harness trains a DQN end to end, records
//! the learning curve and final test score, then measures how far the
//! technique's post-training sampling distribution sits from uniform
//! (count-convention KL, the paper's §4.1.1 metric) by drawing repeated
//! batches from the trained memory. The sweep resolves techniques through
//! [`registry::all`], so a newly registered descriptor joins the study
//! with no code changes here.
//!
//! [`registry::all`]: crate::replay::registry::all

use crate::agent::DqnAgent;
use crate::config::TrainConfig;
use crate::metrics::kl_divergence_counts;
use crate::replay::registry::{self, ReplayDescriptor};
use crate::replay::{ReplayKind, ReplayMemory, SampledBatch};
use crate::util::error::{Context, Result};
use crate::util::json::{obj, Json};
use crate::util::Rng;

/// The five study environments (all have builtin engine specs).
pub const ENVS: [&str; 5] =
    ["cartpole", "acrobot", "lunarlander", "mountaincar", "pongproxy"];

/// One (technique, env) cell's outcome.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub env: &'static str,
    pub replay: &'static str,
    pub seed: u64,
    pub steps: u64,
    pub test_score: f64,
    /// Mean return over the last 10 training episodes.
    pub final_return: f64,
    pub episodes: usize,
    /// (env_step, episode_return) learning curve.
    pub curve: Vec<(u64, f64)>,
    /// Count-convention KL between the technique's post-training sample
    /// counts and a uniform draw of the same mass (nats).
    pub kl_vs_uniform: f64,
}

/// Study-wide settings. `smoke()` shrinks every run so the full 7×5 sweep
/// finishes in CI time; `full()` uses research-scale budgets.
#[derive(Debug, Clone, Copy)]
pub struct StudyConfig {
    pub steps: u64,
    pub seed: u64,
    pub er_size: usize,
    pub test_episodes: usize,
    /// Post-training sampling rounds for the KL measurement.
    pub kl_rounds: usize,
    pub kl_batch: usize,
}

impl StudyConfig {
    pub fn smoke() -> Self {
        StudyConfig {
            steps: 192,
            seed: 17,
            er_size: 512,
            test_episodes: 1,
            kl_rounds: 50,
            kl_batch: 64,
        }
    }

    pub fn full() -> Self {
        StudyConfig {
            steps: 20_000,
            seed: 17,
            er_size: 2000,
            test_episodes: 10,
            kl_rounds: 400,
            kl_batch: 64,
        }
    }
}

/// Draw `rounds` batches from a trained memory and report the
/// count-convention KL against a uniform reference of the same total
/// mass (reuses [`kl_divergence_counts`], floor 0.5 — half an
/// observation, the metric module's default).
pub fn sampling_kl_vs_uniform(
    mem: &mut dyn ReplayMemory,
    rounds: usize,
    batch: usize,
    seed: u64,
) -> f64 {
    let n = mem.len();
    if n == 0 || rounds == 0 || batch == 0 {
        return 0.0;
    }
    let mut rng = Rng::new(seed ^ 0x5EED_C0DE);
    let mut counts = vec![0u32; n];
    let mut scratch = SampledBatch::default();
    for _ in 0..rounds {
        mem.sample_into(batch, &mut rng, &mut scratch);
        for &idx in &scratch.indices {
            if idx < n {
                counts[idx] += 1;
            }
        }
    }
    // uniform reference: the same mass spread evenly, remainder on the
    // low slots so both vectors carry identical totals
    let total = rounds * batch;
    let (each, rem) = (total / n, total % n);
    let uniform: Vec<u32> =
        (0..n).map(|i| (each + usize::from(i < rem)) as u32).collect();
    kl_divergence_counts(&counts, &uniform, 0.5)
}

/// Train one cell and measure it.
pub fn run_cell(
    d: &ReplayDescriptor,
    env: &'static str,
    study: &StudyConfig,
) -> Result<CellResult> {
    let mut config = TrainConfig::default();
    config.env = env.into();
    config.replay = ReplayKind::from_name(d.name);
    config.er_size = study.er_size;
    config.seed = study.seed;
    config.steps = study.steps;
    config.warmup = (study.steps / 10).max(64);
    config.eps_decay_steps = (study.steps / 2).max(1);
    config.test_episodes = study.test_episodes;
    let mut agent = DqnAgent::new(config)
        .with_context(|| format!("building {} on {env}", d.name))?;
    let report = agent
        .run()
        .with_context(|| format!("training {} on {env}", d.name))?;
    let kl = sampling_kl_vs_uniform(
        agent.replay_mut(),
        study.kl_rounds,
        study.kl_batch,
        study.seed,
    );
    Ok(CellResult {
        env,
        replay: d.name,
        seed: study.seed,
        steps: report.steps,
        test_score: report.test_score,
        final_return: report.returns.recent_mean(10),
        episodes: report.returns.n_episodes(),
        curve: report.returns.by_step().to_vec(),
        kl_vs_uniform: kl,
    })
}

/// Run the full sweep: every registered technique × [`ENVS`].
pub fn interplay(study: &StudyConfig) -> Result<Vec<CellResult>> {
    let mut cells = Vec::new();
    for d in registry::all() {
        for env in ENVS {
            crate::info!("interplay: {} on {env}", d.name);
            cells.push(run_cell(&d, env, study)?);
        }
    }
    Ok(cells)
}

/// Serialize the sweep (plus the technique table driving it) to the
/// `STUDY_interplay.json` artifact shape.
pub fn to_json(study: &StudyConfig, cells: &[CellResult]) -> Json {
    let techniques: Vec<Json> = registry::all()
        .iter()
        .map(|d| {
            obj(vec![
                ("name", Json::Str(d.name.into())),
                ("paper", Json::Str(d.paper.into())),
                (
                    "params",
                    Json::Arr(
                        d.param_fields
                            .iter()
                            .map(|f| Json::Str((*f).into()))
                            .collect(),
                    ),
                ),
                ("servable", Json::Bool(d.servable)),
                ("shardable", Json::Bool(d.shardable)),
            ])
        })
        .collect();
    let cell_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("env", Json::Str(c.env.into())),
                ("replay", Json::Str(c.replay.into())),
                ("seed", Json::Num(c.seed as f64)),
                ("steps", Json::Num(c.steps as f64)),
                ("test_score", Json::Num(c.test_score)),
                ("final_return", Json::Num(c.final_return)),
                ("episodes", Json::Num(c.episodes as f64)),
                ("kl_vs_uniform", Json::Num(c.kl_vs_uniform)),
                (
                    "curve",
                    Json::Arr(
                        c.curve
                            .iter()
                            .map(|&(step, ret)| {
                                Json::Arr(vec![
                                    Json::Num(step as f64),
                                    Json::Num(ret),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    obj(vec![
        ("study", Json::Str("interplay".into())),
        ("seed", Json::Num(study.seed as f64)),
        ("steps", Json::Num(study.steps as f64)),
        ("er_size", Json::Num(study.er_size as f64)),
        (
            "envs",
            Json::Arr(ENVS.iter().map(|e| Json::Str((*e).into())).collect()),
        ),
        ("techniques", Json::Arr(techniques)),
        ("cells", Json::Arr(cell_rows)),
    ])
}

/// Run the sweep and write the JSON artifact to `out_path`.
pub fn run_and_write(study: &StudyConfig, out_path: &str) -> Result<()> {
    let cells = interplay(study)?;
    let json = to_json(study, &cells);
    std::fs::write(out_path, format!("{json}\n"))
        .with_context(|| format!("writing {out_path}"))?;
    println!(
        "{:<14} {:<10} {:>10} {:>12} {:>14}",
        "Env", "Replay", "TestScore", "FinalReturn", "KLvsUniform"
    );
    for c in &cells {
        println!(
            "{:<14} {:<10} {:>10.2} {:>12.2} {:>14.1}",
            c.env, c.replay, c.test_score, c.final_return, c.kl_vs_uniform
        );
    }
    println!("wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay;

    #[test]
    fn kl_vs_uniform_is_small_for_uniform_and_larger_for_skewed() {
        let mut rng = Rng::new(3);
        let mut uni = replay::make(ReplayKind::Uniform, 64);
        let mut per = replay::make(ReplayKind::Per, 64);
        for i in 0..64 {
            let e = replay::Experience {
                obs: vec![i as f32; 4],
                action: 0,
                reward: 0.0,
                next_obs: vec![i as f32; 4],
                done: false,
            };
            uni.push(e.clone(), &mut rng);
            per.push(e, &mut rng);
        }
        // one dominant priority skews PER far from uniform
        let idx: Vec<usize> = (0..64).collect();
        let mut tds = vec![0.01f32; 64];
        tds[5] = 100.0;
        per.update_priorities_batch(&idx, &tds);
        let kl_uni = sampling_kl_vs_uniform(uni.as_mut(), 100, 64, 9);
        let kl_per = sampling_kl_vs_uniform(per.as_mut(), 100, 64, 9);
        assert!(kl_uni >= 0.0);
        assert!(
            kl_per > kl_uni + 1.0,
            "PER skew not visible: uniform {kl_uni}, per {kl_per}"
        );
    }

    #[test]
    fn kl_handles_empty_memory() {
        let mut mem = replay::make(ReplayKind::Uniform, 16);
        assert_eq!(sampling_kl_vs_uniform(mem.as_mut(), 10, 8, 1), 0.0);
    }

    #[test]
    fn json_artifact_covers_every_cell_and_technique() {
        let study = StudyConfig::smoke();
        let cells = vec![CellResult {
            env: "cartpole",
            replay: "per",
            seed: 17,
            steps: 192,
            test_score: 9.5,
            final_return: 8.0,
            episodes: 3,
            curve: vec![(10, 9.0), (20, 10.0)],
            kl_vs_uniform: 42.0,
        }];
        let json = to_json(&study, &cells);
        let n_reg = registry::all().len();
        assert_eq!(json.get("techniques").unwrap().as_arr().unwrap().len(), n_reg);
        assert_eq!(json.get("envs").unwrap().as_arr().unwrap().len(), ENVS.len());
        let rows = json.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("replay").unwrap().as_str().unwrap(), "per");
        assert_eq!(
            rows[0].get("kl_vs_uniform").unwrap().as_f64().unwrap(),
            42.0
        );
        // the artifact round-trips through the parser
        let text = format!("{json}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.get("study").unwrap().as_str().unwrap(),
            "interplay"
        );
    }

    #[test]
    fn one_smoke_cell_trains_end_to_end() {
        let mut study = StudyConfig::smoke();
        study.steps = 96;
        study.er_size = 128;
        study.kl_rounds = 10;
        let d = registry::find("dpsr").unwrap();
        let cell = run_cell(&d, "cartpole", &study).unwrap();
        assert_eq!(cell.replay, "dpsr");
        assert_eq!(cell.steps, 96);
        assert!(cell.kl_vs_uniform.is_finite());
    }
}
