//! Fig 9 — end-to-end per-batch sampling latency of the AMPER
//! accelerator vs software PER.
//!
//! (a) vs the GPU reference at ER 5000/10000/20000 (m=20, CSP ratio 0.15);
//! (b) vs group number m (CSP ratio fixed 0.15, ER 10000);
//! (c) vs CSP ratio 0.03–0.15 (m fixed 20, ER 10000).
//!
//! "Latency" is one full sampling operation (CSP construction + batch
//! draw) plus the priority update write-back, matching the paper's
//! per-batch accounting. The software-PER series is *measured* on this
//! host; the hardware series comes from the event-timed functional sim.

use crate::hardware::accelerator::{AccelConfig, AmperAccelerator};
use crate::hardware::gpu_model;
use crate::replay::amper::Variant;
use crate::replay::{PerParams, PerReplay, ReplayMemory};
use crate::replay::Experience;
use crate::util::{Rng, Timer};

/// One latency row.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    pub er_size: usize,
    pub m: usize,
    pub csp_ratio: f64,
    pub variant: &'static str,
    /// Modeled (hardware) or measured (software) per-batch latency, ns.
    pub latency_ns: f64,
    /// CSP actually built (hardware rows).
    pub csp_len: usize,
}

/// λ′ that lands an expected CSP ratio for frNN: each group's prefix
/// block covers ≈ 1.5·Δ_i of value space, so over m groups the CSP is
/// ≈ 1.5·λ′·E[V]·n ≈ 0.75·λ′·n ⇒ λ′ = ratio / 0.75 (m-independent).
pub fn lambda_prime_for_ratio(_m: usize, ratio: f64) -> f32 {
    (ratio / 0.75) as f32
}

/// λ for AMPER-k at a target CSP ratio: E|CSP| = λ·E[V]·n ≈ λ·n/2.
pub fn lambda_for_ratio(ratio: f64) -> f32 {
    (2.0 * ratio) as f32
}

/// Build a filled accelerator with U[0,1] priorities.
pub fn filled_accelerator(
    er_size: usize,
    m: usize,
    ratio: f64,
    seed: u64,
) -> AmperAccelerator {
    let config = AccelConfig {
        m,
        lambda: lambda_for_ratio(ratio),
        lambda_prime: lambda_prime_for_ratio(m, ratio),
        csb_capacity: 8000,
    };
    let mut acc = AmperAccelerator::new(er_size, config, seed as u32 | 1);
    let mut rng = Rng::new(seed);
    for i in 0..er_size {
        acc.write_priority(i, rng.f32());
    }
    acc
}

/// Modeled hardware latency for one sample+update cycle (averaged over
/// `reps` operations).
pub fn hw_latency_ns(
    acc: &mut AmperAccelerator,
    variant: Variant,
    batch: usize,
    reps: usize,
    rng: &mut Rng,
) -> (f64, usize) {
    let mut total = 0.0;
    let mut csp = 0usize;
    for _ in 0..reps {
        let out = acc.sample(batch, variant);
        // write back updated priorities for the sampled batch
        let tds: Vec<f32> = (0..batch).map(|_| rng.f32()).collect();
        let upd = acc.update_priorities(&out.indices, &tds);
        total += out.report.total_ns + upd.total_ns;
        csp = out.csp_len;
    }
    (total / reps as f64, csp)
}

/// Measured software sum-tree PER latency for one sample+update cycle.
pub fn sw_per_latency_ns(er_size: usize, batch: usize, reps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut mem = PerReplay::new(er_size, PerParams::default());
    for i in 0..er_size {
        mem.push(
            Experience {
                obs: vec![0.0; 4],
                action: 0,
                reward: 0.0,
                next_obs: vec![0.0; 4],
                done: false,
            },
            &mut rng,
        );
        mem.set_priority_raw(i, rng.f32());
    }
    // warmup
    for _ in 0..reps / 10 + 1 {
        let b = mem.sample(batch, &mut rng);
        mem.update_priorities(&b.indices, &vec![0.5; batch]);
    }
    let t = Timer::start();
    for _ in 0..reps {
        let b = mem.sample(batch, &mut rng);
        let tds: Vec<f32> = (0..batch).map(|_| rng.f32()).collect();
        mem.update_priorities(&b.indices, &tds);
    }
    t.ns() / reps as f64
}

/// Fig 9a: the three-size comparison (hardware AMPER-k/fr, GPU reference,
/// measured software PER).
pub fn fig9a(batch: usize, seed: u64) -> Vec<LatencyRow> {
    let mut rows = Vec::new();
    let mut rng = Rng::new(seed);
    for &size in &gpu_model::FIG9A_SIZES {
        for (variant, name) in [(Variant::Knn, "amper-k"), (Variant::Frnn, "amper-fr")] {
            let mut acc = filled_accelerator(size, 20, 0.15, seed ^ size as u64);
            let (ns, csp) = hw_latency_ns(&mut acc, variant, batch, 20, &mut rng);
            rows.push(LatencyRow {
                er_size: size,
                m: 20,
                csp_ratio: 0.15,
                variant: name,
                latency_ns: ns,
                csp_len: csp,
            });
        }
        rows.push(LatencyRow {
            er_size: size,
            m: 20,
            csp_ratio: 0.15,
            variant: "per-gpu(paper)",
            latency_ns: gpu_model::gpu_per_latency_ns(size),
            csp_len: 0,
        });
        rows.push(LatencyRow {
            er_size: size,
            m: 20,
            csp_ratio: 0.15,
            variant: "per-cpu(measured)",
            latency_ns: sw_per_latency_ns(size, batch, 200, seed ^ size as u64),
            csp_len: 0,
        });
    }
    rows
}

/// Fig 9b: group-number sweep at fixed CSP ratio 0.15, ER 10000.
pub fn fig9b(batch: usize, seed: u64) -> Vec<LatencyRow> {
    let mut rows = Vec::new();
    let mut rng = Rng::new(seed);
    for m in [4usize, 8, 12, 16, 20] {
        for (variant, name) in [(Variant::Knn, "amper-k"), (Variant::Frnn, "amper-fr")] {
            let mut acc = filled_accelerator(10_000, m, 0.15, seed ^ m as u64);
            let (ns, csp) = hw_latency_ns(&mut acc, variant, batch, 20, &mut rng);
            rows.push(LatencyRow {
                er_size: 10_000,
                m,
                csp_ratio: 0.15,
                variant: name,
                latency_ns: ns,
                csp_len: csp,
            });
        }
    }
    rows
}

/// Fig 9c: CSP-ratio sweep at fixed m=20, ER 10000.
pub fn fig9c(batch: usize, seed: u64) -> Vec<LatencyRow> {
    let mut rows = Vec::new();
    let mut rng = Rng::new(seed);
    for ratio in [0.03, 0.06, 0.09, 0.12, 0.15] {
        for (variant, name) in [(Variant::Knn, "amper-k"), (Variant::Frnn, "amper-fr")] {
            let mut acc = filled_accelerator(10_000, 20, ratio, seed);
            let (ns, csp) = hw_latency_ns(&mut acc, variant, batch, 20, &mut rng);
            rows.push(LatencyRow {
                er_size: 10_000,
                m: 20,
                csp_ratio: ratio,
                variant: name,
                latency_ns: ns,
                csp_len: csp,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9a_speedups_match_paper_shape() {
        let rows = fig9a(64, 1);
        for &size in &gpu_model::FIG9A_SIZES {
            let get = |v: &str| {
                rows.iter()
                    .find(|r| r.er_size == size && r.variant == v)
                    .unwrap()
                    .latency_ns
            };
            let k = get("amper-k");
            let fr = get("amper-fr");
            let gpu = get("per-gpu(paper)");
            assert!(fr < k, "size {size}: fr {fr} !< k {k}");
            let sk = gpu / k;
            let sfr = gpu / fr;
            // shape: both speedups are orders of magnitude, fr > k
            assert!(sk > 20.0, "size {size}: k speedup {sk}");
            assert!(sfr > sk, "size {size}");
        }
    }

    #[test]
    fn fig9b_m_has_small_effect() {
        // paper: "increasing group number has a small impact on latency"
        let rows = fig9b(64, 2);
        let fr: Vec<f64> = rows
            .iter()
            .filter(|r| r.variant == "amper-fr")
            .map(|r| r.latency_ns)
            .collect();
        let min = fr.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = fr.iter().cloned().fold(0.0, f64::max);
        assert!(
            max / min < 2.0,
            "fr latency should be flat-ish in m: {fr:?}"
        );
    }

    #[test]
    fn fig9c_latency_increases_with_csp() {
        // paper: "latency increases linearly with the CSP size"
        let rows = fig9c(64, 3);
        for v in ["amper-k", "amper-fr"] {
            let series: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.variant == v)
                .map(|r| (r.csp_ratio, r.latency_ns))
                .collect();
            for w in series.windows(2) {
                assert!(
                    w[1].1 > w[0].1 * 0.9,
                    "{v}: latency not increasing: {series:?}"
                );
            }
            let first = series.first().unwrap().1;
            let last = series.last().unwrap().1;
            assert!(last > first * 2.0, "{v}: {series:?}");
        }
    }

    #[test]
    fn sw_per_latency_is_positive_and_grows_slowly() {
        let a = sw_per_latency_ns(1_000, 64, 50, 5);
        let b = sw_per_latency_ns(100_000, 64, 50, 6);
        assert!(a > 0.0 && b > a * 0.8, "a={a} b={b}");
    }
}
