//! Fig 7 — sampling-error study.
//!
//! Protocol (paper §4.1.1): a list of 10 000 priorities drawn from
//! U[0, 1]; sample with batch 64 for 100 runs; compare the per-item
//! sample-count distributions of AMPER vs PER via KL divergence (count
//! convention, nats). Also produces the Fig 7a value-histograms and the
//! Fig 7b/c hyper-parameter heat maps and the Fig 7d size sweep.

use crate::metrics::{kl_divergence_counts, Histogram};
use crate::replay::amper::{csp, quant, AmperParams, Variant};
use crate::replay::SumTree;
use crate::util::Rng;

/// The paper's study constants.
pub const LIST_SIZE: usize = 10_000;
pub const BATCH: usize = 64;
pub const RUNS: usize = 100;

/// Which sampler a study row uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampler {
    Uniform,
    Per,
    AmperK,
    AmperFr,
}

impl Sampler {
    pub fn name(&self) -> &'static str {
        match self {
            Sampler::Uniform => "uniform",
            Sampler::Per => "per",
            Sampler::AmperK => "amper-k",
            Sampler::AmperFr => "amper-fr",
        }
    }
}

/// Generate the study's priority list: U[0,1], `n` entries.
pub fn priority_list(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.f32()).collect()
}

/// Accumulate per-item sample counts for `runs` batches of `batch`.
pub fn sample_counts(
    priorities: &[f32],
    sampler: Sampler,
    params: &AmperParams,
    batch: usize,
    runs: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let n = priorities.len();
    let mut counts = vec![0u32; n];
    match sampler {
        Sampler::Uniform => {
            for _ in 0..runs {
                for _ in 0..batch {
                    counts[rng.below(n)] += 1;
                }
            }
        }
        Sampler::Per => {
            let mut tree = SumTree::new(n);
            for (i, &p) in priorities.iter().enumerate() {
                tree.set(i, p as f64);
            }
            for _ in 0..runs {
                for _ in 0..batch {
                    let y = rng.f64() * tree.total();
                    counts[tree.find(y)] += 1;
                }
            }
        }
        Sampler::AmperK | Sampler::AmperFr => {
            let variant = if sampler == Sampler::AmperK {
                Variant::Knn
            } else {
                Variant::Frnn
            };
            let pri_q: Vec<u32> =
                priorities.iter().map(|&p| quant::quantize(p)).collect();
            let mut buf = Vec::new();
            for _ in 0..runs {
                buf.clear();
                csp::build_csp(priorities, &pri_q, params, variant, rng, &mut buf);
                for &i in &csp::draw_batch(&buf, n, batch, rng) {
                    counts[i] += 1;
                }
            }
        }
    }
    counts
}

/// Value bins for the KL measurement. Raw per-item counts at 6400 draws
/// over 10 000 items sit below the Poisson noise floor (every item is
/// seen 0-2 times, so even PER-vs-PER measures ~items/2 nats); binning
/// the sampled *values* — the distribution Fig 7a actually plots — puts
/// the chi-square noise floor at ≈ bins/2 ≈ 125 nats, matching the
/// paper's reported PER-vs-PER reference of ≈ 140 nats.
pub const KL_BINS: usize = 250;

/// Bin per-item sample counts by priority value.
pub fn bin_counts(priorities: &[f32], counts: &[u32], bins: usize) -> Vec<u32> {
    let mut out = vec![0u32; bins];
    for (i, &c) in counts.iter().enumerate() {
        let b = ((priorities[i] as f64 * bins as f64) as usize).min(bins - 1);
        out[b] += c;
    }
    out
}

/// One KL measurement: KL(sampler ‖ PER) under the paper's protocol
/// (batch 64 × 100 runs, count-convention KL in nats over value bins).
pub fn kl_vs_per(
    priorities: &[f32],
    sampler: Sampler,
    params: &AmperParams,
    seed: u64,
) -> f64 {
    let mut rng_a = Rng::new(seed);
    let mut rng_b = Rng::new(seed ^ 0xFACE);
    let a = sample_counts(priorities, sampler, params, BATCH, RUNS, &mut rng_a);
    let b = sample_counts(priorities, Sampler::Per, params, BATCH, RUNS, &mut rng_b);
    kl_divergence_counts(
        &bin_counts(priorities, &a, KL_BINS),
        &bin_counts(priorities, &b, KL_BINS),
        0.5,
    )
}

/// Fig 7a: value-distribution histograms of the sampled priorities.
pub fn value_histogram(
    priorities: &[f32],
    sampler: Sampler,
    params: &AmperParams,
    bins: usize,
    seed: u64,
) -> Histogram {
    let mut rng = Rng::new(seed);
    let counts =
        sample_counts(priorities, sampler, params, BATCH, RUNS, &mut rng);
    let mut h = Histogram::new(0.0, 1.0, bins);
    for (i, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            h.push(priorities[i] as f64);
        }
    }
    h
}

/// One cell of the Fig 7b/c heat map.
#[derive(Debug, Clone)]
pub struct HeatCell {
    pub m: usize,
    pub scale: f32,
    pub kl_nats: f64,
}

/// Fig 7b/c: KL(AMPER‖PER) over (m, λ) or (m, λ′).
pub fn heatmap(
    variant: Variant,
    ms: &[usize],
    scales: &[f32],
    seed: u64,
) -> Vec<HeatCell> {
    let mut rng = Rng::new(seed);
    let priorities = priority_list(LIST_SIZE, &mut rng);
    let sampler = match variant {
        Variant::Knn => Sampler::AmperK,
        Variant::Frnn => Sampler::AmperFr,
    };
    let mut out = Vec::new();
    for &m in ms {
        for &scale in scales {
            // λ and λ′ share the x-axis in Fig 7b/c (both 0.05..0.25)
            let params = AmperParams {
                m,
                lambda: scale,
                lambda_prime: scale,
                csp_cap: usize::MAX,
                ..Default::default()
            };
            let kl = kl_vs_per(&priorities, sampler, &params, seed ^ m as u64);
            out.push(HeatCell { m, scale, kl_nats: kl });
        }
    }
    out
}

/// Fig 7d row: KL vs CSP ratio for one ER size and m.
#[derive(Debug, Clone)]
pub struct SizeCell {
    pub er_size: usize,
    pub m: usize,
    pub csp_ratio: f64,
    pub kl_nats: f64,
}

/// Fig 7d: AMPER-k KL across ER sizes / group counts / CSP ratios.
pub fn size_sweep(
    sizes: &[usize],
    ms: &[usize],
    ratios: &[f64],
    seed: u64,
) -> Vec<SizeCell> {
    let mut out = Vec::new();
    for &er in sizes {
        let mut rng = Rng::new(seed ^ er as u64);
        let priorities = priority_list(er, &mut rng);
        for &m in ms {
            for &ratio in ratios {
                // With V̄ ≈ 0.5 and ΣC = n, E|CSP| ≈ λ·0.5·n ⇒ λ ≈ 2·ratio
                let params = AmperParams {
                    m,
                    lambda: (2.0 * ratio) as f32,
                    csp_cap: usize::MAX,
                    ..Default::default()
                };
                let kl = kl_vs_per(
                    &priorities,
                    Sampler::AmperK,
                    &params,
                    seed ^ (er as u64) << 8 ^ m as u64,
                );
                out.push(SizeCell { er_size: er, m, csp_ratio: ratio, kl_nats: kl });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params() -> AmperParams {
        AmperParams { m: 8, lambda: 0.3, lambda_prime: 0.2, csp_cap: usize::MAX, ..Default::default() }
    }

    #[test]
    fn per_self_kl_is_small_uniform_kl_is_huge() {
        // the paper's reference points: PER-vs-PER ≈ 140 nats, uniform
        // far above it (they report ≈ 9000; see EXPERIMENTS.md on the
        // count-convention sensitivity). The ordering and the ~140-nat
        // noise floor are the reproducible facts.
        let mut rng = Rng::new(0);
        let pri = priority_list(LIST_SIZE, &mut rng);
        let params = quick_params();
        let kl_self = kl_vs_per(&pri, Sampler::Per, &params, 1);
        let kl_uni = kl_vs_per(&pri, Sampler::Uniform, &params, 2);
        assert!(kl_self < 400.0, "PER self-KL {kl_self}");
        assert!(kl_uni > 1000.0, "uniform KL {kl_uni}");
        assert!(kl_uni > kl_self * 5.0);
    }

    #[test]
    fn amper_kl_between_per_and_uniform() {
        let mut rng = Rng::new(3);
        let pri = priority_list(LIST_SIZE, &mut rng);
        let params = quick_params();
        let kl_k = kl_vs_per(&pri, Sampler::AmperK, &params, 4);
        let kl_fr = kl_vs_per(&pri, Sampler::AmperFr, &params, 5);
        let kl_uni = kl_vs_per(&pri, Sampler::Uniform, &params, 6);
        assert!(kl_k < kl_uni * 0.5, "k {kl_k} vs uniform {kl_uni}");
        assert!(kl_fr < kl_uni * 0.5, "fr {kl_fr} vs uniform {kl_uni}");
    }

    #[test]
    fn kl_decreases_with_scale_factor() {
        // Fig 7b/c trend: larger λ (CSP) → smaller KL
        let mut rng = Rng::new(7);
        let pri = priority_list(5000, &mut rng);
        let small = AmperParams { m: 8, lambda: 0.02, csp_cap: usize::MAX, ..Default::default() };
        let large = AmperParams { m: 8, lambda: 0.5, csp_cap: usize::MAX, ..Default::default() };
        let kl_small = kl_vs_per(&pri, Sampler::AmperK, &small, 8);
        let kl_large = kl_vs_per(&pri, Sampler::AmperK, &large, 8);
        assert!(
            kl_large < kl_small,
            "λ=0.5 KL {kl_large} !< λ=0.02 KL {kl_small}"
        );
    }

    #[test]
    fn histogram_reflects_prioritization() {
        let mut rng = Rng::new(9);
        let pri = priority_list(5000, &mut rng);
        let h = value_histogram(&pri, Sampler::AmperFr, &quick_params(), 10, 10);
        let d = h.density();
        // prioritized sampling: high-value bins denser than low-value bins
        assert!(d[9] > d[0], "{d:?}");
    }

    #[test]
    fn heatmap_has_all_cells() {
        let cells = heatmap(Variant::Frnn, &[2, 4], &[0.05, 0.25], 11);
        assert_eq!(cells.len(), 4);
        assert!(cells.iter().all(|c| c.kl_nats.is_finite()));
    }
}
