//! Table 1 / Fig 8 — DQN learning performance: train PER, AMPER-k and
//! AMPER-fr on the paper's four env/ER-size rows, averaging over seeds,
//! and report final test scores + learning curves.

use crate::agent::DqnAgent;
use crate::config::{presets, TrainConfig};
use crate::replay::ReplayKind;
use crate::util::csv::CsvWriter;
use crate::util::error::{Context, Result};

/// One learning run's outcome.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub env: String,
    pub er_size: usize,
    pub replay: &'static str,
    pub seed: u64,
    pub test_score: f64,
    /// (env_step, episode_return) learning curve.
    pub curve: Vec<(u64, f64)>,
}

/// Train one configuration for one seed.
pub fn run_once(mut config: TrainConfig, seed: u64) -> Result<RunResult> {
    config.seed = seed;
    let env = config.env.clone();
    let er_size = config.er_size;
    let replay = config.replay.name();
    let mut agent = DqnAgent::new(config)?;
    let report = agent.run()?;
    Ok(RunResult {
        env,
        er_size,
        replay,
        seed,
        test_score: report.test_score,
        curve: report.returns.by_step().to_vec(),
    })
}

/// A Table 1 row: one preset across replay kinds × seeds.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub env: String,
    pub er_size: usize,
    /// (replay name, mean test score over seeds).
    pub scores: Vec<(&'static str, f64)>,
}

/// Run the full Table 1 suite. `steps_override` shrinks runs for smoke
/// usage; `None` uses the preset step budgets.
pub fn table1(
    preset_names: &[&str],
    kinds: &[ReplayKind],
    seeds: &[u64],
    steps_override: Option<u64>,
    curves_csv: Option<&str>,
) -> Result<Vec<TableRow>> {
    let mut csv = match curves_csv {
        Some(path) => Some(CsvWriter::create(
            path,
            &["env", "er_size", "replay", "seed", "step", "episode_return"],
        )?),
        None => None,
    };
    let mut rows = Vec::new();
    for &name in preset_names {
        let base = presets::preset(name)
            .with_context(|| format!("unknown preset {name}"))?;
        let mut scores = Vec::new();
        for &kind in kinds {
            let mut total = 0.0;
            for &seed in seeds {
                let mut config = base.clone();
                config.replay = kind;
                if let Some(s) = steps_override {
                    config.steps = s;
                    config.warmup = (s / 10).max(64);
                    config.eps_decay_steps = s / 2;
                }
                let res = run_once(config, seed)?;
                total += res.test_score;
                if let Some(w) = csv.as_mut() {
                    for &(step, ret) in &res.curve {
                        w.write_row(&[
                            res.env.clone(),
                            res.er_size.to_string(),
                            res.replay.to_string(),
                            seed.to_string(),
                            step.to_string(),
                            format!("{ret:.2}"),
                        ])?;
                    }
                }
            }
            scores.push((kind.name(), total / seeds.len() as f64));
        }
        rows.push(TableRow {
            env: base.env.clone(),
            er_size: base.er_size,
            scores,
        });
    }
    if let Some(mut w) = csv {
        w.flush()?;
    }
    Ok(rows)
}

/// Print rows in the paper's Table 1 layout.
pub fn print_table(rows: &[TableRow]) {
    print!("{:<14} {:>7}", "Env", "Size");
    if let Some(r) = rows.first() {
        for (name, _) in &r.scores {
            print!(" {name:>10}");
        }
    }
    println!();
    for r in rows {
        print!("{:<14} {:>7}", r.env, r.er_size);
        for (_, score) in &r.scores {
            print!(" {score:>10.2}");
        }
        println!();
    }
}
