//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (DESIGN.md §6 experiment index). Each driver is pure
//! library code so the CLI (`amper <cmd>`), the examples and the bench
//! targets share one implementation.

pub mod fig4;
pub mod fig7;
pub mod fig9;
pub mod interplay;
pub mod table1;
