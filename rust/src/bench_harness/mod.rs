//! Criterion-lite: a small benchmarking harness (the registry is offline;
//! DESIGN.md §4). Warmup + timed iterations, mean/p50/p99 reporting,
//! optional CSV output. Used by every `rust/benches/*` target
//! (`harness = false`).

use crate::util::stats::Summary;
use crate::util::Timer;

/// One benchmark's measurement configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup wall time before measuring.
    pub warmup_ms: u64,
    /// Measured sample count.
    pub samples: usize,
    /// Iterations folded into one sample (amortizes timer overhead for
    /// nanosecond-scale bodies).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_ms: 200, samples: 60, iters_per_sample: 1 }
    }
}

impl BenchConfig {
    /// Apply environment overrides — `AMPER_BENCH_WARMUP_MS`,
    /// `AMPER_BENCH_SAMPLES`, `AMPER_BENCH_ITERS` — so CI smoke jobs can
    /// run every bench target at a reduced iteration count without
    /// touching the per-bench configs. Unset or unparsable variables
    /// leave the config unchanged.
    pub fn from_env(self) -> Self {
        self.with_lookup(|key| std::env::var(key).ok())
    }

    /// [`Self::from_env`] with an injected variable lookup (tests use a
    /// map so the process environment is never mutated).
    fn with_lookup(mut self, get: impl Fn(&str) -> Option<String>) -> Self {
        fn parse<T: std::str::FromStr>(v: Option<String>) -> Option<T> {
            v?.parse().ok()
        }
        if let Some(v) = parse(get("AMPER_BENCH_WARMUP_MS")) {
            self.warmup_ms = v;
        }
        if let Some(v) = parse::<usize>(get("AMPER_BENCH_SAMPLES")) {
            self.samples = v.max(1);
        }
        if let Some(v) = parse::<usize>(get("AMPER_BENCH_ITERS")) {
            self.iters_per_sample = v.max(1);
        }
        self
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration latency summary (ns).
    pub ns: Summary,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            fmt_ns(self.ns.mean),
            fmt_ns(self.ns.p50),
            fmt_ns(self.ns.p99),
            self.ns.n
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// The harness: register cases with [`Bench::case`], results accumulate.
pub struct Bench {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    /// Default config with `AMPER_BENCH_*` environment overrides applied
    /// (the CI smoke job's reduced-iteration knob).
    pub fn new() -> Self {
        Self::with_config(BenchConfig::default())
    }

    /// Explicit config, still honoring `AMPER_BENCH_*` env overrides so
    /// CI can shrink any bench target uniformly.
    pub fn with_config(config: BenchConfig) -> Self {
        Bench { config: config.from_env(), results: Vec::new() }
    }

    /// Measure `body` (called once per iteration; state captured by the
    /// closure). The closure's return value is black-boxed.
    pub fn case<T>(&mut self, name: &str, mut body: impl FnMut() -> T) -> &BenchResult {
        // warmup
        let warm = Timer::start();
        while warm.ms() < self.config.warmup_ms as f64 {
            black_box(body());
        }
        // measure
        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t = Timer::start();
            for _ in 0..self.config.iters_per_sample {
                black_box(body());
            }
            samples.push(t.ns() / self.config.iters_per_sample as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            ns: Summary::of(&samples).unwrap(),
        };
        res.print();
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all results as machine-readable JSON:
    /// `{"cases": [{"name", "mean_ns", "p50_ns", "p99_ns", "std_ns", "n"}]}`
    /// — the format the perf-trajectory tooling ingests (`BENCH_*.json`).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(r.name.clone()));
                m.insert("mean_ns".to_string(), Json::Num(r.ns.mean));
                m.insert("p50_ns".to_string(), Json::Num(r.ns.p50));
                m.insert("p99_ns".to_string(), Json::Num(r.ns.p99));
                m.insert("std_ns".to_string(), Json::Num(r.ns.std));
                m.insert("n".to_string(), Json::Num(r.ns.n as f64));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("cases".to_string(), Json::Arr(cases));
        std::fs::write(path, format!("{}\n", Json::Obj(root)))
    }

    /// Write all results to a CSV (name, mean_ns, p50_ns, p99_ns, std_ns).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &["name", "mean_ns", "p50_ns", "p99_ns", "std_ns"],
        )?;
        for r in &self.results {
            w.write_row(&[
                r.name.clone(),
                format!("{:.2}", r.ns.mean),
                format!("{:.2}", r.ns.p50),
                format!("{:.2}", r.ns.p99),
                format!("{:.2}", r.ns.std),
            ])?;
        }
        w.flush()
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Optimization barrier (std::hint::black_box wrapper, kept here so bench
/// code has a single import).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::with_config(BenchConfig {
            warmup_ms: 1,
            samples: 5,
            iters_per_sample: 10,
        });
        let r = b.case("noop-ish", || 1 + 1);
        assert!(r.ns.mean >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn env_overrides_apply_and_clamp() {
        // injected lookup: the process environment is never mutated, so
        // concurrently running tests cannot observe these overrides
        let c = BenchConfig { warmup_ms: 200, samples: 60, iters_per_sample: 4 }
            .with_lookup(|key| match key {
                "AMPER_BENCH_WARMUP_MS" => Some("3".into()),
                "AMPER_BENCH_SAMPLES" => Some("0".into()), // clamped to 1
                "AMPER_BENCH_ITERS" => Some("nonsense".into()), // ignored
                _ => None,
            });
        assert_eq!(c.warmup_ms, 3);
        assert_eq!(c.samples, 1);
        assert_eq!(c.iters_per_sample, 4);

        // absent variables leave the config untouched
        let d = BenchConfig::default().with_lookup(|_| None);
        assert_eq!(d.samples, BenchConfig::default().samples);
    }

    #[test]
    fn csv_output_writes() {
        let mut b = Bench::with_config(BenchConfig {
            warmup_ms: 1,
            samples: 3,
            iters_per_sample: 1,
        });
        b.case("x", || 0);
        let path = std::env::temp_dir().join("amper_bench_test.csv");
        b.write_csv(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("name,mean_ns"));
        assert!(body.contains("\nx,"));
    }

    #[test]
    fn json_output_round_trips() {
        let mut b = Bench::with_config(BenchConfig {
            warmup_ms: 1,
            samples: 3,
            iters_per_sample: 1,
        });
        b.case("svc/batched/shards4/batch32", || 0);
        let path = std::env::temp_dir().join("amper_bench_test.json");
        b.write_json(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(&body).unwrap();
        let cases = parsed.get("cases").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(
            cases[0].get("name").and_then(|n| n.as_str()),
            Some("svc/batched/shards4/batch32")
        );
        assert!(cases[0].get("mean_ns").and_then(|x| x.as_f64()).unwrap() >= 0.0);
    }
}
