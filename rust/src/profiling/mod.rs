//! Per-phase latency instrumentation for the Fig 4 study: how much of a
//! DQN step goes to store / ER sample+update / train / action as the ER
//! technique and memory size vary.

use std::time::Duration;

use crate::util::stats::Online;
use crate::util::Timer;

/// The four DQN phases the paper profiles (§2.4) plus env stepping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Storing a transition into ER memory.
    Store,
    /// ER operation: sampling a batch + updating priorities.
    ErOp,
    /// Target-network training step.
    Train,
    /// Action-network inference.
    Action,
    /// Environment dynamics (not part of the paper's breakdown; tracked
    /// so the breakdown percentages can exclude it, as the paper does).
    Env,
}

impl Phase {
    pub const ALL: [Phase; 5] =
        [Phase::Store, Phase::ErOp, Phase::Train, Phase::Action, Phase::Env];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Store => "store",
            Phase::ErOp => "er_op",
            Phase::Train => "train",
            Phase::Action => "action",
            Phase::Env => "env",
        }
    }
}

/// Accumulates per-phase wall time.
#[derive(Debug, Default)]
pub struct PhaseProfile {
    totals_ns: [f64; 5],
    stats: [Online; 5],
}

impl PhaseProfile {
    pub fn new() -> Self {
        PhaseProfile {
            totals_ns: [0.0; 5],
            stats: Default::default(),
        }
    }

    #[inline]
    fn slot(phase: Phase) -> usize {
        match phase {
            Phase::Store => 0,
            Phase::ErOp => 1,
            Phase::Train => 2,
            Phase::Action => 3,
            Phase::Env => 4,
        }
    }

    /// Record `f`'s wall time under `phase`.
    #[inline]
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(phase, t.ns());
        out
    }

    #[inline]
    pub fn add(&mut self, phase: Phase, ns: f64) {
        let s = Self::slot(phase);
        self.totals_ns[s] += ns;
        self.stats[s].push(ns);
    }

    pub fn total_ns(&self, phase: Phase) -> f64 {
        self.totals_ns[Self::slot(phase)]
    }

    pub fn count(&self, phase: Phase) -> u64 {
        self.stats[Self::slot(phase)].n()
    }

    pub fn mean_ns(&self, phase: Phase) -> f64 {
        self.stats[Self::slot(phase)].mean()
    }

    /// Total across the paper's four phases (Env excluded).
    pub fn dqn_total_ns(&self) -> f64 {
        Phase::ALL[..4].iter().map(|&p| self.total_ns(p)).sum()
    }

    /// Fraction of DQN time spent in `phase` (Env excluded), 0..1.
    pub fn fraction(&self, phase: Phase) -> f64 {
        let total = self.dqn_total_ns();
        if total == 0.0 {
            0.0
        } else {
            self.total_ns(phase) / total
        }
    }

    /// Pretty breakdown table.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str("phase     total        mean/op      share\n");
        for &p in &Phase::ALL[..4] {
            s.push_str(&format!(
                "{:<8} {:>12} {:>12}   {:>5.1}%\n",
                p.name(),
                fmt_dur(self.total_ns(p)),
                fmt_dur(self.mean_ns(p)),
                self.fraction(p) * 100.0
            ));
        }
        s.push_str(&format!(
            "{:<8} {:>12} {:>12}   (excluded)\n",
            "env",
            fmt_dur(self.total_ns(Phase::Env)),
            fmt_dur(self.mean_ns(Phase::Env)),
        ));
        s
    }
}

fn fmt_dur(ns: f64) -> String {
    crate::bench_harness::fmt_ns(ns)
}

/// Convert a Duration to f64 ns (helper for external timers).
pub fn dur_ns(d: Duration) -> f64 {
    d.as_nanos() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_fractions() {
        let mut p = PhaseProfile::new();
        p.add(Phase::Store, 100.0);
        p.add(Phase::ErOp, 300.0);
        p.add(Phase::Train, 500.0);
        p.add(Phase::Action, 100.0);
        p.add(Phase::Env, 10_000.0); // must not affect fractions
        assert!((p.dqn_total_ns() - 1000.0).abs() < 1e-9);
        assert!((p.fraction(Phase::ErOp) - 0.3).abs() < 1e-9);
        assert_eq!(p.count(Phase::ErOp), 1);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseProfile::new();
        let v = p.time(Phase::Train, || 42);
        assert_eq!(v, 42);
        assert!(p.total_ns(Phase::Train) > 0.0);
    }

    #[test]
    fn report_contains_phases() {
        let mut p = PhaseProfile::new();
        p.add(Phase::Store, 1.0);
        let r = p.report();
        assert!(r.contains("store"));
        assert!(r.contains("er_op"));
    }
}
