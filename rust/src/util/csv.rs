//! Minimal CSV writer for experiment outputs (`results/*.csv`). Quotes
//! fields only when needed; no external crates (offline build).

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer.
pub struct CsvWriter<W: Write> {
    out: W,
    cols: usize,
}

impl CsvWriter<BufWriter<File>> {
    /// Create a file-backed writer and emit the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = CsvWriter { out: BufWriter::new(File::create(path)?), cols: header.len() };
        w.write_row(header)?;
        Ok(w)
    }
}

impl<W: Write> CsvWriter<W> {
    /// Wrap any writer; `header` may be empty to skip the header row.
    pub fn new(out: W, header: &[&str]) -> io::Result<Self> {
        let mut w = CsvWriter { out, cols: header.len() };
        if !header.is_empty() {
            w.write_row(header)?;
        }
        Ok(w)
    }

    /// Write one row of string fields.
    pub fn write_row<S: AsRef<str>>(&mut self, fields: &[S]) -> io::Result<()> {
        if self.cols != 0 && !fields.is_empty() {
            debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        }
        let mut first = true;
        for f in fields {
            if !first {
                self.out.write_all(b",")?;
            }
            first = false;
            let s = f.as_ref();
            if s.contains([',', '"', '\n']) {
                let escaped = s.replace('"', "\"\"");
                write!(self.out, "\"{escaped}\"")?;
            } else {
                self.out.write_all(s.as_bytes())?;
            }
        }
        self.out.write_all(b"\n")
    }

    /// Write a row of f64 values (formatted with up to 6 significant decimals).
    pub fn write_nums(&mut self, fields: &[f64]) -> io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format_num(*x)).collect();
        self.write_row(&strs)
    }

    pub fn flush(&mut self) -> io::Result<()> {
        self.out.flush()
    }
}

/// Compact numeric formatting: integers without decimals, floats with 6.
pub fn format_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
            w.write_row(&["1", "x,y"]).unwrap();
            w.write_nums(&[2.5, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s, "a,b\n1,\"x,y\"\n2.500000,3\n");
    }

    #[test]
    fn escapes_quotes() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &[] as &[&str]).unwrap();
            w.write_row(&["he said \"hi\""]).unwrap();
        }
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s, "\"he said \"\"hi\"\"\"\n");
    }
}
