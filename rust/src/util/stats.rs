//! Descriptive statistics used by the bench harness, the profiler and the
//! experiment drivers (means, std, percentiles, confidence intervals).

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }

    /// Half-width of the ~95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n > 1 { self.m2 / (self.n - 1) as f64 } else { 0.0 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Simple moving average over a fixed window (learning-curve smoothing).
pub fn moving_average(xs: &[f64], window: usize) -> Vec<f64> {
    assert!(window > 0);
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        sum += x;
        if i >= window {
            sum -= xs[i - window];
        }
        out.push(sum / window.min(i + 1) as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - 1.5811388).abs() < 1e-6);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn moving_average_window() {
        let ma = moving_average(&[1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(ma, vec![1.0, 1.5, 2.5, 3.5]);
    }
}
