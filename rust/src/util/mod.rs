//! Shared substrates: deterministic RNG, statistics, timers, CSV/JSON
//! output and a tiny logger. All hand-rolled — the build is fully offline
//! (DESIGN.md §4) and the paper's own hardware URNG is an LFSR anyway.

pub mod csv;
pub mod error;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use error::{Context, Error};
pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
