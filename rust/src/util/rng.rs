//! Deterministic pseudo-random number generation.
//!
//! [`Rng`] is a SplitMix64-seeded xoshiro256++ generator: fast, high
//! quality, and reproducible across platforms — every experiment in
//! EXPERIMENTS.md records its seed. (The *hardware* URNG of the paper is a
//! 32-bit LFSR and lives in [`crate::hardware::urng`]; this one is the
//! software/simulation RNG.)

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's bounded method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-thread/per-env generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_unbiased_roughly() {
        let mut r = Rng::new(11);
        let n = 5usize;
        let mut counts = vec![0u32; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < expect * 0.1, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
