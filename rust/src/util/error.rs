//! Hand-rolled error type for the CLI/agent/runtime layers (offline build
//! — no `anyhow`; DESIGN.md §4). A string-carrying error with the three
//! ergonomics the codebase needs: `err!`/`bail!`/`ensure!` constructors,
//! `?`-conversions from the std error types we actually hit, and a
//! [`Context`] extension for annotating failures on the way up.

use std::fmt;

/// The crate-wide boxed-string error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (drop-in for the old `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    pub fn new(msg: String) -> Error {
        Error { msg }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<crate::config::ParseError> for Error {
    fn from(e: crate::config::ParseError) -> Error {
        Error::msg(e)
    }
}

/// `.context("...")` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::new(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::new(f()))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` stand-in).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke at {}", 42);
    }

    #[test]
    fn macros_build_messages() {
        let e = err!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        assert_eq!(fails().unwrap_err().to_string(), "broke at 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            Ok(n)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "n too big: 12");
    }

    #[test]
    fn context_annotates() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn from_std_errors() {
        let e: Error = "abc".parse::<u64>().unwrap_err().into();
        assert!(e.to_string().contains("invalid digit"));
    }
}
