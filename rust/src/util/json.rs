//! Minimal JSON parser/serializer — enough to read `artifacts/manifest.json`
//! and write experiment records. Hand-rolled because the build is offline
//! (DESIGN.md §4); supports the full JSON grammar except exotic number
//! forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Field access for objects; `None` for other variants/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    s.push_str(
                        std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"k":[1,2.5,"s",null,true]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"envs":{"cartpole":{"obs_dim":4,
            "dims":[4,128,128,2],"train_inputs":[{"shape":[64,4],
            "dtype":"float32"}]}}}"#;
        let j = Json::parse(src).unwrap();
        let cp = j.get("envs").unwrap().get("cartpole").unwrap();
        assert_eq!(cp.get("obs_dim").unwrap().as_usize(), Some(4));
        assert_eq!(
            cp.get("dims").unwrap().as_arr().unwrap().len(),
            4
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
