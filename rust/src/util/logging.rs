//! Tiny leveled logger (stderr). Controlled by `AMPER_LOG` = error|warn|
//! info|debug|trace (default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: std::sync::Once = std::sync::Once::new();

/// Initialize the level from `AMPER_LOG` (idempotent; called lazily).
pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("AMPER_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(lvl: Level) {
    init();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(lvl: Level) -> bool {
    init();
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Log a preformatted message at `lvl`.
pub fn log(lvl: Level, msg: &str) {
    if !enabled(lvl) {
        return;
    }
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let _ = writeln!(
        std::io::stderr(),
        "[{:>10}.{:03} {tag}] {msg}",
        t.as_secs(),
        t.subsec_millis()
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
