//! Wall-clock timing helpers for profiling and the bench harness.

use std::time::{Duration, Instant};

/// A scoped stopwatch accumulating named spans.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    #[inline]
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since construction.
    #[inline]
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed nanoseconds as f64.
    #[inline]
    pub fn ns(&self) -> f64 {
        self.start.elapsed().as_nanos() as f64
    }

    /// Elapsed microseconds as f64.
    #[inline]
    pub fn us(&self) -> f64 {
        self.ns() / 1_000.0
    }

    /// Elapsed milliseconds as f64.
    #[inline]
    pub fn ms(&self) -> f64 {
        self.ns() / 1_000_000.0
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.ns();
        std::thread::sleep(Duration::from_millis(1));
        let b = t.ns();
        assert!(b > a);
        assert!(t.ms() >= 1.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
