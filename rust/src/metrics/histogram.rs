//! Fixed-bin histogram over a known value range (the Fig 7a sampling
//! distribution visualization).

/// Equal-width histogram on `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    #[inline]
    pub fn bin_of(&self, x: f64) -> usize {
        let b = ((x - self.lo) / (self.hi - self.lo)
            * self.counts.len() as f64)
            .floor();
        (b.max(0.0) as usize).min(self.counts.len() - 1)
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        let b = self.bin_of(x);
        self.counts[b] += 1;
        self.total += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Normalized densities (sum = 1).
    pub fn density(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }

    /// Bin centers (for CSV output).
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_clamps() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(0.05);
        h.push(0.95);
        h.push(1.5); // clamped into last bin
        h.push(-0.5); // clamped into first bin
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn density_sums_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        let s: f64 = h.density().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn centers_are_midpoints() {
        let h = Histogram::new(0.0, 1.0, 2);
        assert_eq!(h.centers(), vec![0.25, 0.75]);
    }
}
