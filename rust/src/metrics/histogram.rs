//! Fixed-bin histogram over a known value range (the Fig 7a sampling
//! distribution visualization) and the lock-free log2-bucketed
//! [`LatencyHistogram`] used for per-stage serve-path telemetry.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{obj, Json};

/// Number of log2 buckets in a [`LatencyHistogram`]: bucket 39 covers
/// everything at or above 2^39 ns (~9 minutes) — far past any latency
/// the serve path can produce without already being a fault.
const LAT_BUCKETS: usize = 40;

/// Lock-free latency histogram with power-of-two nanosecond buckets.
///
/// Bucket `b` counts samples in `[2^b, 2^(b+1))` ns (bucket 0 also
/// absorbs 0 ns). Recording is a single `fetch_add` per counter, so the
/// histogram can sit on the hot path of every service stage and be read
/// concurrently by the stats reporter. Quantiles interpolate linearly
/// within the winning bucket, which bounds the error at 2x — plenty for
/// tail-latency telemetry where the bucket magnitude is the signal.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LAT_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample, in nanoseconds.
    #[inline]
    pub fn record(&self, ns: u64) {
        let b = if ns == 0 {
            0
        } else {
            ((63 - ns.leading_zeros()) as usize).min(LAT_BUCKETS - 1)
        };
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in ns (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Largest recorded sample in ns.
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate quantile `q` in [0, 1], in ns (0 when empty).
    ///
    /// Walks the buckets to the one holding the target rank, then
    /// interpolates linearly inside it.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            let c = bucket.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = if b == 0 { 0u64 } else { 1u64 << b };
                let hi = 1u64 << (b + 1);
                let frac = (target - seen) as f64 / c as f64;
                return lo as f64 + frac * (hi - lo) as f64;
            }
            seen += c;
        }
        self.max_ns() as f64
    }

    /// Serialize to JSON: summary quantiles plus the non-empty buckets.
    pub fn to_json(&self) -> Json {
        let mut buckets = Vec::new();
        for (b, bucket) in self.buckets.iter().enumerate() {
            let c = bucket.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            let lo = if b == 0 { 0u64 } else { 1u64 << b };
            buckets.push(obj(vec![
                ("lo_ns", Json::Num(lo as f64)),
                ("count", Json::Num(c as f64)),
            ]));
        }
        obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("p50_ns", Json::Num(self.quantile_ns(0.50))),
            ("p90_ns", Json::Num(self.quantile_ns(0.90))),
            ("p99_ns", Json::Num(self.quantile_ns(0.99))),
            ("max_ns", Json::Num(self.max_ns() as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Equal-width histogram on `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    #[inline]
    pub fn bin_of(&self, x: f64) -> usize {
        let b = ((x - self.lo) / (self.hi - self.lo)
            * self.counts.len() as f64)
            .floor();
        (b.max(0.0) as usize).min(self.counts.len() - 1)
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        let b = self.bin_of(x);
        self.counts[b] += 1;
        self.total += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Normalized densities (sum = 1).
    pub fn density(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }

    /// Bin centers (for CSV output).
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_clamps() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(0.05);
        h.push(0.95);
        h.push(1.5); // clamped into last bin
        h.push(-0.5); // clamped into first bin
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn density_sums_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        let s: f64 = h.density().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn centers_are_midpoints() {
        let h = Histogram::new(0.0, 1.0, 2);
        assert_eq!(h.centers(), vec![0.25, 0.75]);
    }

    #[test]
    fn latency_histogram_buckets_by_log2() {
        let h = LatencyHistogram::new();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1024);
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_ns(), 1024);
        assert!((h.mean_ns() - 206.0).abs() < 1e-9);
        // 0 and 1 share bucket 0; 2 and 3 land in bucket 1 = [2, 4)
        let p50 = h.quantile_ns(0.5);
        assert!(p50 >= 1.0 && p50 < 4.0, "p50 = {p50}");
        // the max dominates the tail
        assert!(h.quantile_ns(1.0) >= 1024.0);
    }

    #[test]
    fn latency_histogram_quantiles_interpolate() {
        let h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(10); // bucket 3 = [8, 16)
        }
        let p50 = h.quantile_ns(0.5);
        assert!((8.0..16.0).contains(&p50), "p50 = {p50}");
        assert_eq!(h.quantile_ns(0.0), h.quantile_ns(0.01));
    }

    #[test]
    fn latency_histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.99), 0.0);
    }

    #[test]
    fn latency_histogram_json_has_quantiles_and_buckets() {
        let h = LatencyHistogram::new();
        h.record(100);
        h.record(100_000);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(2));
        let buckets = j.get("buckets").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(buckets.len(), 2);
        assert!(j.get("p99_ns").and_then(|v| v.as_f64()).unwrap() > 100.0);
    }
}
