//! Metrics for the paper's evaluations: sampling-distribution histograms
//! and KL divergence (Fig 7), episode-return tracking (Fig 8 / Table 1),
//! and latency aggregation (Fig 4 / Fig 9).

pub mod histogram;
pub mod kl;
pub mod returns;

pub use histogram::{Histogram, LatencyHistogram};
pub use kl::{kl_divergence, kl_divergence_counts};
pub use returns::ReturnTracker;
