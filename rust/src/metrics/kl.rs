//! Kullback–Leibler divergence between sampling distributions, the
//! paper's sampling-error metric (§4.1.1):
//! `KL(P,Q) = Σ_i P[i]·ln(P[i]/Q[i])`, in nats.
//!
//! The paper computes KL between *per-item sample-count* distributions
//! accumulated over repeated batch draws (their reported magnitudes —
//! hundreds to thousands of nats — only arise with the summation taken
//! over raw counts rather than normalized frequencies; we reproduce that
//! convention in [`kl_divergence_counts`] and also provide the
//! normalized variant).

/// KL divergence over normalized distributions (nats). Zero-mass bins of
/// `p` contribute nothing; zero-mass bins of `q` are floored to `eps` to
/// keep the sum finite (the paper's runs never produce true zeros at
/// their sample counts).
pub fn kl_divergence(p: &[f64], q: &[f64], eps: f64) -> f64 {
    assert_eq!(p.len(), q.len());
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    assert!(sp > 0.0 && sq > 0.0);
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        let pn = pi / sp;
        if pn <= 0.0 {
            continue;
        }
        let qn = (qi / sq).max(eps);
        kl += pn * (pn / qn).ln();
    }
    kl
}

/// The paper's convention: KL over raw per-item sample counts
/// (`SUM(P[i]*log(P[i]/Q[i]))` with P, Q the count vectors). Zero counts
/// are floored at `floor` (default 0.5, half an observation).
pub fn kl_divergence_counts(p: &[u32], q: &[u32], floor: f64) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi == 0 {
            continue;
        }
        let pf = pi as f64;
        let qf = (qi as f64).max(floor);
        kl += pf * (pf / qf).ln();
    }
    kl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_distributions_zero() {
        let p = [0.25, 0.25, 0.5];
        assert!(kl_divergence(&p, &p, 1e-12).abs() < 1e-12);
        let c = [10u32, 20, 30];
        assert_eq!(kl_divergence_counts(&c, &c, 0.5), 0.0);
    }

    #[test]
    fn asymmetric_and_positive() {
        let p = [0.9, 0.1];
        let q = [0.5, 0.5];
        let kl_pq = kl_divergence(&p, &q, 1e-12);
        let kl_qp = kl_divergence(&q, &p, 1e-12);
        assert!(kl_pq > 0.0 && kl_qp > 0.0);
        assert!((kl_pq - kl_qp).abs() > 1e-3);
    }

    #[test]
    fn known_value() {
        // KL([1,0],[0.5,0.5]) = ln 2
        let kl = kl_divergence(&[1.0, 0.0], &[0.5, 0.5], 1e-12);
        assert!((kl - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn counts_scale_with_mass() {
        // doubling all counts doubles the count-convention KL
        let p = [100u32, 3];
        let q = [50u32, 50];
        let p2 = [200u32, 6];
        let q2 = [100u32, 100];
        let a = kl_divergence_counts(&p, &q, 0.5);
        let b = kl_divergence_counts(&p2, &q2, 0.5);
        assert!((b / a - 2.0).abs() < 1e-6);
    }

    #[test]
    fn unnormalized_inputs_ok_for_normalized_variant() {
        let a = kl_divergence(&[2.0, 2.0], &[1.0, 3.0], 1e-12);
        let b = kl_divergence(&[0.5, 0.5], &[0.25, 0.75], 1e-12);
        assert!((a - b).abs() < 1e-12);
    }
}
