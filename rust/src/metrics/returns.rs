//! Episode-return tracking for learning curves (Fig 8) and final test
//! scores (Table 1).

use crate::util::stats::moving_average;

/// Accumulates per-episode returns during training/testing.
#[derive(Debug, Clone, Default)]
pub struct ReturnTracker {
    current: f64,
    episodes: Vec<f64>,
    /// (env_step, return) pairs for step-aligned curves.
    by_step: Vec<(u64, f64)>,
}

impl ReturnTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one step's reward.
    #[inline]
    pub fn push_reward(&mut self, r: f64) {
        self.current += r;
    }

    /// Close the episode at global step `step`; returns the episode score.
    pub fn end_episode(&mut self, step: u64) -> f64 {
        let score = self.current;
        self.episodes.push(score);
        self.by_step.push((step, score));
        self.current = 0.0;
        score
    }

    pub fn episodes(&self) -> &[f64] {
        &self.episodes
    }

    pub fn by_step(&self) -> &[(u64, f64)] {
        &self.by_step
    }

    pub fn n_episodes(&self) -> usize {
        self.episodes.len()
    }

    /// Mean return over the last `n` episodes.
    pub fn recent_mean(&self, n: usize) -> f64 {
        if self.episodes.is_empty() {
            return 0.0;
        }
        let tail = &self.episodes[self.episodes.len().saturating_sub(n)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// Smoothed learning curve.
    pub fn smoothed(&self, window: usize) -> Vec<f64> {
        moving_average(&self.episodes, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_resets() {
        let mut t = ReturnTracker::new();
        t.push_reward(1.0);
        t.push_reward(2.0);
        assert_eq!(t.end_episode(10), 3.0);
        t.push_reward(5.0);
        assert_eq!(t.end_episode(20), 5.0);
        assert_eq!(t.episodes(), &[3.0, 5.0]);
        assert_eq!(t.by_step(), &[(10, 3.0), (20, 5.0)]);
    }

    #[test]
    fn recent_mean_windows() {
        let mut t = ReturnTracker::new();
        for i in 0..10 {
            t.push_reward(i as f64);
            t.end_episode(i);
        }
        assert_eq!(t.recent_mean(2), 8.5);
        assert_eq!(t.recent_mean(100), 4.5);
    }

    #[test]
    fn empty_recent_mean_is_zero() {
        assert_eq!(ReturnTracker::new().recent_mean(5), 0.0);
    }
}
