//! The replay service: single-owner ER memory behind bounded channels.
//!
//! Design: one worker thread owns the `Box<dyn ReplayMemory>` (no locks
//! on the data structure itself — the paper's hardware has a single
//! search/write port pair, and a single-owner loop mirrors that while
//! keeping the Rust side allocation-free on the hot path). Actors and
//! learners talk to it through a command queue with a bounded depth;
//! senders block when the queue is full (backpressure).
//!
//! The command protocol is **batch-first** (paper §4: one wide parallel
//! operation per batch, not one tree walk per element): experiences move
//! as [`ExperienceBatch`]es — a scalar [`ServiceHandle::push`] is just a
//! one-row batch — and TD errors travel as one coalesced
//! `UpdatePriorities` message per sampled batch.
//!
//! The same worker loop serves one memory here and one memory *per
//! shard* in [`super::sharded::ShardedReplayService`]; both services
//! expose the same push / push_batch / sample / sample_gathered /
//! update_priorities surface.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::pool::{PendingGather, PendingInner, ReplyPool};
use crate::replay::{
    Experience, ExperienceBatch, GatheredBatch, ReplayMemory, SampledBatch,
};
use crate::util::error::Result;
use crate::util::Rng;

/// Idle reply buffers kept per pool when no explicit bound is configured
/// (covers pipeline depths up to ~6 with one buffer in training).
pub const DEFAULT_REPLY_POOL: usize = 8;

/// Commands accepted by the (shared) service worker loop.
pub(crate) enum Command {
    /// Store a whole batch of transitions (a scalar push is a 1-row batch).
    PushBatch(ExperienceBatch),
    Sample {
        batch: usize,
        reply: SyncSender<SampledBatch>,
    },
    /// Gather a batch's transitions into flat buffers and reply. The
    /// reply carries a `Result`: index validation at the ring boundary
    /// surfaces as a proper error, never as silently stale rows. `buf`
    /// is an optional lent reply buffer (a pool hit): the worker gathers
    /// directly into it instead of allocating.
    SampleGathered {
        batch: usize,
        buf: Option<GatheredBatch>,
        reply: SyncSender<Result<GatheredBatch>>,
    },
    UpdatePriorities {
        indices: Vec<usize>,
        td: Vec<f32>,
    },
    Stop,
}

/// Counters exported by the service. Only *accepted* commands count: a
/// `push`/`update_priorities` that fails because the worker has stopped
/// is reported to the caller and not recorded here. `pushes` counts
/// transitions (batch rows), not messages.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub pushes: AtomicU64,
    pub samples: AtomicU64,
    pub updates: AtomicU64,
}

/// Sample + gather inside the owner thread (the ring is hot in cache)
/// **into the lent reply buffer**: `scratch` holds the sampled
/// indices/weights across calls and `g` is resized in place, so a warm
/// (recycled) buffer makes this path allocation-free.
fn sample_gathered_locked(
    memory: &mut dyn ReplayMemory,
    batch: usize,
    rng: &mut Rng,
    scratch: &mut SampledBatch,
    mut g: GatheredBatch,
) -> Result<GatheredBatch> {
    memory.sample_into(batch, rng, scratch);
    let d = memory.ring().obs_dim();
    let n = scratch.indices.len();
    g.reset(n, d);
    g.indices.copy_from_slice(&scratch.indices);
    g.is_weights.copy_from_slice(&scratch.is_weights);
    memory.ring().gather(
        &g.indices,
        &mut g.obs,
        &mut g.actions,
        &mut g.rewards,
        &mut g.next_obs,
        &mut g.dones,
    )?;
    Ok(g)
}

/// The single-owner worker loop: drains commands until `Stop` (or all
/// senders hang up) and returns the memory for inspection. Shared by
/// [`ReplayService`] and the per-shard workers of the sharded service.
pub(crate) fn run_worker(
    mut memory: Box<dyn ReplayMemory>,
    rx: Receiver<Command>,
    mut rng: Rng,
) -> Box<dyn ReplayMemory> {
    // scratch reused across commands (allocation-free loop)
    let mut slots = Vec::new();
    let mut sampled = SampledBatch::default();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::PushBatch(b) => {
                slots.clear();
                memory.push_batch(&b, &mut rng, &mut slots);
            }
            Command::Sample { batch, reply } => {
                let b = if memory.is_empty() {
                    SampledBatch::default()
                } else {
                    memory.sample(batch, &mut rng)
                };
                let _ = reply.send(b);
            }
            Command::SampleGathered { batch, buf, reply } => {
                let mut g = buf.unwrap_or_default();
                let out = if memory.is_empty() {
                    g.reset(0, 0);
                    Ok(g)
                } else {
                    sample_gathered_locked(
                        memory.as_mut(),
                        batch,
                        &mut rng,
                        &mut sampled,
                        g,
                    )
                };
                let _ = reply.send(out);
            }
            Command::UpdatePriorities { indices, td } => {
                memory.update_priorities_batch(&indices, &td);
            }
            Command::Stop => break,
        }
    }
    memory
}

/// Cloneable handle for actors/learners.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Command>,
    stats: Arc<ServiceStats>,
    pool: ReplyPool,
}

impl ServiceHandle {
    /// Store one experience (blocks under backpressure). Returns whether
    /// the service accepted the command; `false` means the worker has
    /// stopped and the experience was dropped. This is the scalar
    /// convenience over [`Self::push_batch`] (a 1-row batch).
    #[must_use = "a false return means the service dropped the experience"]
    pub fn push(&self, e: Experience) -> bool {
        self.push_batch(ExperienceBatch::from_experience(e))
    }

    /// Store a whole batch in one command (blocks under backpressure).
    /// Returns whether the service accepted it; `false` means the worker
    /// has stopped and the batch was dropped. Empty batches are accepted
    /// without a round trip.
    #[must_use = "a false return means the service dropped the batch"]
    pub fn push_batch(&self, batch: ExperienceBatch) -> bool {
        let rows = batch.len() as u64;
        if rows == 0 {
            return true;
        }
        match self.tx.send(Command::PushBatch(batch)) {
            Ok(()) => {
                self.stats.pushes.fetch_add(rows, Ordering::Relaxed);
                true
            }
            Err(_) => false,
        }
    }

    /// Request a batch of slot indices + weights.
    ///
    /// # Panics
    /// Panics if the service worker has stopped — sampling from a dead
    /// service is a coordination bug, unlike the racy fire-and-forget
    /// `push`/`update_priorities` which report failure instead.
    pub fn sample(&self, batch: usize) -> SampledBatch {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Command::Sample { batch, reply: reply_tx })
            .expect("service stopped");
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        reply_rx.recv().expect("service dropped reply")
    }

    /// Request a fully gathered batch (single round trip; the gather runs
    /// inside the owner thread where the ring is hot in cache). An `Err`
    /// means the worker caught a corrupt index at the ring boundary.
    ///
    /// Equivalent to `request_gathered(batch).wait()`; use
    /// [`Self::request_gathered`] + a later `wait` to pipeline requests.
    ///
    /// # Panics
    /// Panics if the service worker has stopped (see [`Self::sample`]).
    pub fn sample_gathered(&self, batch: usize) -> Result<GatheredBatch> {
        self.request_gathered(batch).wait()
    }

    /// Issue a gather request **without waiting for the reply**: attaches
    /// a pooled reply buffer when one is available (the worker gathers
    /// directly into it) and returns the in-flight handle. A pipelined
    /// learner issues request N+1 before training on batch N.
    ///
    /// # Panics
    /// Panics if the service worker has stopped (see [`Self::sample`]).
    pub fn request_gathered(&self, batch: usize) -> PendingGather {
        let (reply_tx, reply_rx) = sync_channel(1);
        let buf = self.pool.take();
        self.tx
            .send(Command::SampleGathered { batch, buf, reply: reply_tx })
            .expect("service stopped");
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        PendingGather { inner: PendingInner::Single { rx: reply_rx } }
    }

    /// Return a consumed reply buffer to the pool so the next
    /// `sample_gathered` refills it in place instead of allocating.
    pub fn recycle(&self, buf: GatheredBatch) {
        self.pool.put(buf);
    }

    /// The gathered-reply buffer pool (stats + the `reply_pool` knob).
    pub fn reply_pool(&self) -> &ReplyPool {
        &self.pool
    }

    /// Feed back TD errors for a previously sampled batch — one coalesced
    /// message for the whole batch. Returns whether the service accepted
    /// the update.
    #[must_use = "a false return means the priority update was dropped"]
    pub fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        match self.tx.send(Command::UpdatePriorities { indices, td }) {
            Ok(()) => {
                self.stats.updates.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => false,
        }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }
}

/// The running service (owns the worker thread).
pub struct ReplayService {
    handle: ServiceHandle,
    worker: Option<JoinHandle<Box<dyn ReplayMemory>>>,
}

impl ReplayService {
    /// Spawn the service around `memory`. `queue_depth` bounds the
    /// command queue (backpressure knob).
    pub fn spawn(
        memory: Box<dyn ReplayMemory>,
        queue_depth: usize,
        seed: u64,
    ) -> ReplayService {
        let (tx, rx): (SyncSender<Command>, Receiver<Command>) =
            sync_channel(queue_depth);
        let stats = Arc::new(ServiceStats::default());
        let worker = std::thread::Builder::new()
            .name("replay-service".into())
            .spawn(move || run_worker(memory, rx, Rng::new(seed)))
            .expect("spawn replay service");
        ReplayService {
            handle: ServiceHandle {
                tx,
                stats,
                pool: ReplyPool::new(DEFAULT_REPLY_POOL),
            },
            worker: Some(worker),
        }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Stop the worker and recover the memory (for inspection).
    pub fn stop(mut self) -> Box<dyn ReplayMemory> {
        let _ = self.handle.tx.send(Command::Stop);
        self.worker.take().unwrap().join().expect("service panicked")
    }
}

impl Drop for ReplayService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.handle.tx.send(Command::Stop);
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayKind, UniformReplay};

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    #[test]
    fn push_sample_update_roundtrip() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Per, 128),
            64,
            0,
        );
        let h = svc.handle();
        for i in 0..100 {
            assert!(h.push(exp(i as f32)));
        }
        let b = h.sample(32);
        assert_eq!(b.indices.len(), 32);
        assert!(h.update_priorities(b.indices.clone(), vec![1.0; 32]));
        let mem = svc.stop();
        assert_eq!(mem.len(), 100);
    }

    #[test]
    fn push_batch_counts_rows_and_stores_them() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(256)), 16, 0);
        let h = svc.handle();
        let exps: Vec<Experience> = (0..40).map(|i| exp(i as f32)).collect();
        assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
        assert!(h.push_batch(ExperienceBatch::new(4)), "empty batch is a no-op");
        let mem = svc.stop();
        assert_eq!(mem.len(), 40);
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 40);
        // rows landed in push order
        for i in 0..40 {
            assert_eq!(mem.ring().reward_of(i), i as f32);
        }
    }

    #[test]
    fn gathered_batch_has_flat_buffers() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(64)), 16, 1);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(16).unwrap();
        assert_eq!(g.obs.len(), 16 * 4);
        assert_eq!(g.actions.len(), 16);
        // obs content matches the sampled indices
        for (row, &idx) in g.indices.iter().enumerate() {
            assert_eq!(g.obs[row * 4], idx as f32);
        }
    }

    #[test]
    fn recycled_buffer_is_refilled_in_place() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(64)), 16, 5);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g1 = h.sample_gathered(16).unwrap();
        let obs_ptr = g1.obs.as_ptr() as usize;
        h.recycle(g1);
        let g2 = h.sample_gathered(16).unwrap();
        assert_eq!(
            g2.obs.as_ptr() as usize,
            obs_ptr,
            "pool hit must reuse the recycled buffer's allocation"
        );
        assert_eq!(g2.rows(), 16);
        assert_eq!(h.reply_pool().stats().hits.load(Ordering::Relaxed), 1);
        assert_eq!(h.reply_pool().stats().misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_actors_and_learner() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::AmperFr, 4096),
            256,
            2,
        );
        let mut producers = Vec::new();
        for t in 0..4 {
            let h = svc.handle();
            producers.push(std::thread::spawn(move || {
                for i in 0..500 {
                    assert!(h.push(exp((t * 1000 + i) as f32)));
                }
            }));
        }
        let learner = {
            let h = svc.handle();
            std::thread::spawn(move || {
                let mut drawn = 0usize;
                for _ in 0..50 {
                    let b = h.sample(32);
                    if !b.indices.is_empty() {
                        assert!(
                            h.update_priorities(b.indices.clone(), vec![0.5; 32])
                        );
                        drawn += b.indices.len();
                    }
                }
                drawn
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        let drawn = learner.join().unwrap();
        assert!(drawn > 0);
        let stats = svc.handle();
        assert_eq!(
            stats.stats().pushes.load(Ordering::Relaxed),
            2000
        );
        let mem = svc.stop();
        assert_eq!(mem.len(), 2000);
    }

    #[test]
    fn sample_on_empty_returns_empty() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(8)), 4, 3);
        let b = svc.handle().sample(4);
        assert!(b.indices.is_empty());
        let g = svc.handle().sample_gathered(4).unwrap();
        assert!(g.indices.is_empty());
    }

    #[test]
    fn commands_after_stop_are_reported_not_counted() {
        // regression: push/update used to increment the counters and then
        // silently drop the send error, so stats overstated work after
        // the worker stopped.
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(8)), 4, 4);
        let h = svc.handle();
        assert!(h.push(exp(1.0)));
        let _mem = svc.stop();
        assert!(!h.push(exp(2.0)), "push after stop must report failure");
        assert!(!h.update_priorities(vec![0], vec![0.1]));
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().updates.load(Ordering::Relaxed), 0);
    }
}
