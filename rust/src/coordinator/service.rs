//! The replay service: single-owner ER memory behind bounded channels.
//!
//! Design: one worker thread owns the `Box<dyn ReplayMemory>` (no locks
//! on the data structure itself — the paper's hardware has a single
//! search/write port pair, and a single-owner loop mirrors that while
//! keeping the Rust side allocation-free on the hot path). Actors and
//! learners talk to it through a command queue with a bounded depth;
//! senders block when the queue is full (backpressure).
//!
//! The command protocol is **batch-first** (paper §4: one wide parallel
//! operation per batch, not one tree walk per element): experiences move
//! as [`ExperienceBatch`]es — a scalar [`ServiceHandle::push`] is just a
//! one-row batch — and TD errors travel as one coalesced
//! `UpdatePriorities` message per sampled batch.
//!
//! The same worker loop serves one memory here and one memory *per
//! shard* in [`super::sharded::ShardedReplayService`]; both services
//! expose the same push / push_batch / sample / sample_gathered /
//! update_priorities surface.
//!
//! **Operability** (README §Operability): every stage of the serve path
//! records into the lock-free per-stage [`LatencyHistogram`]s in
//! [`ServiceStats::stages`], the command-queue depth is tracked by a
//! [`QueueGauge`] (the adaptive-flush signal), gathered waits are
//! bounded by a per-handle timeout instead of blocking forever on a
//! dead worker, and the `testing` cargo feature compiles a [`FaultPlan`]
//! into the worker loop so tests can delay, drop, or kill mid-stream.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::pool::{PendingGather, PendingInner, ReplyPool};
use crate::metrics::LatencyHistogram;
use crate::replay::{
    Experience, ExperienceBatch, GatheredBatch, ReplayMemory, SampledBatch,
};
use crate::util::error::Result;
use crate::util::json::{obj, Json};
use crate::util::{Rng, Timer};

/// Idle reply buffers kept per pool when no explicit bound is configured
/// (covers pipeline depths up to ~6 with one buffer in training).
pub const DEFAULT_REPLY_POOL: usize = 8;

/// Default bound on a single gathered-reply wait. Generous — it exists
/// so a dead or wedged worker surfaces as an error instead of hanging
/// the learner forever; tighten per handle via
/// [`ServiceHandle::set_gather_timeout`] to trade truncated sharded
/// batches for bounded tail latency.
pub const DEFAULT_GATHER_TIMEOUT_MS: u64 = 30_000;

/// Commands accepted by the (shared) service worker loop.
pub(crate) enum Command {
    /// Store a whole batch of transitions (a scalar push is a 1-row batch).
    PushBatch(ExperienceBatch),
    Sample {
        batch: usize,
        reply: SyncSender<SampledBatch>,
    },
    /// Gather a batch's transitions into flat buffers and reply. The
    /// reply carries a `Result`: index validation at the ring boundary
    /// surfaces as a proper error, never as silently stale rows. `buf`
    /// is an optional lent reply buffer (a pool hit): the worker gathers
    /// directly into it instead of allocating.
    SampleGathered {
        batch: usize,
        buf: Option<GatheredBatch>,
        reply: SyncSender<Result<GatheredBatch>>,
    },
    UpdatePriorities {
        indices: Vec<usize>,
        td: Vec<f32>,
    },
    Stop,
}

/// Counters exported by the service. Only *accepted* commands count: a
/// `push`/`update_priorities` that fails because the worker has stopped
/// is reported to the caller and not recorded here. `pushes` counts
/// transitions (batch rows), not messages.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub pushes: AtomicU64,
    pub samples: AtomicU64,
    pub updates: AtomicU64,
    /// Shard replies that missed the gather timeout; the merge served
    /// the batch short instead of blocking on the slow shard.
    pub shard_timeouts: AtomicU64,
    /// Rows requested from timed-out shards and therefore not served.
    pub truncated_rows: AtomicU64,
    /// Per-stage latency histograms along the serve path.
    pub stages: StageLatencies,
    /// Policy-snapshot staleness (publishes, current epoch, actor
    /// epochs-behind). Shared with the serve loop's
    /// [`SnapshotSlot`](super::SnapshotSlot) via
    /// [`SnapshotSlot::with_stats`](super::SnapshotSlot::with_stats);
    /// stays all-zero when no snapshot layer is wired.
    pub snapshot: Arc<super::snapshot::SnapshotStats>,
}

impl ServiceStats {
    /// Counter snapshot as JSON. The per-stage histograms are reported
    /// separately (see [`ServiceHandle::stats_json`]).
    pub fn to_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("pushes", n(&self.pushes)),
            ("samples", n(&self.samples)),
            ("updates", n(&self.updates)),
            ("shard_timeouts", n(&self.shard_timeouts)),
            ("truncated_rows", n(&self.truncated_rows)),
        ])
    }
}

/// Lock-free latency histograms for each stage of the serve path. All
/// four are recorded with single relaxed atomics, so they can sit on
/// the hot path and be snapshotted concurrently by the stats reporter.
#[derive(Debug, Default)]
pub struct StageLatencies {
    /// Actor flush: `push_batch` called → command accepted by the queue
    /// (includes time blocked under backpressure).
    pub flush: LatencyHistogram,
    /// Worker-side sample + gather into the reply buffer.
    pub gather: LatencyHistogram,
    /// Learner-side reply wait: receive + (sharded) offset merge.
    pub merge: LatencyHistogram,
    /// Learner train step on a gathered batch.
    pub train: LatencyHistogram,
}

impl StageLatencies {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("flush_accept", self.flush.to_json()),
            ("worker_gather", self.gather.to_json()),
            ("reply_merge", self.merge.to_json()),
            ("train_step", self.train.to_json()),
        ])
    }
}

/// Depth telemetry for one worker's bounded command queue.
///
/// `std::sync::mpsc` exposes no queue length, so the handle increments
/// *before* each send and the worker decrements once per received
/// command. `depth` therefore counts in-flight commands including any
/// sender currently blocked under backpressure — exactly the signal the
/// adaptive actor flush wants to see.
#[derive(Debug)]
pub struct QueueGauge {
    depth: AtomicUsize,
    capacity: usize,
}

impl QueueGauge {
    pub(crate) fn new(capacity: usize) -> Arc<QueueGauge> {
        Arc::new(QueueGauge {
            depth: AtomicUsize::new(0),
            capacity: capacity.max(1),
        })
    }

    #[inline]
    pub(crate) fn inc(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement: a failed-send rollback racing a worker-side
    /// decrement must never underflow the gauge.
    #[inline]
    pub(crate) fn dec(&self) {
        let _ = self.depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| Some(d.saturating_sub(1)),
        );
    }

    /// In-flight commands (queued + blocked senders).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Configured queue bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fill fraction; exceeds 1.0 while senders block on a full queue.
    pub fn load(&self) -> f64 {
        self.depth() as f64 / self.capacity as f64
    }
}

/// Fault-injection plan for one service worker.
///
/// All fields (and all behavior) exist only under the `testing` cargo
/// feature; in a production build this is a zero-sized no-op and the
/// worker loop carries no fault branches. Tests build plans against
/// [`ReplayService::spawn_with_faults`] /
/// [`super::ShardedReplayService::spawn_with_faults`].
#[derive(Debug, Default, Clone)]
pub struct FaultPlan {
    /// Sleep this long inside every gather before replying (stalls the
    /// shard past the learner's gather timeout).
    #[cfg(feature = "testing")]
    pub delay_gather: Option<Duration>,
    /// Sleep this long before applying each push batch (slow consumer:
    /// backs the command queue up against its bound).
    #[cfg(feature = "testing")]
    pub delay_push: Option<Duration>,
    /// Swallow (never send) the next N gather replies.
    #[cfg(feature = "testing")]
    pub drop_gather_replies: u64,
    /// Exit the worker loop upon *receiving* the Nth command (1-based),
    /// before serving it — the channel disconnects mid-stream exactly
    /// like a crashed worker thread.
    #[cfg(feature = "testing")]
    pub die_after_commands: Option<u64>,
}

impl FaultPlan {
    #[inline]
    fn should_die(&self, seen: u64) -> bool {
        #[cfg(feature = "testing")]
        let die = self.die_after_commands.is_some_and(|n| seen >= n);
        #[cfg(not(feature = "testing"))]
        let die = false;
        #[cfg(not(feature = "testing"))]
        let _ = seen;
        die
    }

    #[inline]
    fn gather_delay(&self) -> Option<Duration> {
        #[cfg(feature = "testing")]
        let d = self.delay_gather;
        #[cfg(not(feature = "testing"))]
        let d = None;
        d
    }

    #[inline]
    fn push_delay(&self) -> Option<Duration> {
        #[cfg(feature = "testing")]
        let d = self.delay_push;
        #[cfg(not(feature = "testing"))]
        let d = None;
        d
    }

    /// Consume one unit of the reply-drop budget.
    #[inline]
    fn take_drop(&mut self) -> bool {
        #[cfg(feature = "testing")]
        if self.drop_gather_replies > 0 {
            self.drop_gather_replies -= 1;
            return true;
        }
        false
    }
}

/// Sample + gather inside the owner thread (the ring is hot in cache)
/// **into the lent reply buffer**: `scratch` holds the sampled
/// indices/weights across calls and `g` is resized in place, so a warm
/// (recycled) buffer makes this path allocation-free.
fn sample_gathered_locked(
    memory: &mut dyn ReplayMemory,
    batch: usize,
    rng: &mut Rng,
    scratch: &mut SampledBatch,
    mut g: GatheredBatch,
) -> Result<GatheredBatch> {
    memory.sample_into(batch, rng, scratch);
    let d = memory.ring().obs_dim();
    let n = scratch.indices.len();
    g.reset(n, d);
    g.indices.copy_from_slice(&scratch.indices);
    g.is_weights.copy_from_slice(&scratch.is_weights);
    memory.ring().gather(
        &g.indices,
        &mut g.obs,
        &mut g.actions,
        &mut g.rewards,
        &mut g.next_obs,
        &mut g.dones,
    )?;
    Ok(g)
}

/// The single-owner worker loop: drains commands until `Stop` (or all
/// senders hang up) and returns the memory for inspection. Shared by
/// [`ReplayService`] and the per-shard workers of the sharded service.
///
/// Each received command decrements `gauge` (paired with the sender-side
/// increment) and times its gather work into `stats.stages.gather`.
/// `faults` is a no-op [`FaultPlan`] outside the `testing` feature.
pub(crate) fn run_worker(
    mut memory: Box<dyn ReplayMemory>,
    rx: Receiver<Command>,
    mut rng: Rng,
    stats: Arc<ServiceStats>,
    gauge: Arc<QueueGauge>,
    mut faults: FaultPlan,
) -> Box<dyn ReplayMemory> {
    // scratch reused across commands (allocation-free loop)
    let mut slots = Vec::new();
    let mut sampled = SampledBatch::default();
    let mut seen = 0u64;
    while let Ok(cmd) = rx.recv() {
        gauge.dec();
        seen += 1;
        if faults.should_die(seen) {
            // simulate a crash: drop the command unserved (its reply
            // sender disconnects) and abandon everything still queued
            break;
        }
        match cmd {
            Command::PushBatch(b) => {
                if let Some(d) = faults.push_delay() {
                    std::thread::sleep(d);
                }
                slots.clear();
                memory.push_batch(&b, &mut rng, &mut slots);
            }
            Command::Sample { batch, reply } => {
                let b = if memory.is_empty() {
                    SampledBatch::default()
                } else {
                    memory.sample(batch, &mut rng)
                };
                let _ = reply.send(b);
            }
            Command::SampleGathered { batch, buf, reply } => {
                let t = Timer::start();
                if let Some(d) = faults.gather_delay() {
                    std::thread::sleep(d);
                }
                let mut g = buf.unwrap_or_default();
                let out = if memory.is_empty() {
                    g.reset(0, 0);
                    Ok(g)
                } else {
                    sample_gathered_locked(
                        memory.as_mut(),
                        batch,
                        &mut rng,
                        &mut sampled,
                        g,
                    )
                };
                // injected delays land in the histogram on purpose: a
                // stalled shard must show up in the gather tail
                stats.stages.gather.record(t.ns() as u64);
                if !faults.take_drop() {
                    let _ = reply.send(out);
                }
            }
            Command::UpdatePriorities { indices, td } => {
                memory.update_priorities_batch(&indices, &td);
            }
            Command::Stop => break,
        }
    }
    memory
}

/// Cloneable handle for actors/learners.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Command>,
    stats: Arc<ServiceStats>,
    pool: ReplyPool,
    gauge: Arc<QueueGauge>,
    timeout_ms: Arc<AtomicU64>,
}

impl ServiceHandle {
    /// Store one experience (blocks under backpressure). Returns whether
    /// the service accepted the command; `false` means the worker has
    /// stopped and the experience was dropped. This is the scalar
    /// convenience over [`Self::push_batch`] (a 1-row batch).
    #[must_use = "a false return means the service dropped the experience"]
    pub fn push(&self, e: Experience) -> bool {
        self.push_batch(ExperienceBatch::from_experience(e))
    }

    /// Store a whole batch in one command (blocks under backpressure).
    /// Returns whether the service accepted it; `false` means the worker
    /// has stopped and the batch was dropped. Empty batches are accepted
    /// without a round trip.
    #[must_use = "a false return means the service dropped the batch"]
    pub fn push_batch(&self, batch: ExperienceBatch) -> bool {
        let rows = batch.len() as u64;
        if rows == 0 {
            return true;
        }
        let t = Timer::start();
        self.gauge.inc();
        match self.tx.send(Command::PushBatch(batch)) {
            Ok(()) => {
                self.stats.stages.flush.record(t.ns() as u64);
                self.stats.pushes.fetch_add(rows, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.gauge.dec();
                false
            }
        }
    }

    /// Request a batch of slot indices + weights.
    ///
    /// # Panics
    /// Panics if the service worker has stopped — sampling from a dead
    /// service is a coordination bug, unlike the racy fire-and-forget
    /// `push`/`update_priorities` which report failure instead.
    pub fn sample(&self, batch: usize) -> SampledBatch {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.gauge.inc();
        self.tx
            .send(Command::Sample { batch, reply: reply_tx })
            .expect("service stopped");
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        reply_rx.recv().expect("service dropped reply")
    }

    /// Request a fully gathered batch (single round trip; the gather runs
    /// inside the owner thread where the ring is hot in cache). An `Err`
    /// means the worker caught a corrupt index at the ring boundary, has
    /// stopped, or missed the gather timeout — a gathered request never
    /// panics and never blocks past [`Self::gather_timeout`].
    ///
    /// Equivalent to `request_gathered(batch).wait()`; use
    /// [`Self::request_gathered`] + a later `wait` to pipeline requests.
    pub fn sample_gathered(&self, batch: usize) -> Result<GatheredBatch> {
        self.request_gathered(batch).wait()
    }

    /// Issue a gather request **without waiting for the reply**: attaches
    /// a pooled reply buffer when one is available (the worker gathers
    /// directly into it) and returns the in-flight handle. A pipelined
    /// learner issues request N+1 before training on batch N.
    ///
    /// If the worker has stopped, nothing is sent: the lent buffer goes
    /// straight back to the pool and the returned handle resolves to an
    /// error from `wait()` (never a panic, never a hang).
    pub fn request_gathered(&self, batch: usize) -> PendingGather {
        self.request_gathered_into(batch, &self.pool)
    }

    /// [`Self::request_gathered`] drawing the reply buffer from (and
    /// settling recovery into) an explicit `pool` instead of the handle's
    /// own — the net server issues each client's gathers against that
    /// client's private pool so tenants cannot starve each other's
    /// buffers.
    pub(crate) fn request_gathered_into(
        &self,
        batch: usize,
        pool: &ReplyPool,
    ) -> PendingGather {
        let (reply_tx, reply_rx) = sync_channel(1);
        let buf = pool.take();
        self.gauge.inc();
        let cmd = Command::SampleGathered { batch, buf, reply: reply_tx };
        match self.tx.send(cmd) {
            Ok(()) => {
                self.stats.samples.fetch_add(1, Ordering::Relaxed);
                PendingGather {
                    inner: PendingInner::Single {
                        rx: reply_rx,
                        timeout: self.gather_timeout(),
                        pool: pool.clone(),
                        stats: Arc::clone(&self.stats),
                    },
                }
            }
            Err(e) => {
                self.gauge.dec();
                // recover the lent buffer from the unsent command so a
                // dead worker never leaks pooled capacity; a miss-path
                // request has no buffer, so balance its take instead
                match e.0 {
                    Command::SampleGathered { buf: Some(b), .. } => pool.put(b),
                    _ => pool.note_lost(),
                }
                PendingGather { inner: PendingInner::Dead }
            }
        }
    }

    /// Return a consumed reply buffer to the pool so the next
    /// `sample_gathered` refills it in place instead of allocating.
    pub fn recycle(&self, buf: GatheredBatch) {
        self.pool.put(buf);
    }

    /// The gathered-reply buffer pool (stats + the `reply_pool` knob).
    pub fn reply_pool(&self) -> &ReplyPool {
        &self.pool
    }

    /// Feed back TD errors for a previously sampled batch — one coalesced
    /// message for the whole batch. Returns whether the service accepted
    /// the update.
    #[must_use = "a false return means the priority update was dropped"]
    pub fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        self.gauge.inc();
        match self.tx.send(Command::UpdatePriorities { indices, td }) {
            Ok(()) => {
                self.stats.updates.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.gauge.dec();
                false
            }
        }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Depth telemetry for the command queue (adaptive-flush signal).
    pub fn queue_gauge(&self) -> &QueueGauge {
        &self.gauge
    }

    /// Bound every gathered-reply wait issued through this handle (and
    /// its clones) from now on. Already-issued requests keep the timeout
    /// they were created with.
    pub fn set_gather_timeout(&self, timeout: Duration) {
        let ms = timeout.as_millis().clamp(1, u64::MAX as u128) as u64;
        self.timeout_ms.store(ms, Ordering::Relaxed);
    }

    /// Current gathered-reply wait bound.
    pub fn gather_timeout(&self) -> Duration {
        Duration::from_millis(self.timeout_ms.load(Ordering::Relaxed))
    }

    /// Full operability snapshot: counters, per-stage latency
    /// histograms, queue depth, and reply-pool accounting. This is what
    /// `amper serve --stats-json` dumps for CI artifacts.
    pub fn stats_json(&self) -> Json {
        obj(vec![
            ("service", self.stats.to_json()),
            ("stages", self.stats.stages.to_json()),
            (
                "queue",
                obj(vec![
                    ("depth", Json::Num(self.gauge.depth() as f64)),
                    ("capacity", Json::Num(self.gauge.capacity() as f64)),
                ]),
            ),
            ("pools", obj(vec![("reply", self.pool.stats().to_json())])),
            ("snapshot", self.stats.snapshot.to_json()),
        ])
    }
}

/// The running service (owns the worker thread).
pub struct ReplayService {
    handle: ServiceHandle,
    worker: Option<JoinHandle<Box<dyn ReplayMemory>>>,
}

impl ReplayService {
    /// Spawn the service around `memory`. `queue_depth` bounds the
    /// command queue (backpressure knob).
    pub fn spawn(
        memory: Box<dyn ReplayMemory>,
        queue_depth: usize,
        seed: u64,
    ) -> ReplayService {
        Self::spawn_inner(memory, queue_depth, seed, FaultPlan::default())
    }

    /// Spawn with an injected [`FaultPlan`] (fault-injection tests only).
    #[cfg(feature = "testing")]
    pub fn spawn_with_faults(
        memory: Box<dyn ReplayMemory>,
        queue_depth: usize,
        seed: u64,
        faults: FaultPlan,
    ) -> ReplayService {
        Self::spawn_inner(memory, queue_depth, seed, faults)
    }

    fn spawn_inner(
        memory: Box<dyn ReplayMemory>,
        queue_depth: usize,
        seed: u64,
        faults: FaultPlan,
    ) -> ReplayService {
        let (tx, rx): (SyncSender<Command>, Receiver<Command>) =
            sync_channel(queue_depth);
        let stats = Arc::new(ServiceStats::default());
        let gauge = QueueGauge::new(queue_depth);
        let worker_stats = Arc::clone(&stats);
        let worker_gauge = Arc::clone(&gauge);
        let worker = std::thread::Builder::new()
            .name("replay-service".into())
            .spawn(move || {
                run_worker(
                    memory,
                    rx,
                    Rng::new(seed),
                    worker_stats,
                    worker_gauge,
                    faults,
                )
            })
            .expect("spawn replay service");
        ReplayService {
            handle: ServiceHandle {
                tx,
                stats,
                pool: ReplyPool::new(DEFAULT_REPLY_POOL),
                gauge,
                timeout_ms: Arc::new(AtomicU64::new(DEFAULT_GATHER_TIMEOUT_MS)),
            },
            worker: Some(worker),
        }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Stop the worker and recover the memory (for inspection).
    ///
    /// This is a **graceful drain**: the command queue is FIFO, so every
    /// push/update accepted before `Stop` is applied before the worker
    /// exits. A worker that already died disconnects the channel, so the
    /// send fails fast and `stop` still returns instead of hanging.
    pub fn stop(mut self) -> Box<dyn ReplayMemory> {
        self.handle.gauge.inc();
        if self.handle.tx.send(Command::Stop).is_err() {
            self.handle.gauge.dec();
        }
        self.worker.take().unwrap().join().expect("service panicked")
    }

    /// [`Self::stop`], plus a final [`ServiceHandle::stats_json`] report
    /// snapshotted *after* the drain completes.
    pub fn stop_with_report(self) -> (Box<dyn ReplayMemory>, Json) {
        let h = self.handle();
        let mem = self.stop();
        (mem, h.stats_json())
    }
}

impl Drop for ReplayService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            self.handle.gauge.inc();
            if self.handle.tx.send(Command::Stop).is_err() {
                self.handle.gauge.dec();
            }
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{ReplayKind, UniformReplay};

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    #[test]
    fn push_sample_update_roundtrip() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Per, 128),
            64,
            0,
        );
        let h = svc.handle();
        for i in 0..100 {
            assert!(h.push(exp(i as f32)));
        }
        let b = h.sample(32);
        assert_eq!(b.indices.len(), 32);
        assert!(h.update_priorities(b.indices.clone(), vec![1.0; 32]));
        let mem = svc.stop();
        assert_eq!(mem.len(), 100);
    }

    #[test]
    fn push_batch_counts_rows_and_stores_them() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(256)), 16, 0);
        let h = svc.handle();
        let exps: Vec<Experience> = (0..40).map(|i| exp(i as f32)).collect();
        assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
        assert!(h.push_batch(ExperienceBatch::new(4)), "empty batch is a no-op");
        let mem = svc.stop();
        assert_eq!(mem.len(), 40);
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 40);
        // rows landed in push order
        for i in 0..40 {
            assert_eq!(mem.ring().reward_of(i), i as f32);
        }
    }

    #[test]
    fn gathered_batch_has_flat_buffers() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(64)), 16, 1);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(16).unwrap();
        assert_eq!(g.obs.len(), 16 * 4);
        assert_eq!(g.actions.len(), 16);
        // obs content matches the sampled indices
        for (row, &idx) in g.indices.iter().enumerate() {
            assert_eq!(g.obs[row * 4], idx as f32);
        }
    }

    #[test]
    fn recycled_buffer_is_refilled_in_place() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(64)), 16, 5);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g1 = h.sample_gathered(16).unwrap();
        let obs_ptr = g1.obs.as_ptr() as usize;
        h.recycle(g1);
        let g2 = h.sample_gathered(16).unwrap();
        assert_eq!(
            g2.obs.as_ptr() as usize,
            obs_ptr,
            "pool hit must reuse the recycled buffer's allocation"
        );
        assert_eq!(g2.rows(), 16);
        assert_eq!(h.reply_pool().stats().hits.load(Ordering::Relaxed), 1);
        assert_eq!(h.reply_pool().stats().misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_actors_and_learner() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::AmperFr, 4096),
            256,
            2,
        );
        let mut producers = Vec::new();
        for t in 0..4 {
            let h = svc.handle();
            producers.push(std::thread::spawn(move || {
                for i in 0..500 {
                    assert!(h.push(exp((t * 1000 + i) as f32)));
                }
            }));
        }
        let learner = {
            let h = svc.handle();
            std::thread::spawn(move || {
                let mut drawn = 0usize;
                for _ in 0..50 {
                    let b = h.sample(32);
                    if !b.indices.is_empty() {
                        assert!(
                            h.update_priorities(b.indices.clone(), vec![0.5; 32])
                        );
                        drawn += b.indices.len();
                    }
                }
                drawn
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        let drawn = learner.join().unwrap();
        assert!(drawn > 0);
        let stats = svc.handle();
        assert_eq!(
            stats.stats().pushes.load(Ordering::Relaxed),
            2000
        );
        let mem = svc.stop();
        assert_eq!(mem.len(), 2000);
    }

    #[test]
    fn sample_on_empty_returns_empty() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(8)), 4, 3);
        let b = svc.handle().sample(4);
        assert!(b.indices.is_empty());
        let g = svc.handle().sample_gathered(4).unwrap();
        assert!(g.indices.is_empty());
    }

    #[test]
    fn queue_gauge_tracks_depth_and_saturates() {
        let g = QueueGauge::new(4);
        assert_eq!(g.depth(), 0);
        g.dec(); // saturating: a rollback race must not underflow
        assert_eq!(g.depth(), 0);
        g.inc();
        g.inc();
        assert_eq!(g.depth(), 2);
        assert!((g.load() - 0.5).abs() < 1e-12);
        assert_eq!(g.capacity(), 4);
    }

    #[test]
    fn stats_json_reports_counters_stages_and_pools() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(64)), 16, 9);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(8).unwrap();
        h.recycle(g);
        let (_mem, report) = svc.stop_with_report();
        let counters = report.get("service").unwrap();
        assert_eq!(counters.get("pushes").and_then(|v| v.as_usize()), Some(64));
        let stages = report.get("stages").unwrap();
        let gather = stages.get("worker_gather").unwrap();
        assert_eq!(gather.get("count").and_then(|v| v.as_usize()), Some(1));
        let flush = stages.get("flush_accept").unwrap();
        assert_eq!(flush.get("count").and_then(|v| v.as_usize()), Some(64));
        assert!(report.get("pools").unwrap().get("reply").is_some());
        // snapshot staleness present even with no snapshot layer wired
        let snap = report.get("snapshot").unwrap();
        assert_eq!(snap.get("publishes").and_then(|v| v.as_usize()), Some(0));
        // post-drain snapshot: every accepted command was consumed
        let depth = report.get("queue").unwrap().get("depth").unwrap();
        assert_eq!(depth.as_usize(), Some(0));
    }

    #[test]
    fn gathered_request_after_stop_errors_and_recovers_buffer() {
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(8)), 4, 6);
        let h = svc.handle();
        for i in 0..8 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(4).unwrap();
        h.recycle(g);
        let _mem = svc.stop();
        assert!(h.sample_gathered(4).is_err(), "dead worker must error");
        // the lent buffer went back to the pool, not into the void
        let s = h.reply_pool().stats();
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(
            s.hits.load(Ordering::Relaxed) + s.misses.load(Ordering::Relaxed),
            s.recycled.load(Ordering::Relaxed)
                + s.dropped.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn commands_after_stop_are_reported_not_counted() {
        // regression: push/update used to increment the counters and then
        // silently drop the send error, so stats overstated work after
        // the worker stopped.
        let svc = ReplayService::spawn(Box::new(UniformReplay::new(8)), 4, 4);
        let h = svc.handle();
        assert!(h.push(exp(1.0)));
        let _mem = svc.stop();
        assert!(!h.push(exp(2.0)), "push after stop must report failure");
        assert!(!h.update_priorities(vec![0], vec![0.1]));
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().updates.load(Ordering::Relaxed), 0);
    }
}
