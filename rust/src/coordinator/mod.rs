//! The L3 coordination layer: replay *services* that own ER memory and
//! serve concurrent actors/learners over channels — the software
//! analogue of the AMPER accelerator sitting between the environment
//! stream and the training engine (paper Fig 1 + Fig 6a).
//!
//! * [`ReplayService`] — one dedicated thread owning a [`ReplayMemory`]
//!   (the paper's single search/write port pair); actors push
//!   experiences, learners request batches and feed back priorities.
//!   Bounded queues provide backpressure.
//! * [`ShardedReplayService`] — N single-owner shard workers behind one
//!   cloneable [`ShardedHandle`]: pushes route round-robin, samples fan
//!   out as per-shard sub-batches and merge under a `(shard, slot)`
//!   global index, priority updates route back to the owning shard.
//!   Scaling the port count like tiling more TCAM banks — the step that
//!   unlocks batching/async/multi-backend work.
//! * [`VectorEnvDriver`] — env actor threads generating experiences
//!   concurrently: random-policy actors for ingest studies, or
//!   snapshot-driven ε-greedy actors stepping every env with **one
//!   batched forward per tick** ([`vec_env`]).
//! * [`SnapshotSlot`] + [`PolicySnapshot`] ([`snapshot`]) — the
//!   epoch-versioned policy hand-off of the Ape-X actor/learner split:
//!   the learner publishes frozen params every `snapshot_interval`
//!   train steps, actors refresh via one atomic epoch check and record
//!   how many epochs behind they read.
//! * [`ReplyPool`] + [`PendingGather`] ([`pool`]) — zero-copy gathered
//!   replies: the learner recycles consumed [`GatheredBatch`] buffers,
//!   workers gather directly into the lent buffers, and sharded replies
//!   merge by shard-offset writes into one pooled pre-sized reply.
//! * [`GatherPipeline`] ([`learner`]) — keeps `pipeline_depth` gather
//!   requests in flight so the service samples ahead of training.
//!
//! [`ReplayMemory`]: crate::replay::ReplayMemory

pub mod learner;
pub mod pool;
pub mod service;
pub mod sharded;
pub mod snapshot;
pub mod vec_env;

pub use learner::GatherPipeline;
pub use pool::{PendingGather, PoolStats, ReplyPool};
pub use service::{
    FaultPlan, QueueGauge, ReplayService, ServiceHandle, ServiceStats, StageLatencies,
    DEFAULT_GATHER_TIMEOUT_MS,
};
pub use sharded::{ShardedHandle, ShardedReplayService};
pub use snapshot::{ActScratch, PolicySnapshot, SnapshotSlot, SnapshotStats};
pub use vec_env::{FlushController, FlushPolicy, VecEnvTicker, VectorEnvDriver};

// the reply unit lives in the replay data layer; re-exported here because
// it is the coordinator's learner-facing currency
pub use crate::replay::GatheredBatch;

use crate::replay::{Experience, ExperienceBatch};
use crate::util::error::Result;

/// Anything an actor can push experiences into: implemented by both the
/// single-owner [`ServiceHandle`] and the [`ShardedHandle`], so drivers
/// and ingest benches are generic over the service shape. The batch
/// method is the native unit; the scalar method is a 1-row convenience.
pub trait ReplaySink: Clone + Send + 'static {
    /// Store one experience; `false` means the service has stopped and
    /// the experience was dropped.
    fn push_experience(&self, e: Experience) -> bool;

    /// Store a whole batch in (at most) one command per shard; `false`
    /// means the service has stopped and (part of) the batch was dropped.
    fn push_experience_batch(&self, batch: ExperienceBatch) -> bool;

    /// Command-queue occupancy in `[0, 1]` (deepest shard for sharded
    /// services) — the backpressure signal the adaptive
    /// [`FlushController`] feeds on. Sinks without a bounded queue
    /// report 0 (never backpressured).
    fn queue_load(&self) -> f64 {
        0.0
    }
}

impl ReplaySink for ServiceHandle {
    fn push_experience(&self, e: Experience) -> bool {
        self.push(e)
    }

    fn push_experience_batch(&self, batch: ExperienceBatch) -> bool {
        self.push_batch(batch)
    }

    fn queue_load(&self) -> f64 {
        self.queue_gauge().load()
    }
}

impl ReplaySink for ShardedHandle {
    fn push_experience(&self, e: Experience) -> bool {
        self.push(e)
    }

    fn push_experience_batch(&self, batch: ExperienceBatch) -> bool {
        self.push_batch(batch)
    }

    fn queue_load(&self) -> f64 {
        ShardedHandle::queue_load(self)
    }
}

/// The learner-facing surface shared by both handle shapes: drain
/// gathered batches (synchronously or pipelined), return consumed reply
/// buffers to the pool, and feed back TD errors. Lets serving loops and
/// throughput benches be generic over single-owner vs sharded services.
pub trait LearnerPort: Clone + Send + 'static {
    /// Sample + gather `batch` transitions into flat buffers. An `Err`
    /// means a worker caught a corrupt index at its ring boundary.
    fn sample_gathered(&self, batch: usize) -> Result<GatheredBatch> {
        self.request_gathered(batch).wait()
    }
    /// Issue a gather request without waiting for the reply (the
    /// pipelined-learner primitive); `wait` on the returned handle
    /// blocks for — and, for sharded services, offset-merges — the
    /// reply.
    fn request_gathered(&self, batch: usize) -> PendingGather;
    /// Return a consumed reply buffer to the service's reply pool so the
    /// next gather refills it in place instead of allocating.
    fn recycle(&self, buf: GatheredBatch);
    /// The reply pool the learner recycles into (hit/miss stats).
    fn reply_pool(&self) -> &ReplyPool;
    /// Route TD errors back for a previously sampled batch; `false`
    /// means (part of) the update was dropped because a worker stopped.
    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool;
    /// The service's shared counters and per-stage latency histograms —
    /// lets generic serving loops record the train stage and print the
    /// same operability report for either handle shape.
    fn service_stats(&self) -> &ServiceStats;
}

impl LearnerPort for ServiceHandle {
    fn request_gathered(&self, batch: usize) -> PendingGather {
        ServiceHandle::request_gathered(self, batch)
    }

    fn recycle(&self, buf: GatheredBatch) {
        ServiceHandle::recycle(self, buf)
    }

    fn reply_pool(&self) -> &ReplyPool {
        ServiceHandle::reply_pool(self)
    }

    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        ServiceHandle::update_priorities(self, indices, td)
    }

    fn service_stats(&self) -> &ServiceStats {
        self.stats()
    }
}

impl LearnerPort for ShardedHandle {
    fn request_gathered(&self, batch: usize) -> PendingGather {
        ShardedHandle::request_gathered(self, batch)
    }

    fn recycle(&self, buf: GatheredBatch) {
        ShardedHandle::recycle(self, buf)
    }

    fn reply_pool(&self) -> &ReplyPool {
        ShardedHandle::reply_pool(self)
    }

    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        ShardedHandle::update_priorities(self, indices, td)
    }

    fn service_stats(&self) -> &ServiceStats {
        self.stats()
    }
}
