//! The L3 coordination layer: a replay *service* that owns the ER memory
//! and serves concurrent actors/learners over channels — the software
//! analogue of the AMPER accelerator sitting between the environment
//! stream and the training engine (paper Fig 1 + Fig 6a).
//!
//! * [`ReplayService`] — a dedicated thread owning a [`ReplayMemory`];
//!   actors push experiences, learners request batches and feed back
//!   priorities. Bounded queues provide backpressure.
//! * [`VectorEnvDriver`] — N environment actor threads generating
//!   experiences concurrently (throughput/ingest studies).

pub mod service;
pub mod vec_env;

pub use service::{ReplayService, ServiceHandle, ServiceStats};
pub use vec_env::VectorEnvDriver;
