//! The pipelined learner front-end: keeps `depth` gather requests in
//! flight over a [`LearnerPort`] so the replay service works **ahead of
//! training** instead of idling through every request/reply round trip.
//!
//! Protocol per iteration (depth `d`):
//!
//! 1. [`GatherPipeline::next`] tops the in-flight window up to `d`
//!    requests, then waits for the oldest one. While the caller trains
//!    on the returned batch, the service is already sampling/gathering
//!    the next `d - 1` batches into pooled buffers.
//! 2. The caller feeds TD errors back ([`GatherPipeline::feedback`]) and
//!    returns the consumed buffer ([`GatherPipeline::recycle`]) before
//!    calling `next` again, so priority updates are always enqueued
//!    before the *next* request is issued.
//!
//! `depth = 1` reproduces the synchronous request → train → update loop
//! exactly. `depth = 2` is the double-buffered mode: one batch training,
//! one in flight. For prioritized replay, a request issued `d - 1`
//! batches ahead samples against priorities that lag by `d - 1` updates
//! — the standard staleness trade of asynchronous samplers (Ape-X /
//! Reverb make the same one); sampling itself stays deterministic per
//! (seed, shard count, depth), and for non-prioritized memories the
//! training stream is bit-identical across depths (pinned by the
//! `batch_equivalence` suite).

use std::collections::VecDeque;

use super::pool::PendingGather;
use super::LearnerPort;
use crate::replay::GatheredBatch;
use crate::util::error::Result;

/// Double-buffered gather requests over a service handle.
pub struct GatherPipeline<P: LearnerPort> {
    port: P,
    batch: usize,
    depth: usize,
    pending: VecDeque<PendingGather>,
}

impl<P: LearnerPort> GatherPipeline<P> {
    /// Pipeline `depth` in-flight requests of `batch` transitions each
    /// (`depth` is clamped to ≥ 1; 1 = synchronous).
    pub fn new(port: P, batch: usize, depth: usize) -> GatherPipeline<P> {
        let depth = depth.max(1);
        GatherPipeline { port, batch, depth, pending: VecDeque::with_capacity(depth) }
    }

    /// The configured pipeline depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Wait for the next gathered batch, keeping `depth` requests in
    /// flight. An `Err` means a worker caught a corrupt index at its
    /// ring boundary, a shard worker died mid-request, or the reply
    /// timed out (see `ServiceHandle::set_gather_timeout`).
    pub fn next_batch(&mut self) -> Result<GatheredBatch> {
        while self.pending.len() < self.depth {
            self.pending.push_back(self.port.request_gathered(self.batch));
        }
        self.pending
            .pop_front()
            .expect("depth >= 1 guarantees a pending request")
            .wait()
    }

    /// Feed TD errors back for a batch returned by [`Self::next_batch`]
    /// (the indices stay in the buffer so it can be recycled whole).
    /// Returns whether every worker accepted its update slice.
    #[must_use = "a false return means the priority update was dropped"]
    pub fn feedback(&self, g: &GatheredBatch, td: &[f32]) -> bool {
        self.port.update_priorities(g.indices.clone(), td.to_vec())
    }

    /// Return a consumed reply buffer to the service's pool.
    pub fn recycle(&self, buf: GatheredBatch) {
        self.port.recycle(buf);
    }

    /// The underlying service port.
    pub fn port(&self) -> &P {
        &self.port
    }

    /// Settle every in-flight request, recycling the replies that
    /// arrive. Returns how many pending requests were drained. Called
    /// on drop so a pipeline abandoned mid-stream (learner error,
    /// shutdown) never strands lent pool buffers in worker reply
    /// channels; each wait is bounded by the service's gather timeout,
    /// and requests against a dead worker settle instantly.
    pub fn drain(&mut self) -> usize {
        let mut drained = 0;
        while let Some(p) = self.pending.pop_front() {
            if let Ok(g) = p.wait() {
                self.port.recycle(g);
            }
            drained += 1;
        }
        drained
    }
}

impl<P: LearnerPort> Drop for GatherPipeline<P> {
    fn drop(&mut self) {
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReplayService;
    use crate::replay::{Experience, ReplayKind};

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    #[test]
    fn pipeline_drains_identical_stream_to_sync_requests() {
        // two identical services; one drained synchronously, one through
        // a depth-3 pipeline with recycling — same sample stream
        let spawn = || {
            let svc = ReplayService::spawn(
                crate::replay::make(ReplayKind::Uniform, 128),
                64,
                9,
            );
            let h = svc.handle();
            for i in 0..100 {
                assert!(h.push(exp(i as f32)));
            }
            svc
        };
        let sync_svc = spawn();
        let pipe_svc = spawn();
        let sync = sync_svc.handle();
        let mut pipe = GatherPipeline::new(pipe_svc.handle(), 16, 3);
        for round in 0..8 {
            let a = sync.sample_gathered(16).unwrap();
            let b = pipe.next_batch().unwrap();
            assert_eq!(a.indices, b.indices, "round {round}");
            assert_eq!(a.obs, b.obs, "round {round}");
            pipe.recycle(b);
        }
        // steady state: every request after warmup was a pool hit
        let stats = pipe.port().reply_pool().stats();
        use std::sync::atomic::Ordering;
        let hits = stats.hits.load(Ordering::Relaxed);
        assert!(hits >= 5, "pool barely hit: {hits}");
    }

    #[test]
    fn drain_settles_in_flight_requests_and_recycles() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 128),
            64,
            3,
        );
        let h = svc.handle();
        for i in 0..50 {
            assert!(h.push(exp(i as f32)));
        }
        let mut pipe = GatherPipeline::new(svc.handle(), 8, 3);
        let g = pipe.next_batch().unwrap(); // leaves depth-1 requests in flight
        pipe.recycle(g);
        assert_eq!(pipe.drain(), 2);
        drop(pipe); // second drain is a no-op
        use std::sync::atomic::Ordering;
        let pool = h.reply_pool().stats();
        let taken =
            pool.hits.load(Ordering::Relaxed) + pool.misses.load(Ordering::Relaxed);
        let settled = pool.recycled.load(Ordering::Relaxed)
            + pool.dropped.load(Ordering::Relaxed);
        assert_eq!(taken, settled, "every lent buffer must come home");
        svc.stop();
    }

    #[test]
    fn depth_is_clamped_to_one() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 32),
            16,
            1,
        );
        let h = svc.handle();
        assert!(h.push(exp(1.0)));
        let mut pipe = GatherPipeline::new(h, 4, 0);
        assert_eq!(pipe.depth(), 1);
        let g = pipe.next_batch().unwrap();
        assert_eq!(g.rows(), 4);
    }
}
