//! Epoch-versioned policy snapshots: the actor/learner split's policy
//! hand-off (Ape-X, Horgan et al. — distributed actors act on
//! periodically refreshed copies of the learner's network).
//!
//! The learner owns the live [`TrainState`] and publishes a frozen
//! [`PolicySnapshot`] (online params + network dims + epoch) into a
//! shared [`SnapshotSlot`] every `snapshot_interval` train steps.
//! Actors never touch the engine or the training state: they hold a
//! cached `Arc<PolicySnapshot>`, compare one atomic epoch per tick
//! ([`SnapshotSlot::refresh`] — the steady-state fast path takes no
//! lock), and swap in the latest snapshot when the learner has moved.
//! How far behind each actor read is recorded into the
//! [`SnapshotStats`] epochs-behind histogram, surfaced by `amper serve`
//! and `--stats-json` alongside the pool hit rate.
//!
//! This is the module boundary that unlocks multi-process actors: an
//! actor needs a snapshot slot and a [`ReplaySink`](super::ReplaySink)
//! — nothing else.
//!
//! [`TrainState`]: crate::runtime::TrainState

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ensure;
use crate::metrics::LatencyHistogram;
use crate::runtime::engine::act_batch_dims;
use crate::util::error::Result;
use crate::util::json::{obj, Json};

// actors re-use the engine's inference scratch without importing the
// engine: the snapshot layer is their only policy surface
pub use crate::runtime::engine::ActScratch;

/// A frozen, immutable copy of the online policy: parameters, the
/// network dims needed to run them, and the epoch they were published
/// at. Cheap to share (`Arc`), never mutated after construction.
pub struct PolicySnapshot {
    params: Vec<Vec<f32>>,
    dims: Vec<usize>,
    epoch: u64,
}

impl PolicySnapshot {
    /// Wrap exported parameters (see
    /// [`TrainState::snapshot_params`](crate::runtime::TrainState::snapshot_params))
    /// with the network dims of the spec that produced them.
    pub fn new(params: Vec<Vec<f32>>, dims: Vec<usize>, epoch: u64) -> Result<PolicySnapshot> {
        ensure!(dims.len() == 4, "snapshot dims must be the 3-layer MLP shape");
        ensure!(params.len() == 6, "snapshot params must be w0,b0,w1,b1,w2,b2");
        ensure!(
            params[0].len() == dims[0] * dims[1] && params[4].len() == dims[2] * dims[3],
            "snapshot params do not match dims"
        );
        Ok(PolicySnapshot { params, dims, epoch })
    }

    /// Epoch this snapshot was published at (0 = the initial snapshot).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Observation dimensionality the policy expects.
    pub fn obs_dim(&self) -> usize {
        self.dims[0]
    }

    /// Number of discrete actions the policy emits.
    pub fn n_actions(&self) -> usize {
        self.dims[3]
    }

    /// The frozen online parameters (w0,b0,w1,b1,w2,b2).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// The network dims the parameters were exported under (the wire
    /// layer ships these so a remote peer can reconstruct the snapshot).
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Batched greedy actions for `rows` flat row-major observations:
    /// one forward pass over all rows, first-occurrence argmax per row,
    /// scratch reused across ticks. Bit-identical to
    /// [`Engine::act_batch`](crate::runtime::Engine::act_batch) on the
    /// same parameters — the snapshot runs the engine's own math, it
    /// just doesn't need an engine in scope.
    pub fn greedy_actions<'s>(
        &self,
        obs: &[f32],
        rows: usize,
        scratch: &'s mut ActScratch,
    ) -> Result<&'s [u32]> {
        act_batch_dims(&self.params, &self.dims, obs, rows, scratch, None)
    }
}

/// Snapshot staleness counters, shared between the slot (publisher
/// side) and [`ServiceStats`](super::ServiceStats) (reporting side).
///
/// `behind` reuses the log2-bucketed [`LatencyHistogram`] with
/// *epochs behind* as the recorded value (not nanoseconds): one sample
/// per actor refresh, 0 = the actor was current.
#[derive(Debug, Default)]
pub struct SnapshotStats {
    /// Snapshots published so far (the initial snapshot is not counted).
    pub publishes: AtomicU64,
    /// Epoch of the currently published snapshot.
    pub epoch: AtomicU64,
    /// Actor-observed epochs-behind, one sample per refresh.
    pub behind: LatencyHistogram,
}

impl SnapshotStats {
    /// Staleness snapshot as JSON (for the serve stats dump). The
    /// `behind` histogram's `*_ns` keys read as epoch counts here.
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "publishes",
                Json::Num(self.publishes.load(Ordering::Relaxed) as f64),
            ),
            ("epoch", Json::Num(self.epoch.load(Ordering::Relaxed) as f64)),
            ("behind_epochs", self.behind.to_json()),
        ])
    }
}

/// The shared slot a learner publishes policy snapshots into and actors
/// load them from.
///
/// Swap protocol: the slot holds an `Arc<PolicySnapshot>` behind a
/// `Mutex` plus the current epoch in an atomic. Actors poll the atomic
/// epoch every tick ([`Self::refresh`]) and only take the mutex on the
/// rare tick where the learner actually published — the steady-state
/// read path is one relaxed atomic load, and the lock is only ever held
/// for an `Arc` clone/store (never for parameter copies), so publishers
/// and late actors cannot stall each other behind a forward pass.
pub struct SnapshotSlot {
    slot: Mutex<Arc<PolicySnapshot>>,
    stats: Arc<SnapshotStats>,
}

impl SnapshotSlot {
    /// Create a slot holding `initial` with private stats.
    pub fn new(initial: PolicySnapshot) -> Arc<SnapshotSlot> {
        Self::with_stats(initial, Arc::new(SnapshotStats::default()))
    }

    /// Create a slot that records into shared stats — `amper serve`
    /// passes the service's
    /// [`ServiceStats::snapshot`](super::ServiceStats) so staleness
    /// lands in the same report as the pool hit rate.
    pub fn with_stats(
        initial: PolicySnapshot,
        stats: Arc<SnapshotStats>,
    ) -> Arc<SnapshotSlot> {
        stats.epoch.store(initial.epoch, Ordering::Relaxed);
        Arc::new(SnapshotSlot { slot: Mutex::new(Arc::new(initial)), stats })
    }

    /// Publish new parameters as the next epoch (learner side; dims are
    /// inherited from the current snapshot). Returns the new epoch.
    pub fn publish(&self, params: Vec<Vec<f32>>) -> u64 {
        let mut slot = self.slot.lock().expect("snapshot slot poisoned");
        let epoch = slot.epoch + 1;
        *slot = Arc::new(PolicySnapshot { params, dims: slot.dims.clone(), epoch });
        // epoch becomes visible only after the snapshot is in place, so
        // an actor that sees the new epoch always loads the new params
        self.stats.epoch.store(epoch, Ordering::Release);
        self.stats.publishes.fetch_add(1, Ordering::Relaxed);
        epoch
    }

    /// Install a fully formed snapshot if it is *newer* than the current
    /// one (relay side: the net server mirrors learner publishes into its
    /// actor-facing slot, and with several learner clients racing, the
    /// highest epoch wins). Returns whether the snapshot was installed.
    pub fn install(&self, snap: PolicySnapshot) -> bool {
        let mut slot = self.slot.lock().expect("snapshot slot poisoned");
        if snap.epoch <= slot.epoch {
            return false;
        }
        let epoch = snap.epoch;
        *slot = Arc::new(snap);
        // same ordering contract as publish: epoch becomes visible only
        // after the snapshot is in place
        self.stats.epoch.store(epoch, Ordering::Release);
        self.stats.publishes.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// The currently published snapshot (an `Arc` clone under the lock).
    pub fn load(&self) -> Arc<PolicySnapshot> {
        Arc::clone(&self.slot.lock().expect("snapshot slot poisoned"))
    }

    /// Epoch of the currently published snapshot (lock-free).
    pub fn epoch(&self) -> u64 {
        self.stats.epoch.load(Ordering::Acquire)
    }

    /// Actor-side refresh: if the learner has published past `cached`,
    /// swap in the latest snapshot. Records the observed epochs-behind
    /// (0 when already current) into the staleness histogram and
    /// returns it. The current-snapshot fast path is one atomic load.
    pub fn refresh(&self, cached: &mut Arc<PolicySnapshot>) -> u64 {
        let behind = self.epoch().saturating_sub(cached.epoch);
        if behind > 0 {
            *cached = self.load();
        }
        self.stats.behind.record(behind);
        behind
    }

    /// The staleness counters this slot records into.
    pub fn stats(&self) -> &SnapshotStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, EnvArtifacts, TrainState};
    use crate::util::Rng;

    fn snap_from(spec: &EnvArtifacts, seed: u64, epoch: u64) -> (TrainState, PolicySnapshot) {
        let state = TrainState::init(spec, seed).unwrap();
        let snap =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), epoch).unwrap();
        (state, snap)
    }

    #[test]
    fn snapshot_greedy_matches_engine_act_batch() {
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let engine = Engine::from_spec(spec.clone());
        let (state, snap) = snap_from(&spec, 3, 0);
        let mut rng = Rng::new(11);
        let rows = 17;
        let obs: Vec<f32> =
            (0..rows * spec.obs_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut s1 = ActScratch::default();
        let mut s2 = ActScratch::default();
        let a = snap.greedy_actions(&obs, rows, &mut s1).unwrap().to_vec();
        let b = engine.act_batch(&state.params, &obs, rows, &mut s2).unwrap();
        assert_eq!(a, b);
        assert_eq!(snap.obs_dim(), spec.obs_dim);
        assert_eq!(snap.n_actions(), spec.n_actions);
    }

    #[test]
    fn publish_advances_epoch_and_refresh_records_staleness() {
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let (state, snap) = snap_from(&spec, 5, 0);
        let slot = SnapshotSlot::new(snap);
        let mut cached = slot.load();
        assert_eq!(slot.epoch(), 0);
        assert_eq!(slot.refresh(&mut cached), 0, "fresh cache is current");

        assert_eq!(slot.publish(state.snapshot_params()), 1);
        assert_eq!(slot.publish(state.snapshot_params()), 2);
        assert_eq!(slot.epoch(), 2);
        assert_eq!(slot.refresh(&mut cached), 2, "two publishes behind");
        assert_eq!(cached.epoch(), 2);
        assert_eq!(slot.refresh(&mut cached), 0, "refreshed cache is current");

        let stats = slot.stats();
        assert_eq!(stats.publishes.load(Ordering::Relaxed), 2);
        assert_eq!(stats.epoch.load(Ordering::Relaxed), 2);
        assert_eq!(stats.behind.count(), 3, "one sample per refresh");
        assert_eq!(stats.behind.max_ns(), 2);
        let j = stats.to_json();
        assert_eq!(j.get("publishes").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("epoch").and_then(|v| v.as_usize()), Some(2));
        assert!(j.get("behind_epochs").is_some());
    }

    #[test]
    fn install_takes_newer_snapshots_only() {
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let (state, snap) = snap_from(&spec, 5, 0);
        let slot = SnapshotSlot::new(snap);
        let newer =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 3).unwrap();
        assert!(slot.install(newer), "epoch 3 beats epoch 0");
        assert_eq!(slot.epoch(), 3);
        assert_eq!(slot.load().epoch(), 3);
        let stale =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 3).unwrap();
        assert!(!slot.install(stale), "equal epoch is not newer");
        let older =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 1).unwrap();
        assert!(!slot.install(older));
        assert_eq!(slot.epoch(), 3);
        assert_eq!(slot.stats().publishes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_new_validates_shapes() {
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let state = TrainState::init(&spec, 0).unwrap();
        assert!(PolicySnapshot::new(state.snapshot_params(), vec![4, 128], 0).is_err());
        assert!(
            PolicySnapshot::new(state.snapshot_params(), vec![6, 128, 128, 3], 0).is_err(),
            "dims from another env must be rejected"
        );
        assert!(PolicySnapshot::new(vec![vec![0.0]; 3], spec.dims.clone(), 0).is_err());
    }

    #[test]
    fn concurrent_publishers_and_readers_stay_consistent() {
        // the epoch an actor observes must never run ahead of the
        // snapshot it then loads
        let spec = EnvArtifacts::builtin("mountaincar").unwrap();
        let (state, snap) = snap_from(&spec, 9, 0);
        let slot = SnapshotSlot::new(snap);
        let writer = {
            let slot = Arc::clone(&slot);
            let params = state.snapshot_params();
            std::thread::spawn(move || {
                for _ in 0..500 {
                    slot.publish(params.clone());
                }
            })
        };
        let mut cached = slot.load();
        for _ in 0..2000 {
            let seen = slot.epoch();
            slot.refresh(&mut cached);
            assert!(cached.epoch() >= seen.min(cached.epoch()));
            assert!(cached.epoch() <= slot.epoch());
        }
        writer.join().unwrap();
        assert_eq!(slot.epoch(), 500);
    }
}
