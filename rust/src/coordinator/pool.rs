//! The gathered-reply buffer pool and the in-flight reply handle.
//!
//! Zero-copy gathered replies work by *lending* buffers instead of
//! allocating them: the learner hands consumed [`GatheredBatch`] buffers
//! back to its service handle ([`recycle`]), the handle attaches a pooled
//! buffer to the next `SampleGathered` command, and the worker gathers
//! **directly into the lent buffer** ([`GatheredBatch::reset`] resizes
//! the columns without reallocating). On the steady-state path every
//! request is a pool hit and a gathered batch crosses the service with
//! zero fresh allocations.
//!
//! [`PendingGather`] is the other half of the tentpole: a request that
//! has been *issued* but not yet *received*, so a pipelined learner can
//! keep `pipeline_depth` batches in flight while it trains on the
//! current one. For sharded services the pending handle owns the
//! pre-sized merged reply and streams the shard-offset merge in shard
//! order: as soon as shard k's reply arrives its columns are copied
//! while the later shards' gathers are still running — no all-shards
//! join barrier before copy work starts, and no per-shard column
//! re-copies through `Vec` growth. (Replies are consumed in fixed
//! shard order, not completion order; a slow shard 0 delays the merge
//! of faster later shards but not their gathers.)
//!
//! [`recycle`]: crate::coordinator::LearnerPort::recycle

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::service::ServiceStats;
use crate::replay::traits::global_index;
use crate::replay::GatheredBatch;
use crate::util::error::{Error, Result};
use crate::util::json::{obj, Json};
use crate::util::Timer;

/// Counters exported by a [`ReplyPool`]. `misses` is the number of
/// requests that had to allocate a fresh reply buffer — the acceptance
/// bar for the zero-copy path is that this stays flat at steady state.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Requests served from a recycled buffer.
    pub hits: AtomicU64,
    /// Requests that allocated because the pool was empty (warmup) or
    /// disabled (`capacity == 0`).
    pub misses: AtomicU64,
    /// Buffers returned to the pool.
    pub recycled: AtomicU64,
    /// Returned buffers dropped: pool at capacity, or a capacity-less
    /// buffer not worth pooling.
    pub dropped: AtomicU64,
}

impl PoolStats {
    /// Hit percentage (0..=100) for explicit counter values (callers
    /// that snapshot the counters before reporting).
    pub fn rate_percent(hits: u64, misses: u64) -> f64 {
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    }

    /// Current hit percentage of this pool (0..=100).
    pub fn hit_rate_percent(&self) -> f64 {
        Self::rate_percent(
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Counter snapshot as JSON (for the serve stats dump).
    pub fn to_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("hits", n(&self.hits)),
            ("misses", n(&self.misses)),
            ("recycled", n(&self.recycled)),
            ("dropped", n(&self.dropped)),
            ("hit_rate_percent", Json::Num(self.hit_rate_percent())),
        ])
    }
}

struct PoolInner {
    bufs: Mutex<Vec<GatheredBatch>>,
    capacity: AtomicUsize,
    stats: PoolStats,
}

/// A bounded, cloneable free-list of [`GatheredBatch`] reply buffers
/// shared by all clones of a service handle.
#[derive(Clone)]
pub struct ReplyPool {
    inner: Arc<PoolInner>,
}

impl ReplyPool {
    /// Pool holding at most `capacity` idle buffers (0 disables pooling:
    /// every take is a miss, every recycle a drop — the PR-4 allocating
    /// behavior, kept as the bench baseline).
    pub fn new(capacity: usize) -> ReplyPool {
        ReplyPool {
            inner: Arc::new(PoolInner {
                bufs: Mutex::new(Vec::new()),
                capacity: AtomicUsize::new(capacity),
                stats: PoolStats::default(),
            }),
        }
    }

    /// Take a recycled buffer if one is available (counts hit/miss).
    pub fn take(&self) -> Option<GatheredBatch> {
        let got = self.inner.bufs.lock().expect("reply pool poisoned").pop();
        let stat = if got.is_some() {
            &self.inner.stats.hits
        } else {
            &self.inner.stats.misses
        };
        stat.fetch_add(1, Ordering::Relaxed);
        got
    }

    /// Return a consumed buffer; dropped if the pool is at capacity.
    /// Buffers that never grew any column capacity (e.g. empty warmup
    /// replies recycled by a learner loop) are dropped too: pooling them
    /// would let a later "hit" still allocate every column, which would
    /// make the hit counter overstate the allocation-free guarantee.
    pub fn put(&self, buf: GatheredBatch) {
        let cap = self.inner.capacity.load(Ordering::Relaxed);
        if buf.obs.capacity() == 0 && buf.indices.capacity() == 0 {
            self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut bufs = self.inner.bufs.lock().expect("reply pool poisoned");
        if bufs.len() < cap {
            bufs.push(buf);
            self.inner.stats.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account for a lent buffer that will never come back: its reply
    /// timed out, its worker died mid-request, or its request could not
    /// be sent and carried no buffer. Counted under `dropped` so the
    /// quiescent identity `hits + misses == recycled + dropped` keeps
    /// holding with faults in play — every `take` (hit *or* miss, since
    /// a miss makes the worker allocate the reply) must end in exactly
    /// one `put` or `note_lost`.
    pub fn note_lost(&self) {
        self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Change the idle-buffer bound (the `reply_pool` config knob).
    pub fn set_capacity(&self, capacity: usize) {
        self.inner.capacity.store(capacity, Ordering::Relaxed);
        let mut bufs = self.inner.bufs.lock().expect("reply pool poisoned");
        if bufs.len() > capacity {
            bufs.truncate(capacity);
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity.load(Ordering::Relaxed)
    }

    /// Idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        self.inner.bufs.lock().expect("reply pool poisoned").len()
    }

    pub fn stats(&self) -> &PoolStats {
        &self.inner.stats
    }
}

/// One per-shard leg of a sharded gather request.
pub(crate) struct ShardPart {
    pub(crate) shard: usize,
    /// Rows asked of this shard (truncation accounting on timeout).
    pub(crate) requested: usize,
    pub(crate) rx: Receiver<Result<GatheredBatch>>,
}

pub(crate) enum PendingInner {
    /// Single-owner service: one reply channel.
    Single {
        rx: Receiver<Result<GatheredBatch>>,
        /// Bound on the reply wait (the handle's gather timeout).
        timeout: Duration,
        /// Accounts the lent buffer if the reply never arrives.
        pool: ReplyPool,
        /// Merge-stage histogram + timeout counters.
        stats: Arc<ServiceStats>,
    },
    /// Sharded service: per-shard replies merged by shard-offset writes
    /// into one pre-sized reply taken from the merged-reply pool.
    Sharded {
        parts: Vec<ShardPart>,
        /// Total rows requested across all shards (pre-size bound).
        requested: usize,
        /// The merged reply buffer (pooled).
        merged: GatheredBatch,
        /// The merged-reply pool (error path recycles `merged` here).
        pool: ReplyPool,
        /// Per-shard segment buffers return here after merging.
        seg_pool: ReplyPool,
        /// Bound on each shard's reply wait.
        timeout: Duration,
        /// Merge-stage histogram + timeout counters.
        stats: Arc<ServiceStats>,
        /// Some shard worker was already dead at request time.
        dead: bool,
    },
    /// The worker was dead at request time; nothing is in flight and
    /// `wait` resolves to an error immediately.
    Dead,
}

/// An issued `sample_gathered` request whose reply has not been received
/// yet. Obtained from [`LearnerPort::request_gathered`]; [`Self::wait`]
/// blocks for the reply (streaming the per-shard merge in shard order
/// for sharded services). Dropping a pending request abandons the
/// reply; the worker's send fails silently and its buffer is freed.
///
/// [`LearnerPort::request_gathered`]: crate::coordinator::LearnerPort::request_gathered
pub struct PendingGather {
    pub(crate) inner: PendingInner,
}

impl PendingGather {
    /// Block until the gathered batch is available — bounded by the
    /// issuing handle's gather timeout, never forever.
    ///
    /// Fault semantics: a dead worker resolves to `Err`; a sharded
    /// request with one *slow* shard resolves to `Ok` with the rows the
    /// healthy shards served (the timed-out shard's rows are accounted
    /// in `ServiceStats::{shard_timeouts, truncated_rows}`); a shard
    /// worker that *died* mid-request resolves to `Err` after the other
    /// shards' segment buffers have drained back to their pool. Every
    /// path recycles or accounts every pooled buffer.
    pub fn wait(self) -> Result<GatheredBatch> {
        match self.inner {
            PendingInner::Dead => Err(Error::msg(
                "replay service worker has stopped; request was not sent",
            )),
            PendingInner::Single { rx, timeout, pool, stats } => {
                let t = Timer::start();
                let out = match rx.recv_timeout(timeout) {
                    Ok(res) => res,
                    Err(RecvTimeoutError::Timeout) => {
                        // the lent buffer (or the miss-path allocation)
                        // is stuck with the wedged worker — account it
                        pool.note_lost();
                        Err(Error::msg(format!(
                            "gathered reply timed out after {timeout:?}"
                        )))
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        pool.note_lost();
                        Err(Error::msg(
                            "replay service worker died before replying",
                        ))
                    }
                };
                stats.stages.merge.record(t.ns() as u64);
                out
            }
            PendingInner::Sharded {
                parts,
                requested,
                mut merged,
                pool,
                seg_pool,
                timeout,
                stats,
                dead,
            } => {
                // Stream the merge in shard order: the reply buffer is
                // pre-sized once for the full request, shard k's columns
                // are copied at the running row offset as soon as its
                // reply arrives (while later shards still gather — no
                // all-shards join barrier, no growth re-copies), and the
                // segment buffer goes straight back to the pool.
                let t = Timer::start();
                let mut rows = 0usize;
                let mut dim = 0usize;
                let mut sized = false;
                let mut first_err = if dead {
                    Some(Error::msg(
                        "a replay shard worker had stopped at request time",
                    ))
                } else {
                    None
                };
                for part in parts {
                    let g = match part.rx.recv_timeout(timeout) {
                        Ok(Ok(g)) => g,
                        Ok(Err(e)) => {
                            // keep draining so the other shards' segment
                            // buffers still recycle instead of leaking
                            // out of the pool on every error
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                            continue;
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            // slow shard: serve the batch short instead
                            // of stalling the learner behind it
                            let lost = part.requested as u64;
                            stats
                                .shard_timeouts
                                .fetch_add(1, Ordering::Relaxed);
                            stats
                                .truncated_rows
                                .fetch_add(lost, Ordering::Relaxed);
                            seg_pool.note_lost();
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            seg_pool.note_lost();
                            if first_err.is_none() {
                                first_err = Some(Error::msg(format!(
                                    "replay shard {} worker died mid-request",
                                    part.shard
                                )));
                            }
                            continue;
                        }
                    };
                    let n = g.rows();
                    if n == 0 || first_err.is_some() {
                        seg_pool.put(g);
                        continue;
                    }
                    if !sized {
                        dim = g.obs_dim();
                        merged.reset(requested, dim);
                        sized = true;
                    }
                    debug_assert_eq!(g.obs_dim(), dim, "shard obs_dim mismatch");
                    for (dst, &slot) in
                        merged.indices[rows..rows + n].iter_mut().zip(&g.indices)
                    {
                        *dst = global_index::encode(part.shard, slot);
                    }
                    merged.is_weights[rows..rows + n]
                        .copy_from_slice(&g.is_weights);
                    merged.obs[rows * dim..(rows + n) * dim]
                        .copy_from_slice(&g.obs);
                    merged.actions[rows..rows + n].copy_from_slice(&g.actions);
                    merged.rewards[rows..rows + n].copy_from_slice(&g.rewards);
                    merged.next_obs[rows * dim..(rows + n) * dim]
                        .copy_from_slice(&g.next_obs);
                    merged.dones[rows..rows + n].copy_from_slice(&g.dones);
                    rows += n;
                    seg_pool.put(g);
                }
                let out = if let Some(e) = first_err {
                    // the merged buffer is still whole — recycle it
                    // instead of letting the error path drain the pool
                    pool.put(merged);
                    Err(e)
                } else {
                    if sized {
                        merged.truncate(rows, dim);
                    } else {
                        merged.reset(0, 0);
                    }
                    Ok(merged)
                };
                stats.stages.merge.record(t.ns() as u64);
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A buffer with real column capacity (what a served reply looks
    /// like when it comes back from the learner).
    fn warm_buf() -> GatheredBatch {
        let mut b = GatheredBatch::default();
        b.reset(8, 4);
        b
    }

    #[test]
    fn pool_hits_after_recycle_and_respects_capacity() {
        let pool = ReplyPool::new(2);
        assert!(pool.take().is_none(), "empty pool must miss");
        pool.put(warm_buf());
        pool.put(warm_buf());
        pool.put(warm_buf()); // over capacity -> dropped
        assert_eq!(pool.idle(), 2);
        assert!(pool.take().is_some());
        assert!(pool.take().is_some());
        assert!(pool.take().is_none());
        let s = pool.stats();
        assert_eq!(s.hits.load(Ordering::Relaxed), 2);
        assert_eq!(s.misses.load(Ordering::Relaxed), 2);
        assert_eq!(s.recycled.load(Ordering::Relaxed), 2);
        assert_eq!(s.dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disabled_pool_always_allocates() {
        let pool = ReplyPool::new(0);
        pool.put(warm_buf());
        assert!(pool.take().is_none());
        assert_eq!(pool.stats().dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capacityless_buffers_are_not_pooled() {
        // an empty warmup reply recycled by a learner loop must not
        // occupy a pool slot: a "hit" on it would still allocate
        let pool = ReplyPool::new(4);
        pool.put(GatheredBatch::default());
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.stats().dropped.load(Ordering::Relaxed), 1);
        assert!(pool.take().is_none());
    }

    #[test]
    fn shrinking_capacity_evicts_idle_buffers() {
        let pool = ReplyPool::new(4);
        for _ in 0..4 {
            pool.put(warm_buf());
        }
        pool.set_capacity(1);
        assert_eq!(pool.idle(), 1);
        assert_eq!(pool.capacity(), 1);
    }

    #[test]
    fn reset_reuses_capacity_and_zero_fills_growth() {
        let mut b = GatheredBatch::default();
        b.reset(16, 4); // growth from empty is zero-filled
        assert!(b.obs.iter().all(|&x| x == 0.0));
        assert!(b.indices.iter().all(|&x| x == 0));
        b.obs.iter_mut().for_each(|x| *x = 1.0);
        let obs_ptr = b.obs.as_ptr();
        b.reset(8, 4); // shrink keeps the allocation (stale prefix is
                       // overwritten by every filler before being read)
        assert_eq!(b.rows(), 8);
        assert_eq!(b.obs.len(), 32);
        assert_eq!(b.obs.as_ptr(), obs_ptr, "reset must not reallocate");
        b.reset(16, 4); // regrow within capacity: still no realloc
        assert_eq!(b.obs.as_ptr(), obs_ptr);
        assert_eq!(b.obs.len(), 64);
    }
}
