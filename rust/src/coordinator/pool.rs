//! The gathered-reply buffer pool and the in-flight reply handle.
//!
//! Zero-copy gathered replies work by *lending* buffers instead of
//! allocating them: the learner hands consumed [`GatheredBatch`] buffers
//! back to its service handle ([`recycle`]), the handle attaches a pooled
//! buffer to the next `SampleGathered` command, and the worker gathers
//! **directly into the lent buffer** ([`GatheredBatch::reset`] resizes
//! the columns without reallocating). On the steady-state path every
//! request is a pool hit and a gathered batch crosses the service with
//! zero fresh allocations.
//!
//! [`PendingGather`] is the other half of the tentpole: a request that
//! has been *issued* but not yet *received*, so a pipelined learner can
//! keep `pipeline_depth` batches in flight while it trains on the
//! current one. For sharded services the pending handle owns the
//! pre-sized merged reply and merges replies in **completion order**:
//! every shard's row offset is precomputed from the request split, so
//! whichever reply lands first has its columns copied immediately —
//! a slow shard 0 hides behind the copy work of faster later shards
//! instead of gating it. A final compaction pass (in shard order, only
//! when some shard served short or timed out) closes the gaps, so a
//! fully-served merge is bit-identical to a fixed shard-order stream.
//! No all-shards join barrier before copy work starts, and no per-shard
//! column re-copies through `Vec` growth. All shard waits share one
//! deadline, so the worst-case wall time is one gather timeout — not
//! one per shard.
//!
//! [`recycle`]: crate::coordinator::LearnerPort::recycle

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::service::ServiceStats;
use crate::replay::traits::global_index;
use crate::replay::GatheredBatch;
use crate::util::error::{Error, Result};
use crate::util::json::{obj, Json};
use crate::util::Timer;

/// Counters exported by a [`ReplyPool`]. `misses` is the number of
/// requests that had to allocate a fresh reply buffer — the acceptance
/// bar for the zero-copy path is that this stays flat at steady state.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Requests served from a recycled buffer.
    pub hits: AtomicU64,
    /// Requests that allocated because the pool was empty (warmup) or
    /// disabled (`capacity == 0`).
    pub misses: AtomicU64,
    /// Buffers returned to the pool.
    pub recycled: AtomicU64,
    /// Returned buffers dropped: pool at capacity, or a capacity-less
    /// buffer not worth pooling.
    pub dropped: AtomicU64,
}

impl PoolStats {
    /// Hit percentage (0..=100) for explicit counter values (callers
    /// that snapshot the counters before reporting).
    pub fn rate_percent(hits: u64, misses: u64) -> f64 {
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    }

    /// Current hit percentage of this pool (0..=100).
    pub fn hit_rate_percent(&self) -> f64 {
        Self::rate_percent(
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Counter snapshot as JSON (for the serve stats dump).
    pub fn to_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("hits", n(&self.hits)),
            ("misses", n(&self.misses)),
            ("recycled", n(&self.recycled)),
            ("dropped", n(&self.dropped)),
            ("hit_rate_percent", Json::Num(self.hit_rate_percent())),
        ])
    }
}

struct PoolInner {
    bufs: Mutex<Vec<GatheredBatch>>,
    capacity: AtomicUsize,
    stats: PoolStats,
}

/// A bounded, cloneable free-list of [`GatheredBatch`] reply buffers
/// shared by all clones of a service handle.
#[derive(Clone)]
pub struct ReplyPool {
    inner: Arc<PoolInner>,
}

impl ReplyPool {
    /// Pool holding at most `capacity` idle buffers (0 disables pooling:
    /// every take is a miss, every recycle a drop — the PR-4 allocating
    /// behavior, kept as the bench baseline).
    pub fn new(capacity: usize) -> ReplyPool {
        ReplyPool {
            inner: Arc::new(PoolInner {
                bufs: Mutex::new(Vec::new()),
                capacity: AtomicUsize::new(capacity),
                stats: PoolStats::default(),
            }),
        }
    }

    /// Take a recycled buffer if one is available (counts hit/miss).
    pub fn take(&self) -> Option<GatheredBatch> {
        let got = self.inner.bufs.lock().expect("reply pool poisoned").pop();
        let stat = if got.is_some() {
            &self.inner.stats.hits
        } else {
            &self.inner.stats.misses
        };
        stat.fetch_add(1, Ordering::Relaxed);
        got
    }

    /// Return a consumed buffer; dropped if the pool is at capacity.
    /// Buffers that never grew any column capacity (e.g. empty warmup
    /// replies recycled by a learner loop) are dropped too: pooling them
    /// would let a later "hit" still allocate every column, which would
    /// make the hit counter overstate the allocation-free guarantee.
    pub fn put(&self, buf: GatheredBatch) {
        let cap = self.inner.capacity.load(Ordering::Relaxed);
        if buf.obs.capacity() == 0 && buf.indices.capacity() == 0 {
            self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut bufs = self.inner.bufs.lock().expect("reply pool poisoned");
        if bufs.len() < cap {
            bufs.push(buf);
            self.inner.stats.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account for a lent buffer that will never come back: its reply
    /// timed out, its worker died mid-request, or its request could not
    /// be sent and carried no buffer. Counted under `dropped` so the
    /// quiescent identity `hits + misses == recycled + dropped` keeps
    /// holding with faults in play — every `take` (hit *or* miss, since
    /// a miss makes the worker allocate the reply) must end in exactly
    /// one `put` or `note_lost`.
    pub fn note_lost(&self) {
        self.inner.stats.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Change the idle-buffer bound (the `reply_pool` config knob).
    pub fn set_capacity(&self, capacity: usize) {
        self.inner.capacity.store(capacity, Ordering::Relaxed);
        let mut bufs = self.inner.bufs.lock().expect("reply pool poisoned");
        if bufs.len() > capacity {
            bufs.truncate(capacity);
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity.load(Ordering::Relaxed)
    }

    /// Idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        self.inner.bufs.lock().expect("reply pool poisoned").len()
    }

    pub fn stats(&self) -> &PoolStats {
        &self.inner.stats
    }
}

/// Park time on a quiet shard between completion-order readiness sweeps
/// (`std::sync::mpsc` has no select). Only bounds how quickly a reply
/// from a *different* shard is noticed while one shard is quiet; the
/// parked shard's own reply wakes the wait immediately.
const POLL_SLICE: Duration = Duration::from_micros(500);

/// One per-shard leg of a sharded gather request.
pub(crate) struct ShardPart {
    pub(crate) shard: usize,
    /// Rows asked of this shard (truncation accounting on timeout).
    pub(crate) requested: usize,
    pub(crate) rx: Receiver<Result<GatheredBatch>>,
}

pub(crate) enum PendingInner {
    /// Single-owner service: one reply channel.
    Single {
        rx: Receiver<Result<GatheredBatch>>,
        /// Bound on the reply wait (the handle's gather timeout).
        timeout: Duration,
        /// Accounts the lent buffer if the reply never arrives.
        pool: ReplyPool,
        /// Merge-stage histogram + timeout counters.
        stats: Arc<ServiceStats>,
    },
    /// Sharded service: per-shard replies merged by shard-offset writes
    /// into one pre-sized reply taken from the merged-reply pool.
    Sharded {
        parts: Vec<ShardPart>,
        /// Total rows requested across all shards (pre-size bound).
        requested: usize,
        /// The merged reply buffer (pooled).
        merged: GatheredBatch,
        /// The merged-reply pool (error path recycles `merged` here).
        pool: ReplyPool,
        /// Per-shard segment buffers return here after merging.
        seg_pool: ReplyPool,
        /// Bound on each shard's reply wait.
        timeout: Duration,
        /// Merge-stage histogram + timeout counters.
        stats: Arc<ServiceStats>,
        /// Some shard worker was already dead at request time.
        dead: bool,
    },
    /// The worker was dead at request time; nothing is in flight and
    /// `wait` resolves to an error immediately.
    Dead,
}

/// An issued `sample_gathered` request whose reply has not been received
/// yet. Obtained from [`LearnerPort::request_gathered`]; [`Self::wait`]
/// blocks for the reply (merging per-shard replies in completion order
/// for sharded services). Dropping a pending request abandons the
/// reply; the worker's send fails silently and its buffer is freed.
///
/// [`LearnerPort::request_gathered`]: crate::coordinator::LearnerPort::request_gathered
pub struct PendingGather {
    pub(crate) inner: PendingInner,
}

impl PendingGather {
    /// Block until the gathered batch is available — bounded by the
    /// issuing handle's gather timeout, never forever.
    ///
    /// Fault semantics: a dead worker resolves to `Err`; a sharded
    /// request with one *slow* shard resolves to `Ok` with the rows the
    /// healthy shards served (the timed-out shard's rows are accounted
    /// in `ServiceStats::{shard_timeouts, truncated_rows}`); a shard
    /// worker that *died* mid-request resolves to `Err` after the other
    /// shards' segment buffers have drained back to their pool. Every
    /// path recycles or accounts every pooled buffer.
    pub fn wait(self) -> Result<GatheredBatch> {
        match self.inner {
            PendingInner::Dead => Err(Error::msg(
                "replay service worker has stopped; request was not sent",
            )),
            PendingInner::Single { rx, timeout, pool, stats } => {
                let t = Timer::start();
                let out = match rx.recv_timeout(timeout) {
                    Ok(res) => res,
                    Err(RecvTimeoutError::Timeout) => {
                        // the lent buffer (or the miss-path allocation)
                        // is stuck with the wedged worker — account it
                        pool.note_lost();
                        Err(Error::msg(format!(
                            "gathered reply timed out after {timeout:?}"
                        )))
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        pool.note_lost();
                        Err(Error::msg(
                            "replay service worker died before replying",
                        ))
                    }
                };
                stats.stages.merge.record(t.ns() as u64);
                out
            }
            PendingInner::Sharded {
                parts,
                requested,
                mut merged,
                pool,
                seg_pool,
                timeout,
                stats,
                dead,
            } => {
                // Merge in completion order: the reply buffer is
                // pre-sized once for the full request and every shard's
                // row offset is precomputed from the request split, so
                // whichever reply lands first has its columns copied
                // immediately — a slow shard 0 hides behind the copy
                // work of faster later shards instead of gating it.
                // `std::sync::mpsc` has no select, so readiness is
                // polled with `try_recv` across the outstanding parts,
                // parking briefly on one of them between sweeps; all
                // parts share a single deadline. A compaction pass (in
                // shard order, only when some shard served short or
                // timed out) closes the gaps, so a fully-served merge
                // is bit-identical to a fixed shard-order stream.
                let t = Timer::start();
                let mut dim = 0usize;
                let mut sized = false;
                let mut first_err = if dead {
                    Some(Error::msg(
                        "a replay shard worker had stopped at request time",
                    ))
                } else {
                    None
                };
                let mut offsets = Vec::with_capacity(parts.len());
                let mut off = 0usize;
                for part in &parts {
                    offsets.push(off);
                    off += part.requested;
                }
                let mut served = vec![0usize; parts.len()];
                // a received reply: merge at the part's precomputed
                // offset (or recycle it on the empty/error paths)
                let mut settle = |idx: usize,
                                  res: Result<GatheredBatch>,
                                  merged: &mut GatheredBatch,
                                  first_err: &mut Option<Error>| {
                    let g = match res {
                        Ok(g) => g,
                        Err(e) => {
                            if first_err.is_none() {
                                *first_err = Some(e);
                            }
                            return;
                        }
                    };
                    let n = g.rows();
                    if n == 0 || first_err.is_some() {
                        seg_pool.put(g);
                        return;
                    }
                    if !sized {
                        dim = g.obs_dim();
                        merged.reset(requested, dim);
                        sized = true;
                    }
                    debug_assert_eq!(g.obs_dim(), dim, "shard obs_dim mismatch");
                    let at = offsets[idx];
                    let shard = parts[idx].shard;
                    for (dst, &slot) in
                        merged.indices[at..at + n].iter_mut().zip(&g.indices)
                    {
                        *dst = global_index::encode(shard, slot);
                    }
                    merged.is_weights[at..at + n].copy_from_slice(&g.is_weights);
                    merged.obs[at * dim..(at + n) * dim]
                        .copy_from_slice(&g.obs);
                    merged.actions[at..at + n].copy_from_slice(&g.actions);
                    merged.rewards[at..at + n].copy_from_slice(&g.rewards);
                    merged.next_obs[at * dim..(at + n) * dim]
                        .copy_from_slice(&g.next_obs);
                    merged.dones[at..at + n].copy_from_slice(&g.dones);
                    served[idx] = n;
                    seg_pool.put(g);
                };
                let deadline = Instant::now() + timeout;
                let mut outstanding: Vec<usize> = (0..parts.len()).collect();
                'merge: while !outstanding.is_empty() {
                    // non-blocking sweep: drain every reply that is ready
                    let mut progressed = false;
                    let mut k = 0;
                    while k < outstanding.len() {
                        let idx = outstanding[k];
                        match parts[idx].rx.try_recv() {
                            Ok(res) => {
                                settle(idx, res, &mut merged, &mut first_err);
                                outstanding.swap_remove(k);
                                progressed = true;
                            }
                            Err(TryRecvError::Empty) => k += 1,
                            Err(TryRecvError::Disconnected) => {
                                seg_pool.note_lost();
                                if first_err.is_none() {
                                    first_err = Some(Error::msg(format!(
                                        "replay shard {} worker died mid-request",
                                        parts[idx].shard
                                    )));
                                }
                                outstanding.swap_remove(k);
                                progressed = true;
                            }
                        }
                    }
                    if progressed || outstanding.is_empty() {
                        continue;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        // slow shards: serve the batch short instead of
                        // stalling the learner behind the slowest one
                        for &idx in &outstanding {
                            stats
                                .shard_timeouts
                                .fetch_add(1, Ordering::Relaxed);
                            stats.truncated_rows.fetch_add(
                                parts[idx].requested as u64,
                                Ordering::Relaxed,
                            );
                            seg_pool.note_lost();
                        }
                        break 'merge;
                    }
                    // park on one outstanding part; the slice keeps the
                    // sweep responsive to the *other* shards while this
                    // one stays quiet (only the gap until the next sweep
                    // of already-ready replies, never added completion
                    // latency — the merge can't finish without this part
                    // anyway)
                    let slice = (deadline - now).min(POLL_SLICE);
                    let idx = outstanding[0];
                    match parts[idx].rx.recv_timeout(slice) {
                        Ok(res) => {
                            settle(idx, res, &mut merged, &mut first_err);
                            outstanding.swap_remove(0);
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => {
                            seg_pool.note_lost();
                            if first_err.is_none() {
                                first_err = Some(Error::msg(format!(
                                    "replay shard {} worker died mid-request",
                                    parts[idx].shard
                                )));
                            }
                            outstanding.swap_remove(0);
                        }
                    }
                }
                drop(settle);
                let out = if let Some(e) = first_err {
                    // the merged buffer is still whole — recycle it
                    // instead of letting the error path drain the pool
                    pool.put(merged);
                    Err(e)
                } else if sized {
                    // compact in shard order: close the gaps left by
                    // shards that served short or timed out (no-op — and
                    // bit-identical to the old shard-order stream — when
                    // every shard served its full sub-batch)
                    let mut rows = 0usize;
                    for (idx, &n) in served.iter().enumerate() {
                        if n == 0 {
                            continue;
                        }
                        let at = offsets[idx];
                        if at != rows {
                            merged.indices.copy_within(at..at + n, rows);
                            merged
                                .is_weights
                                .copy_within(at..at + n, rows);
                            merged.obs.copy_within(
                                at * dim..(at + n) * dim,
                                rows * dim,
                            );
                            merged.actions.copy_within(at..at + n, rows);
                            merged.rewards.copy_within(at..at + n, rows);
                            merged.next_obs.copy_within(
                                at * dim..(at + n) * dim,
                                rows * dim,
                            );
                            merged.dones.copy_within(at..at + n, rows);
                        }
                        rows += n;
                    }
                    merged.truncate(rows, dim);
                    Ok(merged)
                } else {
                    merged.reset(0, 0);
                    Ok(merged)
                };
                stats.stages.merge.record(t.ns() as u64);
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A buffer with real column capacity (what a served reply looks
    /// like when it comes back from the learner).
    fn warm_buf() -> GatheredBatch {
        let mut b = GatheredBatch::default();
        b.reset(8, 4);
        b
    }

    #[test]
    fn pool_hits_after_recycle_and_respects_capacity() {
        let pool = ReplyPool::new(2);
        assert!(pool.take().is_none(), "empty pool must miss");
        pool.put(warm_buf());
        pool.put(warm_buf());
        pool.put(warm_buf()); // over capacity -> dropped
        assert_eq!(pool.idle(), 2);
        assert!(pool.take().is_some());
        assert!(pool.take().is_some());
        assert!(pool.take().is_none());
        let s = pool.stats();
        assert_eq!(s.hits.load(Ordering::Relaxed), 2);
        assert_eq!(s.misses.load(Ordering::Relaxed), 2);
        assert_eq!(s.recycled.load(Ordering::Relaxed), 2);
        assert_eq!(s.dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disabled_pool_always_allocates() {
        let pool = ReplyPool::new(0);
        pool.put(warm_buf());
        assert!(pool.take().is_none());
        assert_eq!(pool.stats().dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capacityless_buffers_are_not_pooled() {
        // an empty warmup reply recycled by a learner loop must not
        // occupy a pool slot: a "hit" on it would still allocate
        let pool = ReplyPool::new(4);
        pool.put(GatheredBatch::default());
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.stats().dropped.load(Ordering::Relaxed), 1);
        assert!(pool.take().is_none());
    }

    #[test]
    fn shrinking_capacity_evicts_idle_buffers() {
        let pool = ReplyPool::new(4);
        for _ in 0..4 {
            pool.put(warm_buf());
        }
        pool.set_capacity(1);
        assert_eq!(pool.idle(), 1);
        assert_eq!(pool.capacity(), 1);
    }

    #[test]
    fn reset_reuses_capacity_and_zero_fills_growth() {
        let mut b = GatheredBatch::default();
        b.reset(16, 4); // growth from empty is zero-filled
        assert!(b.obs.iter().all(|&x| x == 0.0));
        assert!(b.indices.iter().all(|&x| x == 0));
        b.obs.iter_mut().for_each(|x| *x = 1.0);
        let obs_ptr = b.obs.as_ptr();
        b.reset(8, 4); // shrink keeps the allocation (stale prefix is
                       // overwritten by every filler before being read)
        assert_eq!(b.rows(), 8);
        assert_eq!(b.obs.len(), 32);
        assert_eq!(b.obs.as_ptr(), obs_ptr, "reset must not reallocate");
        b.reset(16, 4); // regrow within capacity: still no realloc
        assert_eq!(b.obs.as_ptr(), obs_ptr);
        assert_eq!(b.obs.len(), 64);
    }
}
