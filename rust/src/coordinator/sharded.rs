//! Sharded replay service: N single-owner shard workers behind one
//! cloneable handle.
//!
//! The single-owner [`super::ReplayService`] mirrors the paper's
//! one-search-port-per-bank hardware, but at service scale it serializes
//! every actor and learner behind one command queue. This service keeps
//! the per-shard ownership model (each worker owns its own
//! [`ReplayMemory`] partition and RNG — no locks, no sharing) and scales
//! the port count instead, exactly like tiling more TCAM banks:
//!
//! * **push** routes round-robin across shards (or by caller-supplied
//!   hash via [`ShardedHandle::push_to`]), so partitions stay balanced;
//! * **sample** / **sample_gathered** fan one batch out as per-shard
//!   sub-batches (remainder spread over the first shards), run
//!   concurrently on every shard worker, and merge the replies;
//! * every index crossing the boundary is a
//!   [`global_index`](crate::replay::traits::global_index) encoding
//!   `(shard, slot)`, so **update_priorities** can route each TD error
//!   back to the shard that owns the slot;
//! * determinism: shard workers draw from RNGs derived from
//!   `(seed, shard)` only, so a given (seed, shard count, command
//!   sequence) reproduces exactly.
//!
//! Priority semantics: sampling is prioritized *within* each shard while
//! the batch is split evenly *across* shards. With round-robin placement
//! the shards hold statistically identical priority distributions, so a
//! hot transition is oversampled globally no matter which shard holds it
//! (pinned by `high_priority_oversampled_on_any_shard`); the paper's
//! Predictive-PER-style per-bank behavior stays testable per shard.
//!
//! IS-weight caveat: PER importance weights are normalized by each
//! shard's *local* `max_w` (its own length and min priority), so merged
//! weights are comparable across shards only while the shard
//! distributions match — which round-robin placement maintains. Routing
//! by [`ShardedHandle::push_to`] affinity (or sampling while shards warm
//! unevenly) skews shard distributions and with them the relative weight
//! scale across shards; learners that rely on exact IS corrections
//! should stick to round-robin ingest.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::pool::{PendingGather, PendingInner, ReplyPool, ShardPart};
use super::service::{
    run_worker, Command, FaultPlan, QueueGauge, ServiceStats,
    DEFAULT_GATHER_TIMEOUT_MS,
};
use crate::replay::traits::global_index;
use crate::replay::{
    Experience, ExperienceBatch, GatheredBatch, ReplayMemory, SampledBatch,
};
use crate::util::error::Result;
use crate::util::json::{obj, Json};
use crate::util::{Rng, Timer};

/// Cloneable handle onto the shard workers.
#[derive(Clone)]
pub struct ShardedHandle {
    shards: Arc<Vec<SyncSender<Command>>>,
    next: Arc<AtomicUsize>,
    stats: Arc<ServiceStats>,
    /// Pool of merged reply buffers (what learners receive and recycle).
    pool: ReplyPool,
    /// Pool of per-shard segment buffers (recycled internally by the
    /// merge as each shard reply lands).
    seg_pool: ReplyPool,
    /// One queue-depth gauge per shard command queue.
    gauges: Arc<Vec<Arc<QueueGauge>>>,
    /// Gathered-reply wait bound in ms (shared across clones).
    timeout_ms: Arc<AtomicU64>,
}

impl ShardedHandle {
    /// Number of shard workers behind this handle.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Store one experience on the next shard (round-robin; blocks under
    /// backpressure). Returns whether the shard accepted it. This is the
    /// scalar convenience over the batch-first protocol (a 1-row batch).
    #[must_use = "a false return means the service dropped the experience"]
    pub fn push(&self, e: Experience) -> bool {
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.push_to(shard, e)
    }

    /// Store one experience on an explicit shard (hash/affinity routing).
    /// Note: skewing shard contents away from the round-robin balance
    /// makes PER IS weights incomparable across shards (see the module
    /// docs) — prefer [`Self::push`] when exact IS corrections matter.
    #[must_use = "a false return means the service dropped the experience"]
    pub fn push_to(&self, shard: usize, e: Experience) -> bool {
        self.push_batch_to(shard, ExperienceBatch::from_experience(e))
    }

    /// Store a whole batch on an explicit shard in one command.
    #[must_use = "a false return means the service dropped the batch"]
    pub fn push_batch_to(&self, shard: usize, batch: ExperienceBatch) -> bool {
        let rows = batch.len() as u64;
        if rows == 0 {
            return true;
        }
        let shard = shard % self.shards.len();
        self.gauges[shard].inc();
        match self.shards[shard].send(Command::PushBatch(batch)) {
            Ok(()) => {
                self.stats.pushes.fetch_add(rows, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.gauges[shard].dec();
                false
            }
        }
    }

    /// Store a whole batch, split into per-shard sub-batches in one pass.
    /// Rows continue the same round-robin rotation the scalar
    /// [`Self::push`] uses (row `i` lands on shard `(next + i) % N`), so
    /// batched and scalar ingest interleave without skewing the balance.
    /// Each shard receives at most one `PushBatch` command. Returns
    /// whether every addressed shard accepted its sub-batch.
    #[must_use = "a false return means at least one shard dropped its sub-batch"]
    pub fn push_batch(&self, batch: ExperienceBatch) -> bool {
        let n = self.shards.len();
        let rows = batch.len();
        if rows == 0 {
            return true;
        }
        // one flush-stage sample covers the whole split + send (incl.
        // time blocked under backpressure on the slowest shard)
        let t = Timer::start();
        let start = self.next.fetch_add(rows, Ordering::Relaxed);
        let ok = if n == 1 {
            self.push_batch_to(0, batch)
        } else if rows == 1 {
            // single-row batch: route directly, skip the sub-batch split
            // (the push_batch=1 ingest default would otherwise allocate N
            // sub-batches per env step)
            self.push_batch_to(start % n, batch)
        } else {
            let per = rows.div_ceil(n);
            let mut subs: Vec<ExperienceBatch> = (0..n)
                .map(|_| ExperienceBatch::with_capacity(batch.obs_dim(), per))
                .collect();
            for row in 0..rows {
                subs[(start + row) % n].push_row(&batch, row);
            }
            let mut ok = true;
            for (shard, sub) in subs.into_iter().enumerate() {
                if sub.is_empty() {
                    continue;
                }
                ok &= self.push_batch_to(shard, sub);
            }
            ok
        };
        if ok {
            self.stats.stages.flush.record(t.ns() as u64);
        }
        ok
    }

    /// Per-shard sub-batch sizes for a request of `batch` (remainder
    /// spread over the leading shards).
    fn split(&self, batch: usize) -> Vec<usize> {
        let n = self.shards.len();
        let base = batch / n;
        let rem = batch % n;
        (0..n).map(|i| base + usize::from(i < rem)).collect()
    }

    /// Sample `batch` transitions: fan per-shard sub-batches out, merge
    /// replies, with indices globally encoded as `(shard, slot)`. Shards
    /// still warming up (empty) contribute nothing, so the merged batch
    /// can be shorter than requested until every shard has data.
    ///
    /// # Panics
    /// Panics if a shard worker has stopped.
    pub fn sample(&self, batch: usize) -> SampledBatch {
        let sizes = self.split(batch);
        let mut replies = Vec::with_capacity(self.shards.len());
        for (shard, (&size, tx)) in sizes.iter().zip(self.shards.iter()).enumerate() {
            if size == 0 {
                continue;
            }
            let (reply_tx, reply_rx) = sync_channel(1);
            self.gauges[shard].inc();
            tx.send(Command::Sample { batch: size, reply: reply_tx })
                .expect("shard worker stopped");
            replies.push((shard, reply_rx));
        }
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        let mut out = SampledBatch::default();
        for (shard, rx) in replies {
            let b = rx.recv().expect("shard dropped reply");
            out.indices.extend(
                b.indices.iter().map(|&slot| global_index::encode(shard, slot)),
            );
            out.is_weights.extend_from_slice(&b.is_weights);
        }
        out
    }

    /// Sample and gather `batch` transitions into flat buffers (one round
    /// trip per shard, gathers run inside the owner threads — in
    /// parallel across shards). Indices are globally encoded. An `Err`
    /// means a shard caught a corrupt index at its ring boundary or a
    /// shard worker died; a shard that merely misses the gather timeout
    /// yields a *short* `Ok` batch with the truncation accounted in
    /// [`ServiceStats`]. Never panics, never blocks past the timeout.
    ///
    /// Equivalent to `request_gathered(batch).wait()`; use
    /// [`Self::request_gathered`] + a later `wait` to pipeline requests.
    pub fn sample_gathered(&self, batch: usize) -> Result<GatheredBatch> {
        self.request_gathered(batch).wait()
    }

    /// Fan a gather request out to the shards **without waiting for the
    /// replies**: each shard receives a lent segment buffer (pool hit)
    /// to gather into, and the returned handle owns a pooled merged
    /// reply pre-sized for the whole request. `wait` consumes replies in
    /// **completion order** with precomputed shard-offset column writes
    /// (a slow shard 0 hides behind faster later shards), then compacts
    /// any timed-out shard's gap in shard order — no growth re-copies,
    /// no allocation on the steady-state path, and the fully-served
    /// merge is bit-identical to the old shard-order stream.
    ///
    /// Shards whose worker already died are skipped (their segment
    /// buffers return to the pool); the live shards still serve so
    /// their buffers drain, and `wait` reports the dead shard as `Err`.
    pub fn request_gathered(&self, batch: usize) -> PendingGather {
        self.request_gathered_into(batch, &self.pool)
    }

    /// [`Self::request_gathered`] drawing the *merged* reply buffer from
    /// (and settling recovery into) an explicit `pool` — the net server
    /// issues each client's gathers against that client's private pool.
    /// Segment buffers still come from the shared per-shard segment
    /// pool: they never leave the service.
    pub(crate) fn request_gathered_into(
        &self,
        batch: usize,
        pool: &ReplyPool,
    ) -> PendingGather {
        let sizes = self.split(batch);
        let mut parts = Vec::with_capacity(self.shards.len());
        let mut dead = false;
        for (shard, (&size, tx)) in
            sizes.iter().zip(self.shards.iter()).enumerate()
        {
            if size == 0 {
                continue;
            }
            let (reply_tx, reply_rx) = sync_channel(1);
            let buf = self.seg_pool.take();
            self.gauges[shard].inc();
            let cmd =
                Command::SampleGathered { batch: size, buf, reply: reply_tx };
            match tx.send(cmd) {
                Ok(()) => parts.push(ShardPart {
                    shard,
                    requested: size,
                    rx: reply_rx,
                }),
                Err(e) => {
                    self.gauges[shard].dec();
                    dead = true;
                    // recover the lent segment buffer (or balance the
                    // miss) so a dead shard never leaks pool capacity
                    match e.0 {
                        Command::SampleGathered { buf: Some(b), .. } => {
                            self.seg_pool.put(b)
                        }
                        _ => self.seg_pool.note_lost(),
                    }
                }
            }
        }
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        let merged = pool.take().unwrap_or_default();
        PendingGather {
            inner: PendingInner::Sharded {
                parts,
                requested: batch,
                merged,
                pool: pool.clone(),
                seg_pool: self.seg_pool.clone(),
                timeout: self.gather_timeout(),
                stats: Arc::clone(&self.stats),
                dead,
            },
        }
    }

    /// Return a consumed merged reply buffer to the pool so the next
    /// `sample_gathered` refills it in place instead of allocating.
    pub fn recycle(&self, buf: GatheredBatch) {
        self.pool.put(buf);
    }

    /// The merged-reply buffer pool (stats + the `reply_pool` knob).
    pub fn reply_pool(&self) -> &ReplyPool {
        &self.pool
    }

    /// The per-shard segment buffer pool (recycled internally).
    pub fn segment_pool(&self) -> &ReplyPool {
        &self.seg_pool
    }

    /// Feed back TD errors for a previously sampled batch: each
    /// globally-encoded index routes its TD error to the owning shard,
    /// coalesced into **one** `UpdatePriorities` message per shard (the
    /// shard worker then applies it with one batched pass). Returns
    /// whether every shard accepted its slice.
    #[must_use = "a false return means at least one shard dropped its update"]
    pub fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        debug_assert_eq!(indices.len(), td.len());
        let n = self.shards.len();
        let mut per_shard: Vec<(Vec<usize>, Vec<f32>)> =
            (0..n).map(|_| (Vec::new(), Vec::new())).collect();
        for (&g, &e) in indices.iter().zip(&td) {
            let (shard, slot) = global_index::decode(g);
            debug_assert!(shard < n, "global index {g:#x} addresses shard {shard}");
            per_shard[shard % n].0.push(slot);
            per_shard[shard % n].1.push(e);
        }
        let mut ok = true;
        let mut any = false;
        for (shard, (idx, td)) in per_shard.into_iter().enumerate() {
            if idx.is_empty() {
                continue;
            }
            any = true;
            self.gauges[shard].inc();
            let sent = self.shards[shard]
                .send(Command::UpdatePriorities { indices: idx, td })
                .is_ok();
            if !sent {
                self.gauges[shard].dec();
            }
            ok &= sent;
        }
        if any && ok {
            self.stats.updates.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    /// Accepted-command counters (shared across all clones).
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Worst per-shard command-queue fill fraction. The adaptive flush
    /// watches the most backed-up shard: a batch split blocks on it.
    pub fn queue_load(&self) -> f64 {
        self.gauges.iter().map(|g| g.load()).fold(0.0, f64::max)
    }

    /// Per-shard queue gauges (index = shard id).
    pub fn queue_gauges(&self) -> &[Arc<QueueGauge>] {
        &self.gauges
    }

    /// Bound every gathered-reply wait issued through this handle (and
    /// its clones) from now on; the bound applies per shard reply.
    pub fn set_gather_timeout(&self, timeout: Duration) {
        let ms = timeout.as_millis().clamp(1, u64::MAX as u128) as u64;
        self.timeout_ms.store(ms, Ordering::Relaxed);
    }

    /// Current gathered-reply wait bound.
    pub fn gather_timeout(&self) -> Duration {
        Duration::from_millis(self.timeout_ms.load(Ordering::Relaxed))
    }

    /// Full operability snapshot: counters, per-stage latency
    /// histograms, summed queue depth, and both pools' accounting.
    pub fn stats_json(&self) -> Json {
        let depth: usize = self.gauges.iter().map(|g| g.depth()).sum();
        let capacity: usize = self.gauges.iter().map(|g| g.capacity()).sum();
        obj(vec![
            ("service", self.stats.to_json()),
            ("stages", self.stats.stages.to_json()),
            (
                "queue",
                obj(vec![
                    ("depth", Json::Num(depth as f64)),
                    ("capacity", Json::Num(capacity as f64)),
                ]),
            ),
            (
                "pools",
                obj(vec![
                    ("reply", self.pool.stats().to_json()),
                    ("segment", self.seg_pool.stats().to_json()),
                ]),
            ),
            ("snapshot", self.stats.snapshot.to_json()),
        ])
    }
}

/// The running sharded service (owns the shard worker threads).
pub struct ShardedReplayService {
    handle: ShardedHandle,
    workers: Vec<JoinHandle<Box<dyn ReplayMemory>>>,
}

impl ShardedReplayService {
    /// Spawn `shards` workers, each owning the memory produced by
    /// `make_shard(shard_id)`. `queue_depth` bounds each shard's command
    /// queue; worker RNGs derive deterministically from `(seed, shard)`.
    pub fn spawn(
        shards: usize,
        queue_depth: usize,
        seed: u64,
        make_shard: impl FnMut(usize) -> Box<dyn ReplayMemory>,
    ) -> ShardedReplayService {
        Self::spawn_inner(shards, queue_depth, seed, make_shard, |_| {
            FaultPlan::default()
        })
    }

    /// Spawn with per-shard injected [`FaultPlan`]s (fault-injection
    /// tests only): `fault_for_shard(shard)` builds shard `shard`'s plan.
    #[cfg(feature = "testing")]
    pub fn spawn_with_faults(
        shards: usize,
        queue_depth: usize,
        seed: u64,
        make_shard: impl FnMut(usize) -> Box<dyn ReplayMemory>,
        fault_for_shard: impl FnMut(usize) -> FaultPlan,
    ) -> ShardedReplayService {
        Self::spawn_inner(shards, queue_depth, seed, make_shard, fault_for_shard)
    }

    fn spawn_inner(
        shards: usize,
        queue_depth: usize,
        seed: u64,
        mut make_shard: impl FnMut(usize) -> Box<dyn ReplayMemory>,
        mut fault_for_shard: impl FnMut(usize) -> FaultPlan,
    ) -> ShardedReplayService {
        assert!(shards >= 1, "need at least one shard");
        assert!(
            shards <= global_index::MAX_SHARDS,
            "{} shards exceeds the global-index limit {}",
            shards,
            global_index::MAX_SHARDS
        );
        let stats = Arc::new(ServiceStats::default());
        let mut txs = Vec::with_capacity(shards);
        let mut gauges = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = sync_channel(queue_depth);
            let memory = make_shard(shard);
            let faults = fault_for_shard(shard);
            let rng = Rng::new(
                seed ^ (shard as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let gauge = QueueGauge::new(queue_depth);
            let worker_stats = Arc::clone(&stats);
            let worker_gauge = Arc::clone(&gauge);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("replay-shard-{shard}"))
                    .spawn(move || {
                        run_worker(
                            memory,
                            rx,
                            rng,
                            worker_stats,
                            worker_gauge,
                            faults,
                        )
                    })
                    .expect("spawn replay shard"),
            );
            txs.push(tx);
            gauges.push(gauge);
        }
        ShardedReplayService {
            handle: ShardedHandle {
                shards: Arc::new(txs),
                next: Arc::new(AtomicUsize::new(0)),
                stats,
                pool: ReplyPool::new(super::service::DEFAULT_REPLY_POOL),
                // every in-flight request lends one segment per shard
                seg_pool: ReplyPool::new(
                    shards * super::service::DEFAULT_REPLY_POOL,
                ),
                gauges: Arc::new(gauges),
                timeout_ms: Arc::new(AtomicU64::new(
                    DEFAULT_GATHER_TIMEOUT_MS,
                )),
            },
            workers,
        }
    }

    /// Convenience: shard one logical capacity evenly across workers,
    /// each shard built by `make_shard(shard_id, shard_capacity)`.
    pub fn spawn_partitioned(
        total_capacity: usize,
        shards: usize,
        queue_depth: usize,
        seed: u64,
        mut make_shard: impl FnMut(usize, usize) -> Box<dyn ReplayMemory>,
    ) -> ShardedReplayService {
        let per_shard = total_capacity.div_ceil(shards).max(1);
        Self::spawn(shards, queue_depth, seed, |shard| make_shard(shard, per_shard))
    }

    pub fn handle(&self) -> ShardedHandle {
        self.handle.clone()
    }

    pub fn shard_count(&self) -> usize {
        self.workers.len()
    }

    /// Stop every shard worker and recover the per-shard memories (index
    /// = shard id).
    ///
    /// Graceful drain: each shard's command queue is FIFO, so every
    /// accepted push/update is applied before its worker exits. A shard
    /// whose worker already died fails the send fast and is simply
    /// joined — a crashed shard never deadlocks `stop`.
    pub fn stop(mut self) -> Vec<Box<dyn ReplayMemory>> {
        for (shard, tx) in self.handle.shards.iter().enumerate() {
            self.handle.gauges[shard].inc();
            if tx.send(Command::Stop).is_err() {
                self.handle.gauges[shard].dec();
            }
        }
        self.workers
            .drain(..)
            .map(|w| w.join().expect("shard worker panicked"))
            .collect()
    }

    /// [`Self::stop`], plus a final [`ShardedHandle::stats_json`] report
    /// snapshotted *after* the drain completes.
    pub fn stop_with_report(self) -> (Vec<Box<dyn ReplayMemory>>, Json) {
        let h = self.handle();
        let mems = self.stop();
        (mems, h.stats_json())
    }
}

impl Drop for ShardedReplayService {
    fn drop(&mut self) {
        for (shard, tx) in self.handle.shards.iter().enumerate() {
            self.handle.gauges[shard].inc();
            if tx.send(Command::Stop).is_err() {
                self.handle.gauges[shard].dec();
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{PerParams, PerReplay, ReplayKind};

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v; 4],
            action: 0,
            reward: v,
            next_obs: vec![v; 4],
            done: false,
        }
    }

    fn per_shards(
        total_capacity: usize,
        shards: usize,
        seed: u64,
    ) -> ShardedReplayService {
        ShardedReplayService::spawn_partitioned(
            total_capacity,
            shards,
            1024,
            seed,
            |_, cap| Box::new(PerReplay::new(cap, PerParams::default())),
        )
    }

    #[test]
    fn push_distributes_round_robin() {
        let svc = per_shards(4096, 4, 0);
        let h = svc.handle();
        for i in 0..1000 {
            assert!(h.push(exp(i as f32)));
        }
        let mems = svc.stop();
        assert_eq!(mems.len(), 4);
        assert_eq!(mems.iter().map(|m| m.len()).sum::<usize>(), 1000);
        for (s, m) in mems.iter().enumerate() {
            assert_eq!(m.len(), 250, "shard {s} holds {}", m.len());
        }
    }

    #[test]
    fn push_batch_splits_rows_round_robin() {
        let svc = per_shards(4096, 4, 0);
        let h = svc.handle();
        // 2 scalar pushes advance the rotation, then one 10-row batch
        // must continue it: row i lands on shard (2 + i) % 4
        assert!(h.push(exp(0.0)));
        assert!(h.push(exp(1.0)));
        let exps: Vec<Experience> = (2..12).map(|i| exp(i as f32)).collect();
        assert!(h.push_batch(ExperienceBatch::from_experiences(&exps)));
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 12);
        let mems = svc.stop();
        for global_row in 0..12usize {
            let shard = global_row % 4;
            let slot = global_row / 4;
            assert_eq!(
                mems[shard].ring().reward_of(slot),
                global_row as f32,
                "row {global_row} misrouted"
            );
        }
    }

    #[test]
    fn sample_merges_full_batch_and_routes_updates() {
        let svc = per_shards(4096, 4, 1);
        let h = svc.handle();
        for i in 0..800 {
            assert!(h.push(exp(i as f32)));
        }
        let b = h.sample(64);
        assert_eq!(b.indices.len(), 64);
        assert_eq!(b.is_weights.len(), 64);
        // every index decodes to a live shard/slot
        for &g in &b.indices {
            let (shard, slot) = global_index::decode(g);
            assert!(shard < 4, "index {g:#x}");
            assert!(slot < 200, "slot {slot} out of range");
        }
        assert!(h.update_priorities(b.indices.clone(), vec![1.5; 64]));
        let mems = svc.stop();
        // the priority updates landed on the owning shards: at least one
        // updated slot per touched shard now differs from max priority 1.0
        let mut touched = std::collections::HashSet::new();
        for &g in &b.indices {
            touched.insert(global_index::decode(g));
        }
        for &(shard, slot) in &touched {
            let p = mems[shard].priority_of(slot);
            assert!(
                (p - crate::replay::priority_from_td(1.5, 1e-2, 0.6)).abs() < 1e-5,
                "shard {shard} slot {slot}: priority {p} not updated"
            );
        }
    }

    #[test]
    fn sample_gathered_merges_flat_buffers() {
        let svc = per_shards(512, 2, 2);
        let h = svc.handle();
        for i in 0..200 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(32).unwrap();
        assert_eq!(g.indices.len(), 32);
        assert_eq!(g.obs.len(), 32 * 4);
        assert_eq!(g.next_obs.len(), 32 * 4);
        assert_eq!(g.actions.len(), 32);
        assert_eq!(g.rewards.len(), 32);
        assert_eq!(g.dones.len(), 32);
        // gathered rows carry the pushed payload (obs[0] == reward here)
        for (row, &r) in g.rewards.iter().enumerate() {
            assert_eq!(g.obs[row * 4], r, "row {row}");
        }
    }

    #[test]
    fn sampling_deterministic_per_seed_and_shard_count() {
        for shards in [1usize, 2, 4] {
            let run = || {
                let svc = per_shards(2048, shards, 42);
                let h = svc.handle();
                for i in 0..600 {
                    assert!(h.push(exp(i as f32)));
                }
                let mut drawn = Vec::new();
                for _ in 0..5 {
                    let b = h.sample(32);
                    assert!(h.update_priorities(b.indices.clone(), vec![0.7; 32]));
                    drawn.push(b.indices);
                }
                drop(svc);
                drawn
            };
            assert_eq!(run(), run(), "{shards} shards not deterministic");
        }
    }

    #[test]
    fn high_priority_oversampled_on_any_shard() {
        // a hot transition must be oversampled globally regardless of
        // which shard holds it
        for hot in 0..4usize {
            let svc = per_shards(1600, 4, 3);
            let h = svc.handle();
            for i in 0..1600 {
                assert!(h.push(exp(i as f32)));
            }
            // round-robin: global push i lands on shard i % 4, slot i / 4;
            // heat exactly one slot on shard `hot`
            let hot_global = global_index::encode(hot, 7);
            assert!(h.update_priorities(vec![hot_global], vec![100.0]));
            let mut hits = 0usize;
            let mut total = 0usize;
            for _ in 0..300 {
                let b = h.sample(64);
                total += b.indices.len();
                hits += b.indices.iter().filter(|&&g| g == hot_global).count();
            }
            let frac = hits as f64 / total as f64;
            // uniform rate would be 1/1600; PER within the owning shard
            // concentrates ~ p_hot/(p_hot + 399) of that shard's quarter
            let p_hot = 100.01f64.powf(0.6);
            let expect = 0.25 * p_hot / (399.0 * 1.01f64.powf(0.6) + p_hot);
            assert!(
                frac > expect * 0.5 && frac > 10.0 / 1600.0,
                "hot on shard {hot}: frac {frac:.4} vs expected ~{expect:.4}"
            );
        }
    }

    #[test]
    fn empty_shards_contribute_nothing_until_warm() {
        let svc = per_shards(64, 4, 5);
        let h = svc.handle();
        // only shard 0 gets data (explicit routing)
        for i in 0..10 {
            assert!(h.push_to(0, exp(i as f32)));
        }
        let b = h.sample(16);
        assert_eq!(b.indices.len(), 4, "one warm shard serves its split only");
        for &g in &b.indices {
            assert_eq!(global_index::decode(g).0, 0);
        }
    }

    #[test]
    fn concurrent_multi_actor_multi_learner_stress() {
        // the sharded mirror of service::concurrent_actors_and_learner,
        // with two learners hammering sample+update concurrently
        let svc = ShardedReplayService::spawn_partitioned(
            8192,
            4,
            256,
            6,
            |_, cap| crate::replay::make(ReplayKind::AmperFr, cap),
        );
        let mut producers = Vec::new();
        for t in 0..4 {
            let h = svc.handle();
            producers.push(std::thread::spawn(move || {
                for i in 0..500 {
                    assert!(h.push(exp((t * 1000 + i) as f32)));
                }
            }));
        }
        let mut learners = Vec::new();
        for _ in 0..2 {
            let h = svc.handle();
            learners.push(std::thread::spawn(move || {
                let mut drawn = 0usize;
                for _ in 0..50 {
                    let b = h.sample(32);
                    if !b.indices.is_empty() {
                        let n = b.indices.len();
                        assert!(h.update_priorities(b.indices, vec![0.5; n]));
                        drawn += n;
                    }
                }
                drawn
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let drawn: usize = learners.into_iter().map(|l| l.join().unwrap()).sum();
        assert!(drawn > 0);
        let h = svc.handle();
        assert_eq!(h.stats().pushes.load(Ordering::Relaxed), 2000);
        let mems = svc.stop();
        assert_eq!(mems.iter().map(|m| m.len()).sum::<usize>(), 2000);
    }

    #[test]
    fn sharded_stats_json_includes_segment_pool_and_drained_queues() {
        let svc = per_shards(512, 2, 11);
        let h = svc.handle();
        for i in 0..64 {
            assert!(h.push(exp(i as f32)));
        }
        let g = h.sample_gathered(16).unwrap();
        h.recycle(g);
        let (_mems, report) = svc.stop_with_report();
        let pools = report.get("pools").unwrap();
        assert!(pools.get("segment").is_some());
        assert!(pools.get("reply").is_some());
        let stages = report.get("stages").unwrap();
        let merge = stages.get("reply_merge").unwrap();
        assert_eq!(merge.get("count").and_then(|v| v.as_usize()), Some(1));
        // both shard gathers recorded into the shared histogram
        let gather = stages.get("worker_gather").unwrap();
        assert_eq!(gather.get("count").and_then(|v| v.as_usize()), Some(2));
        let depth = report.get("queue").unwrap().get("depth").unwrap();
        assert_eq!(depth.as_usize(), Some(0), "queues drained after stop");
    }

    #[test]
    fn one_shard_matches_single_owner_semantics() {
        let svc = per_shards(256, 1, 9);
        let h = svc.handle();
        for i in 0..100 {
            assert!(h.push(exp(i as f32)));
        }
        let b = h.sample(32);
        assert_eq!(b.indices.len(), 32);
        // shard 0 encodes to the identity: indices are plain slots
        assert!(b.indices.iter().all(|&i| i < 100));
        assert!(h.update_priorities(b.indices.clone(), vec![1.0; 32]));
        let mems = svc.stop();
        assert_eq!(mems[0].len(), 100);
    }
}
