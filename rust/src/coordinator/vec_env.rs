//! Vectorized environment driver: env actor threads stepping
//! independent env instances, feeding the replay service — the ingest
//! side of the serving path and the throughput benches.
//!
//! Two actor shapes share the same flush machinery:
//!
//! * [`VectorEnvDriver::spawn`] — N random-policy actor threads
//!   (exploration-phase ingest, backpressure studies).
//! * [`VectorEnvDriver::spawn_snapshot`] — one thread running a
//!   [`VecEnvTicker`]: all envs advance together and each tick runs
//!   **one batched forward** over every env's observation against the
//!   latest [`PolicySnapshot`], with per-env ε-greedy exploration on
//!   top of the batched greedy actions. The actor depends only on a
//!   [`SnapshotSlot`] and a [`ReplaySink`] — never on the engine or the
//!   agent — which is what lets it move out of process (Ape-X).
//!
//! Ingest is batch-first: each actor accumulates transitions into a
//! local [`ExperienceBatch`] (no per-step heap allocation, no per-step
//! channel send) and flushes it as one `PushBatch` command. The flush
//! size is governed by a [`FlushPolicy`]: a fixed policy flushes every
//! `push_batch` steps exactly like the PR-4 knob, while an adaptive
//! policy lets each actor's [`FlushController`] watch the service
//! command-queue load ([`ReplaySink::queue_load`]) and grow the batch
//! when the queue is deep (throughput: fewer, wider commands) or shrink
//! it when shallow (latency: transitions reach the memory sooner).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::snapshot::{ActScratch, PolicySnapshot, SnapshotSlot};
use super::ReplaySink;
use crate::envs;
use crate::envs::Environment;
use crate::replay::ExperienceBatch;
use crate::util::Rng;

/// Bounds for the actor flush batch (the `push_batch_min`/
/// `push_batch_max` config keys). `fixed(n)` pins both bounds to `n`,
/// which makes the adaptive controller a bit-exact no-op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlushPolicy {
    min: usize,
    max: usize,
}

impl FlushPolicy {
    /// Always flush every `n` steps (clamped to ≥ 1) — the PR-4
    /// fixed-knob behavior.
    pub fn fixed(n: usize) -> FlushPolicy {
        let n = n.max(1);
        FlushPolicy { min: n, max: n }
    }

    /// Adapt the flush batch within `[min, max]` (min clamped to ≥ 1,
    /// max clamped to ≥ min).
    pub fn adaptive(min: usize, max: usize) -> FlushPolicy {
        let min = min.max(1);
        FlushPolicy { min, max: max.max(min) }
    }

    pub fn min(&self) -> usize {
        self.min
    }

    pub fn max(&self) -> usize {
        self.max
    }

    /// A fixed policy never moves; the controller short-circuits.
    pub fn is_fixed(&self) -> bool {
        self.min == self.max
    }
}

/// Queue load at or above which the flush batch doubles.
const GROW_LOAD: f64 = 0.5;
/// Queue load at or below which the flush batch halves.
const SHRINK_LOAD: f64 = 0.125;

/// Per-actor depth-aware flush controller: multiplicative
/// increase/decrease of the flush batch within the policy bounds,
/// driven by the service's queue load observed after each flush.
///
/// The controller is deliberately hysteretic (grow at ≥ 50% load,
/// shrink at ≤ 12.5%) so it doesn't oscillate on a queue hovering at
/// moderate depth, and deterministic given the same load observations.
/// With `min == max` it never moves and `observe` returns immediately —
/// the fixed-flush path stays bit-identical (pinned by
/// `batch_equivalence`).
#[derive(Debug, Clone)]
pub struct FlushController {
    policy: FlushPolicy,
    current: usize,
}

impl FlushController {
    /// Start at the policy minimum (latency-first until load says grow).
    pub fn new(policy: FlushPolicy) -> FlushController {
        FlushController { policy, current: policy.min }
    }

    /// The flush threshold to use for the next sub-batch.
    pub fn flush_at(&self) -> usize {
        self.current
    }

    /// Feed one queue-load observation (from
    /// [`ReplaySink::queue_load`], taken after a flush).
    pub fn observe(&mut self, load: f64) {
        if self.policy.is_fixed() {
            return;
        }
        if load >= GROW_LOAD {
            self.current = (self.current * 2).min(self.policy.max);
        } else if load <= SHRINK_LOAD {
            self.current = (self.current / 2).max(self.policy.min);
        }
    }
}

/// Steps `n_envs` environments in lockstep against the latest published
/// [`PolicySnapshot`]: every tick refreshes the cached snapshot (one
/// atomic epoch check — staleness is recorded into the slot's
/// histogram), runs **one batched forward** over all envs'
/// observations, then applies per-env ε-greedy exploration on top of
/// the batched greedy actions. Per-env RNG streams use the same
/// derivation as the threaded driver (`seed ^ i·0xA5A5_A5A5`), so env
/// trajectories are reproducible per seed.
///
/// The ticker is deliberately engine-free: its whole policy surface is
/// the snapshot slot, so an actor process needs only this plus a
/// [`ReplaySink`] to participate.
pub struct VecEnvTicker {
    envs: Vec<Box<dyn Environment>>,
    rngs: Vec<Rng>,
    /// Current observation of every env, row-major `n_envs × dim`.
    obs: Vec<f32>,
    dim: usize,
    n_actions: usize,
    slot: Arc<SnapshotSlot>,
    snap: Arc<PolicySnapshot>,
    scratch: ActScratch,
    eps: f64,
}

impl VecEnvTicker {
    /// Build `n_envs` instances of `env_name` (panics on an unknown env,
    /// like [`VectorEnvDriver::spawn`]) and validate that the slot's
    /// current snapshot matches the env's dims — published snapshots
    /// inherit the initial dims, so the check holds for the lifetime of
    /// the ticker.
    pub fn new(
        env_name: &str,
        n_envs: usize,
        slot: Arc<SnapshotSlot>,
        seed: u64,
        eps: f64,
    ) -> VecEnvTicker {
        assert!(n_envs > 0, "ticker needs at least one env");
        let mut envs: Vec<Box<dyn Environment>> = (0..n_envs)
            .map(|_| {
                envs::make(env_name).unwrap_or_else(|| panic!("unknown env {env_name}"))
            })
            .collect();
        let dim = envs[0].obs_dim();
        let n_actions = envs[0].n_actions();
        let snap = slot.load();
        assert_eq!(snap.obs_dim(), dim, "snapshot obs_dim must match {env_name}");
        assert_eq!(
            snap.n_actions(),
            n_actions,
            "snapshot n_actions must match {env_name}"
        );
        let mut rngs: Vec<Rng> = (0..n_envs)
            .map(|i| Rng::new(seed ^ (i as u64).wrapping_mul(0xA5A5_A5A5)))
            .collect();
        let mut obs = vec![0.0; n_envs * dim];
        for (i, env) in envs.iter_mut().enumerate() {
            let first = env.reset(&mut rngs[i]);
            obs[i * dim..(i + 1) * dim].copy_from_slice(&first);
        }
        VecEnvTicker {
            envs,
            rngs,
            obs,
            dim,
            n_actions,
            slot,
            snap,
            scratch: ActScratch::default(),
            eps,
        }
    }

    pub fn n_envs(&self) -> usize {
        self.envs.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.dim
    }

    /// Epoch of the snapshot the next tick will act on.
    pub fn snapshot_epoch(&self) -> u64 {
        self.snap.epoch()
    }

    /// Advance every env by one step, appending `n_envs` transitions to
    /// `out`. Refreshes the cached snapshot first and returns how many
    /// epochs behind this tick acted (also recorded in the slot's
    /// staleness histogram).
    pub fn tick(&mut self, out: &mut ExperienceBatch) -> u64 {
        let behind = self.slot.refresh(&mut self.snap);
        let n = self.envs.len();
        // destructured so the greedy-action borrow of `scratch` can
        // coexist with mutation of the envs/rngs/obs columns
        let VecEnvTicker { envs, rngs, obs, dim, n_actions, snap, scratch, eps, .. } = self;
        let dim = *dim;
        let greedy = snap
            .greedy_actions(obs, n, scratch)
            .expect("snapshot dims validated at construction");
        for i in 0..n {
            let rng = &mut rngs[i];
            let action =
                if rng.chance(*eps) { rng.below(*n_actions) } else { greedy[i] as usize };
            let step = envs[i].step(action, rng);
            out.push_parts(
                &obs[i * dim..(i + 1) * dim],
                action as u32,
                step.reward,
                &step.obs,
                step.terminated,
            );
            let next = if step.done() { envs[i].reset(rng) } else { step.obs };
            obs[i * dim..(i + 1) * dim].copy_from_slice(&next);
        }
        behind
    }
}

/// Runs env actor threads feeding a [`ReplaySink`]: random-policy
/// actors via [`Self::spawn`] (exploration/ingest studies) or a
/// snapshot-driven batched ε-greedy actor via [`Self::spawn_snapshot`]
/// (the serve path).
pub struct VectorEnvDriver {
    stop: Arc<AtomicBool>,
    steps: Arc<AtomicU64>,
    /// High-water mark of any actor's flush batch (telemetry: proves
    /// the adaptive controller actually moved under load).
    flush_hwm: Arc<AtomicUsize>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl VectorEnvDriver {
    /// Spawn the actors with a fixed flush of `push_batch` steps
    /// (clamped to ≥ 1) — the scalar-compatible convenience over
    /// [`Self::spawn_with_policy`]. `push_batch = 1` reproduces the
    /// one-command-per-step behavior exactly.
    pub fn spawn<S: ReplaySink>(
        env_name: &str,
        n_envs: usize,
        service: S,
        seed: u64,
        push_batch: usize,
    ) -> VectorEnvDriver {
        Self::spawn_with_policy(
            env_name,
            n_envs,
            service,
            seed,
            FlushPolicy::fixed(push_batch),
        )
    }

    /// Spawn the actors. Each steps its own env, accumulates transitions
    /// into a local [`ExperienceBatch`], and flushes it to `service`
    /// (either a [`super::ServiceHandle`] or a [`super::ShardedHandle`])
    /// when its [`FlushController`] threshold is reached; the controller
    /// re-reads the service queue load after every flush. The tail is
    /// flushed on stop; actors exit when the service stops accepting
    /// pushes. The step counter advances per *accepted* transition, at
    /// flush time.
    pub fn spawn_with_policy<S: ReplaySink>(
        env_name: &str,
        n_envs: usize,
        service: S,
        seed: u64,
        policy: FlushPolicy,
    ) -> VectorEnvDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let flush_hwm = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let name = env_name.to_string();
            let svc = service.clone();
            let stop_flag = stop.clone();
            let counter = steps.clone();
            let hwm = flush_hwm.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("actor-{i}"))
                    .spawn(move || {
                        let mut env = envs::make(&name)
                            .unwrap_or_else(|| panic!("unknown env {name}"));
                        let dim = env.obs_dim();
                        let mut rng =
                            Rng::new(seed ^ (i as u64).wrapping_mul(0xA5A5_A5A5));
                        let mut obs = env.reset(&mut rng);
                        let mut ctl = FlushController::new(policy);
                        // capacity for the policy max: adapting the
                        // threshold never reallocates the pending batch
                        let mut pending =
                            ExperienceBatch::with_capacity(dim, policy.max());
                        while !stop_flag.load(Ordering::Relaxed) {
                            let action = rng.below(env.n_actions());
                            let step = env.step(action, &mut rng);
                            pending.push_parts(
                                &obs,
                                action as u32,
                                step.reward,
                                &step.obs,
                                step.terminated,
                            );
                            if pending.len() >= ctl.flush_at() {
                                let rows = pending.len() as u64;
                                hwm.fetch_max(pending.len(), Ordering::Relaxed);
                                let full = std::mem::replace(
                                    &mut pending,
                                    ExperienceBatch::with_capacity(
                                        dim,
                                        policy.max(),
                                    ),
                                );
                                if !svc.push_experience_batch(full) {
                                    return; // service stopped — stop producing
                                }
                                counter.fetch_add(rows, Ordering::Relaxed);
                                ctl.observe(svc.queue_load());
                            }
                            obs = if step.done() {
                                env.reset(&mut rng)
                            } else {
                                step.obs
                            };
                        }
                        // flush the sub-batch tail so no transition is lost
                        let rows = pending.len() as u64;
                        if rows > 0 && svc.push_experience_batch(pending) {
                            counter.fetch_add(rows, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn actor"),
            );
        }
        VectorEnvDriver { stop, steps, flush_hwm, threads }
    }

    /// Spawn one snapshot-driven actor thread running a
    /// [`VecEnvTicker`]: all `n_envs` envs advance together, each tick
    /// is one batched forward against the latest snapshot in `slot`,
    /// and transitions flush to `service` under the same
    /// [`FlushController`] rules as the random-policy actors. `eps` is
    /// the per-env exploration rate applied on top of the batched
    /// greedy actions.
    pub fn spawn_snapshot<S: ReplaySink>(
        env_name: &str,
        n_envs: usize,
        slot: Arc<SnapshotSlot>,
        service: S,
        seed: u64,
        eps: f64,
        policy: FlushPolicy,
    ) -> VectorEnvDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let flush_hwm = Arc::new(AtomicUsize::new(0));
        let name = env_name.to_string();
        let stop_flag = stop.clone();
        let counter = steps.clone();
        let hwm = flush_hwm.clone();
        let thread = std::thread::Builder::new()
            .name("vec-actor".into())
            .spawn(move || {
                let mut ticker = VecEnvTicker::new(&name, n_envs, slot, seed, eps);
                let dim = ticker.obs_dim();
                // a tick appends n_envs rows at once, so the pending
                // batch must hold at least one whole tick past the
                // flush threshold
                let cap = policy.max().max(n_envs) + n_envs;
                let mut ctl = FlushController::new(policy);
                let mut pending = ExperienceBatch::with_capacity(dim, cap);
                while !stop_flag.load(Ordering::Relaxed) {
                    ticker.tick(&mut pending);
                    if pending.len() >= ctl.flush_at() {
                        let rows = pending.len() as u64;
                        hwm.fetch_max(pending.len(), Ordering::Relaxed);
                        let full = std::mem::replace(
                            &mut pending,
                            ExperienceBatch::with_capacity(dim, cap),
                        );
                        if !service.push_experience_batch(full) {
                            return; // service stopped — stop producing
                        }
                        counter.fetch_add(rows, Ordering::Relaxed);
                        ctl.observe(service.queue_load());
                    }
                }
                // flush the sub-batch tail so no transition is lost
                let rows = pending.len() as u64;
                if rows > 0 && service.push_experience_batch(pending) {
                    counter.fetch_add(rows, Ordering::Relaxed);
                }
            })
            .expect("spawn vec actor");
        VectorEnvDriver { stop, steps, flush_hwm, threads: vec![thread] }
    }

    /// Total env steps pushed (and accepted) so far.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Largest flush batch any actor has sent so far (0 before the
    /// first flush). Under a fixed policy this equals the knob; under
    /// an adaptive policy it shows how far backpressure pushed the
    /// controller toward `push_batch_max`.
    pub fn max_flush(&self) -> usize {
        self.flush_hwm.load(Ordering::Relaxed)
    }

    /// Signal and join all actors (flushes pending sub-batches).
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.steps.load(Ordering::Relaxed)
    }
}

impl Drop for VectorEnvDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReplayService;
    use crate::replay::ReplayKind;
    use crate::runtime::{EnvArtifacts, TrainState};

    fn cartpole_slot(seed: u64) -> (Arc<SnapshotSlot>, TrainState) {
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let state = TrainState::init(&spec, seed).unwrap();
        let snap =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 0).unwrap();
        (SnapshotSlot::new(snap), state)
    }

    #[test]
    fn ticker_pushes_one_row_per_env_per_tick() {
        let (slot, state) = cartpole_slot(1);
        let mut ticker = VecEnvTicker::new("cartpole", 3, slot.clone(), 42, 0.1);
        assert_eq!(ticker.n_envs(), 3);
        let mut out = ExperienceBatch::with_capacity(ticker.obs_dim(), 32);
        assert_eq!(ticker.tick(&mut out), 0, "initial snapshot is current");
        assert_eq!(out.len(), 3);
        slot.publish(state.snapshot_params());
        slot.publish(state.snapshot_params());
        assert_eq!(ticker.tick(&mut out), 2, "ticker observed two missed epochs");
        assert_eq!(ticker.snapshot_epoch(), 2);
        assert_eq!(out.len(), 6);
        let stats = slot.stats();
        assert_eq!(stats.behind.count(), 2, "one staleness sample per tick");
        assert_eq!(stats.behind.max_ns(), 2);
    }

    #[test]
    fn snapshot_driver_fills_the_memory_and_flushes_tails() {
        let (slot, state) = cartpole_slot(2);
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 10_000),
            1024,
            0,
        );
        let driver = VectorEnvDriver::spawn_snapshot(
            "cartpole",
            4,
            slot.clone(),
            svc.handle(),
            42,
            0.05,
            FlushPolicy::fixed(32),
        );
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while driver.steps() < 500 && std::time::Instant::now() < deadline {
            slot.publish(state.snapshot_params());
            std::thread::yield_now();
        }
        assert_eq!(driver.max_flush(), 32, "4-env ticks land exactly on the fixed knob");
        let total = driver.stop();
        assert!(total >= 500, "only {total} steps ingested");
        let pushes = svc.handle().stats().pushes.load(Ordering::Relaxed);
        assert_eq!(pushes, total, "accepted rows must match counted steps");
        let mem = svc.stop();
        assert_eq!(mem.len() as u64, total.min(10_000), "tails flushed on stop");
        assert!(slot.stats().publishes.load(Ordering::Relaxed) > 0);
    }

    fn run_to(n: u64, push_batch: usize) -> (u64, usize) {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 10_000),
            1024,
            0,
        );
        let driver =
            VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 42, push_batch);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while driver.steps() < n && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let total = driver.stop();
        let pushes = svc.handle().stats().pushes.load(Ordering::Relaxed);
        let mem = svc.stop();
        assert_eq!(pushes, total, "accepted rows must match counted steps");
        (total, mem.len())
    }

    #[test]
    fn actors_fill_the_memory() {
        let (total, stored) = run_to(2000, 1);
        assert!(total >= 2000, "only {total} steps ingested");
        assert!(stored > 1000);
    }

    #[test]
    fn batched_actors_fill_the_memory_and_flush_tails() {
        let (total, stored) = run_to(2000, 32);
        assert!(total >= 2000, "only {total} steps ingested");
        assert!(stored > 1000);
        // every accepted step is stored (tails flushed on stop) up to
        // ring capacity
        assert_eq!(stored as u64, total.min(10_000));
    }

    #[test]
    fn policy_clamps_and_classifies() {
        assert_eq!(FlushPolicy::fixed(0), FlushPolicy::fixed(1));
        assert!(FlushPolicy::fixed(8).is_fixed());
        let p = FlushPolicy::adaptive(0, 0);
        assert_eq!((p.min(), p.max()), (1, 1));
        let p = FlushPolicy::adaptive(16, 4); // max below min: clamped up
        assert_eq!((p.min(), p.max()), (16, 16));
        assert!(!FlushPolicy::adaptive(2, 64).is_fixed());
    }

    #[test]
    fn controller_grows_under_load_and_shrinks_when_idle() {
        let mut c = FlushController::new(FlushPolicy::adaptive(2, 64));
        assert_eq!(c.flush_at(), 2);
        for _ in 0..10 {
            c.observe(0.9); // deep queue: double up to the max
        }
        assert_eq!(c.flush_at(), 64);
        c.observe(0.3); // moderate load: hysteresis band, no move
        assert_eq!(c.flush_at(), 64);
        for _ in 0..10 {
            c.observe(0.0); // idle: halve down to the min
        }
        assert_eq!(c.flush_at(), 2);
    }

    #[test]
    fn fixed_controller_never_moves() {
        let mut c = FlushController::new(FlushPolicy::fixed(8));
        for load in [0.0, 0.5, 1.0, 2.0] {
            c.observe(load);
            assert_eq!(c.flush_at(), 8);
        }
    }

    #[test]
    fn adaptive_driver_reports_flush_high_water_mark() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 10_000),
            1024,
            0,
        );
        let driver = VectorEnvDriver::spawn_with_policy(
            "cartpole",
            2,
            svc.handle(),
            7,
            FlushPolicy::fixed(4),
        );
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(5);
        while driver.steps() < 100 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let hwm = driver.max_flush();
        driver.stop();
        // fixed policy: the high-water mark is exactly the knob
        // (tail flushes are smaller, never larger)
        assert_eq!(hwm, 4);
        let _ = svc.stop();
    }
}
