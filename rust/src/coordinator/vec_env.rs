//! Vectorized environment driver: N actor threads stepping independent
//! env instances with a shared policy snapshot, feeding the replay
//! service — the ingest side of the serving example and the throughput
//! benches.
//!
//! Ingest is batch-first: each actor accumulates transitions into a
//! local [`ExperienceBatch`] (no per-step heap allocation, no per-step
//! channel send) and flushes it as one `PushBatch` command every
//! `push_batch` steps. `push_batch = 1` reproduces the scalar
//! one-command-per-step behavior exactly.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use super::ReplaySink;
use crate::envs;
use crate::replay::ExperienceBatch;
use crate::util::Rng;

/// Runs `n_envs` actor threads with random policies (exploration phase) —
/// the policy-driven path lives in the agent; this driver exists to
/// exercise ingest concurrency and backpressure.
pub struct VectorEnvDriver {
    stop: Arc<AtomicBool>,
    steps: Arc<AtomicU64>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl VectorEnvDriver {
    /// Spawn the actors. Each steps its own env, accumulates transitions
    /// into a local [`ExperienceBatch`], and flushes it to `service`
    /// (either a [`super::ServiceHandle`] or a [`super::ShardedHandle`])
    /// every `push_batch` steps (clamped to ≥ 1; the tail is flushed on
    /// stop). Actors exit when the service stops accepting pushes. The
    /// step counter advances per *accepted* transition, at flush time.
    pub fn spawn<S: ReplaySink>(
        env_name: &str,
        n_envs: usize,
        service: S,
        seed: u64,
        push_batch: usize,
    ) -> VectorEnvDriver {
        let flush_at = push_batch.max(1);
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let name = env_name.to_string();
            let svc = service.clone();
            let stop_flag = stop.clone();
            let counter = steps.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("actor-{i}"))
                    .spawn(move || {
                        let mut env = envs::make(&name)
                            .unwrap_or_else(|| panic!("unknown env {name}"));
                        let dim = env.obs_dim();
                        let mut rng =
                            Rng::new(seed ^ (i as u64).wrapping_mul(0xA5A5_A5A5));
                        let mut obs = env.reset(&mut rng);
                        let mut pending = ExperienceBatch::with_capacity(dim, flush_at);
                        while !stop_flag.load(Ordering::Relaxed) {
                            let action = rng.below(env.n_actions());
                            let step = env.step(action, &mut rng);
                            pending.push_parts(
                                &obs,
                                action as u32,
                                step.reward,
                                &step.obs,
                                step.terminated,
                            );
                            if pending.len() >= flush_at {
                                let rows = pending.len() as u64;
                                let full = std::mem::replace(
                                    &mut pending,
                                    ExperienceBatch::with_capacity(dim, flush_at),
                                );
                                if !svc.push_experience_batch(full) {
                                    return; // service stopped — stop producing
                                }
                                counter.fetch_add(rows, Ordering::Relaxed);
                            }
                            obs = if step.done() {
                                env.reset(&mut rng)
                            } else {
                                step.obs
                            };
                        }
                        // flush the sub-batch tail so no transition is lost
                        let rows = pending.len() as u64;
                        if rows > 0 && svc.push_experience_batch(pending) {
                            counter.fetch_add(rows, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn actor"),
            );
        }
        VectorEnvDriver { stop, steps, threads }
    }

    /// Total env steps pushed (and accepted) so far.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Signal and join all actors (flushes pending sub-batches).
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.steps.load(Ordering::Relaxed)
    }
}

impl Drop for VectorEnvDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReplayService;
    use crate::replay::ReplayKind;

    fn run_to(n: u64, push_batch: usize) -> (u64, usize) {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 10_000),
            1024,
            0,
        );
        let driver =
            VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 42, push_batch);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while driver.steps() < n && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let total = driver.stop();
        let pushes = svc.handle().stats().pushes.load(Ordering::Relaxed);
        let mem = svc.stop();
        assert_eq!(pushes, total, "accepted rows must match counted steps");
        (total, mem.len())
    }

    #[test]
    fn actors_fill_the_memory() {
        let (total, stored) = run_to(2000, 1);
        assert!(total >= 2000, "only {total} steps ingested");
        assert!(stored > 1000);
    }

    #[test]
    fn batched_actors_fill_the_memory_and_flush_tails() {
        let (total, stored) = run_to(2000, 32);
        assert!(total >= 2000, "only {total} steps ingested");
        assert!(stored > 1000);
        // every accepted step is stored (tails flushed on stop) up to
        // ring capacity
        assert_eq!(stored as u64, total.min(10_000));
    }
}
